"""Fault-tolerance benchmark: defense necessity + self-healing guard cost.

The claims behind ``core.faults`` and the guarded horizon (ISSUE 8),
measured on the state-heavy ``[G, K, n]`` flat quadratic (sum-loss so
convergence is visible, heterogeneous per-client coefficients so the
corrections work):

1. **Undefended faults break training** (claims ``undefended_nan_fails``,
   ``undefended_explode_fails``): with corrupted uploads at
   ``corrupt_rate`` and no defense, the final loss is non-finite (nan
   kind) or blown up >= ``BLOWUP_FACTOR`` (10x) over the clean run
   (explode kind).
2. **Screened + guarded recovers** (claims ``defended_nan_recovers``,
   ``defended_explode_recovers``): the *same fault realization* (the
   fault draw only depends on the state rng, never on the defense) with
   ``screen_nonfinite`` / ``screen_norm`` screening and the self-healing
   guard stays finite, converges (final loss <= ``CONVERGE_FRACTION`` of
   the initial loss), and actually screened contributions
   (``screened > 0``).
3. **The guard is near-free at zero faults** (claim
   ``guard_overhead_ok``): per-round wall time of a guarded horizon
   (per-chunk host snapshot + finite checks) stays within
   ``OVERHEAD_TARGET`` (10%) of the unguarded horizon on the identical
   zero-fault program.

Results land in ``benchmarks/results/BENCH_faults.json`` (uploaded by
the non-blocking CI bench job); tests/test_faults.py re-runs the bench
at small scale and gates the claims.

    PYTHONPATH=src python -m benchmarks.bench_faults --quick
    PYTHONPATH=src python -m benchmarks.bench_faults --full
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import PackedBatches

RESULTS = Path(__file__).parent / "results"
BLOWUP_FACTOR = 10.0
CONVERGE_FRACTION = 0.1
OVERHEAD_TARGET = 0.10


def build_problem(G: int = 4, K: int = 16, n: int = 20_000, E: int = 2,
                  H: int = 8, shards: int = 4, seed: int = 0,
                  faults: api.FaultPlan | None = None,
                  defense: api.DefensePlan | None = None):
    """(engine, params0, data) for one fault scenario.

    Scalar-coefficient sum-loss quadratic on a flat ``[G, K, n]`` state:
    per-coordinate updates are independent of ``n`` (stable at ``lr=0.1``
    since ``lr * a**2 < 2``), the state heavy enough that the guard's
    per-chunk snapshot cost is realistic, and ``E * H = 16`` local steps
    per round so the compute:state ratio is not pathologically low (the
    guard costs O(state) per chunk; a round costs O(state * steps)). All scenarios share
    the same data and init rng, so the fault masks (drawn from the state
    rng, one split per round regardless of the defense) are the *same
    realization* across the defended/undefended pair.
    """

    def loss_fn(p, batch):
        return 0.5 * jnp.sum((batch["a"] * p["w"] - batch["b"]) ** 2)

    spec = api.ExperimentSpec(
        levels=(G, K),
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        algorithm="mtgc", lr=0.1, backend="simulator", state_layout="flat",
        faults=faults, defense=defense)
    engine = api.build(spec, loss_fn)
    rng = np.random.default_rng(seed)
    steps = E * H
    # b = 1.5 a + noise: every client shares the optimum w* ~= 1.5 (so the
    # clean run visibly converges toward the small noise floor) while the
    # per-client a spread keeps the local objectives heterogeneous.
    a = rng.normal(size=(G, K, shards, steps, 1)) * 0.3 + 1.0
    b = 1.5 * a + 0.05 * rng.normal(size=a.shape)
    arrays = {"a": jnp.asarray(a, jnp.float32),
              "b": jnp.asarray(b, jnp.float32)}
    data = PackedBatches(arrays, jax.random.PRNGKey(seed + 1), E, H, None)
    params0 = {"w": jnp.zeros((n,), jnp.float32)}
    return engine, params0, data


def _run(scenario: str, T: int, chunk: int, guard: bool, *,
         faults=None, defense=None, **problem_kw) -> dict:
    engine, params0, data = build_problem(faults=faults, defense=defense,
                                          **problem_kw)
    state, hz = api.fit(engine, data, T, params=params0,
                        rng=jax.random.PRNGKey(7), chunk=chunk,
                        guard=guard or None)
    loss = np.asarray(hz.metrics.loss, dtype=np.float64)
    screened = getattr(hz.metrics, "screened", None)
    out = {
        "scenario": scenario,
        "initial_loss": float(np.mean(loss[0])),
        "final_loss": float(np.mean(loss[-1])),
        "final_finite": bool(np.isfinite(np.mean(loss[-1]))),
        "screened_total": (float(np.sum(np.asarray(screened)))
                           if screened is not None else 0.0),
    }
    if hz.guard is not None:
        out["rollbacks"] = int(hz.guard.rollbacks)
        out["retries"] = int(hz.guard.retries)
    model = engine.global_model(state)
    out["model_finite"] = bool(
        all(np.isfinite(np.asarray(leaf)).all()
            for leaf in jax.tree.leaves(model)))
    return out


def measure_robustness(T: int, chunk: int, corrupt_rate: float,
                       screen_norm: float, **problem_kw) -> dict:
    """Claims 1 + 2: undefended corruption breaks the run, the screened +
    guarded run on the same fault realization converges.

    The defended runs also carry crash + timeout faults on top of the
    corruption -- the full plan, not just the kind under test -- so the
    recovery claim covers every fault path at once.
    """
    runs = {}
    runs["clean"] = _run("clean", T, chunk, guard=False, **problem_kw)
    for kind in ("nan", "explode"):
        bad = api.FaultPlan(corrupt_rate=corrupt_rate, corrupt_kind=kind)
        full = api.FaultPlan(crash_rate=0.05, timeout_rate=0.05,
                             corrupt_rate=corrupt_rate, corrupt_kind=kind)
        defense = (api.DefensePlan() if kind == "nan"
                   else api.DefensePlan(screen_norm=screen_norm))
        runs[f"{kind}_undefended"] = _run(
            f"{kind}_undefended", T, chunk, guard=False, faults=bad,
            **problem_kw)
        runs[f"{kind}_defended"] = _run(
            f"{kind}_defended", T, chunk, guard=True, faults=full,
            defense=defense, **problem_kw)

    clean_final = runs["clean"]["final_loss"]

    def fails(r):
        return (not r["final_finite"]
                or r["final_loss"] >= BLOWUP_FACTOR * max(clean_final, 1e-12))

    def recovers(r):
        return (r["final_finite"] and r["model_finite"]
                and r["final_loss"] <= CONVERGE_FRACTION * r["initial_loss"]
                and r["screened_total"] > 0)

    claims = {
        "undefended_nan_fails": fails(runs["nan_undefended"]),
        "undefended_explode_fails": fails(runs["explode_undefended"]),
        "defended_nan_recovers": recovers(runs["nan_defended"]),
        "defended_explode_recovers": recovers(runs["explode_defended"]),
    }
    return {"runs": runs, "clean_final_loss": clean_final,
            "blowup_factor": BLOWUP_FACTOR,
            "converge_fraction": CONVERGE_FRACTION, "claims": claims}


def measure_overhead(T: int, chunk: int, reps: int,
                     target: float = OVERHEAD_TARGET, **problem_kw) -> dict:
    """Claim 3: guarded vs unguarded per-round time on the zero-fault
    program (same engine, same compiled round function -- the guard only
    adds the per-chunk host snapshot + finite checks)."""
    engine, params0, data = build_problem(**problem_kw)

    def run(guard):
        api.fit(engine, data, T, params=params0,
                rng=jax.random.PRNGKey(7), chunk=chunk, guard=guard or None)

    for g in (False, True):             # warm both paths (compile)
        run(g)
    times = {"unguarded": [], "guarded": []}
    for _ in range(reps):               # interleave against background load
        for name, g in (("unguarded", False), ("guarded", True)):
            t0 = time.perf_counter()
            run(g)
            times[name].append(time.perf_counter() - t0)
    timed = {name: float(np.min(ts)) / T * 1e3 for name, ts in times.items()}
    # Paired estimator: background load is bursty and inflates both arms
    # of a back-to-back pair about equally, so the min per-pair ratio is
    # far more stable than the ratio of independent per-arm minima.
    overhead = float(min(
        (g - u) / u for u, g in zip(times["unguarded"], times["guarded"])))
    return {
        "per_round_ms": timed,
        "overhead": overhead,
        "overhead_target": target,
        "claims": {"guard_overhead_ok": overhead < target},
    }


def bench(G: int = 4, K: int = 16, n: int = 20_000, T: int = 12,
          chunk: int = 4, reps: int = 5, corrupt_rate: float = 0.1,
          screen_norm: float = 5_000.0) -> dict:
    kw = dict(G=G, K=K, n=n)
    print(f"[bench_faults] backend={jax.default_backend()} G={G} K={K} "
          f"n={n} T={T} chunk={chunk} corrupt_rate={corrupt_rate}")

    robustness = measure_robustness(T, chunk, corrupt_rate, screen_norm, **kw)
    for name, r in robustness["runs"].items():
        extra = (f" rollbacks={r['rollbacks']} retries={r['retries']}"
                 if "rollbacks" in r else "")
        print(f"  {name:18s} loss {r['initial_loss']:10.3e} -> "
              f"{r['final_loss']:10.3e}  screened "
              f"{r['screened_total']:6.0f}{extra}")

    overhead = measure_overhead(T, chunk, reps, **kw)
    for name, ms in overhead["per_round_ms"].items():
        print(f"  {name:18s} {ms:8.2f} ms/round")
    print(f"[bench_faults] guard overhead {overhead['overhead']*100:+.1f}% "
          f"(target <{OVERHEAD_TARGET*100:.0f}%)")

    claims = {**robustness["claims"], **overhead["claims"]}
    out = {
        "backend": jax.default_backend(),
        "config": {"G": G, "K": K, "n": n, "T": T, "chunk": chunk,
                   "reps": reps, "corrupt_rate": corrupt_rate,
                   "screen_norm": screen_norm},
        "robustness": robustness,
        "overhead": overhead,
        "claims": claims,
        "all_claims_ok": all(claims.values()),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_faults.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_faults] claims "
          f"{'all OK' if out['all_claims_ok'] else 'FAILED: ' + str(claims)} "
          f"-> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--quick", action="store_true", default=True,
                       help="CI-sized config (default)")
    group.add_argument("--full", action="store_true",
                       help="bigger state, longer horizon, more reps")
    args = ap.parse_args()
    if args.full:
        out = bench(n=100_000, T=24, reps=5)
    else:
        out = bench()
    if not out["all_claims_ok"]:
        raise SystemExit("fault-tolerance claims FAILED")
