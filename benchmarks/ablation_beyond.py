"""Beyond-paper ablations on the MTGC design space (not in the paper):

1. correction_init: footnote-2 zero-init vs the theoretical gradient init
   (Alg. 1 line 3) -- does the theory's init pay off in practice?
2. server_lr: aggregator-side over-relaxation (1.0 = paper's plain average).
3. client scale: linear-speedup check -- rounds-to-target vs #clients
   (Corollary 4.1 predicts ~1/sqrt(N*n) error, i.e. fewer rounds with more
   clients at equal E*H).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSetup, report, rounds_to_accuracy
from repro.core import HFLConfig, global_model, hfl_init, make_global_round
from repro.data.partition import partition, sample_round_batches
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp


def _run(setup, rounds=None, **cfg_over):
    """run_algorithm twin that exposes every HFLConfig field."""
    rng = np.random.default_rng(setup.seed)
    ds = make_classification(rng, num_samples=setup.samples,
                             num_classes=setup.num_classes, dim=setup.dim)
    train, test = train_test_split(ds, rng)
    G, K = setup.num_groups, setup.clients_per_group
    idx = partition(train.y, G, K, mode=setup.mode, alpha=setup.alpha, seed=0)
    init, apply = mlp(setup.num_classes, setup.dim, hidden=setup.hidden)
    cfg = HFLConfig(num_groups=G, clients_per_group=K,
                    local_steps=setup.local_steps,
                    group_rounds=setup.group_rounds, lr=setup.lr,
                    algorithm="mtgc", **cfg_over)
    state = hfl_init(init(jax.random.PRNGKey(0)), cfg)
    step = jax.jit(make_global_round(make_loss(apply), cfg))
    hist = {"round": [], "acc": []}
    for t in range(rounds or setup.rounds):
        b = sample_round_batches(train.x, train.y, idx, rng,
                                 setup.group_rounds, setup.local_steps,
                                 setup.batch)
        state, _ = step(state, jax.tree.map(jnp.asarray, b))
        if (t + 1) % 2 == 0:
            hist["round"].append(t + 1)
            hist["acc"].append(float(accuracy(
                apply, global_model(state), jnp.asarray(test.x), test.y)))
    return hist


def main(quick: bool = True) -> None:
    setup = BenchSetup(rounds=24) if quick else BenchSetup.paper()
    rows = []
    for init_mode in ("zero", "gradient"):
        h = _run(setup, correction_init=init_mode)
        rows.append(["correction_init", init_mode, h["acc"][-1],
                     rounds_to_accuracy(h, 0.95)])
    for slr in (1.0, 1.25, 1.5):
        h = _run(setup, server_lr=slr)
        rows.append(["server_lr", slr, h["acc"][-1],
                     rounds_to_accuracy(h, 0.95)])
    for K in (2, 5, 10):
        # milder skew for the scale sweep: 40 clients at alpha=0.1 can
        # starve clients of samples entirely
        s2 = dataclasses.replace(setup, clients_per_group=K, alpha=0.5,
                                 samples=max(setup.samples, 1200 * K))
        h = _run(s2)
        rows.append(["clients_per_group", K, h["acc"][-1],
                     rounds_to_accuracy(h, 0.95)])
    report("ablation_beyond", rows,
           ["knob", "value", "final_acc", "rounds_to_0.95"])


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
