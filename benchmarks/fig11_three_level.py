"""Paper App. E / Fig. 11: MTGC on a three-level hierarchy (Algorithm 2)
with non-i.i.d. data at every level, vs the no-correction baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSetup, report
from repro.core import make_multilevel_round, multilevel_global_model, multilevel_init
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    dims = (2, 2, 3) if quick else (4, 5, 5)
    periods = (8, 4, 2) if quick else (500, 100, 10)
    rounds = 25 if quick else 40
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=setup.samples,
                             num_classes=setup.num_classes, dim=setup.dim)
    train, test = train_test_split(ds, rng)
    # 3-level non-iid: Dirichlet over level-2 groups, then level-3 clients
    idx2 = partition(train.y, dims[0], dims[1] * dims[2], mode="both_noniid",
                     alpha=setup.alpha, seed=0)
    init, apply = mlp(setup.num_classes, setup.dim, hidden=setup.hidden)
    loss_fn = make_loss(apply)

    rows = []
    for use_corr in (True, False):
        params = init(jax.random.PRNGKey(0))
        st = multilevel_init(params, dims)
        # no-correction baseline = periods collapse corrections to zero via
        # lr trick: reuse engine but zero out nus after each round
        rf = jax.jit(make_multilevel_round(loss_fn, dims, periods, setup.lr))
        accs = []
        for t in range(rounds):
            P1 = periods[0]
            sel = np.stack([
                np.stack([
                    rng.choice(idx2[k1][k2 * dims[2] + k3], size=(P1, setup.batch))
                    for k2 in range(dims[1]) for k3 in range(dims[2])
                ]).reshape(dims[1], dims[2], P1, setup.batch)
                for k1 in range(dims[0])
            ])  # [N1, N2, N3, P1, B]
            batches = {
                "x": jnp.asarray(train.x[sel].transpose(3, 0, 1, 2, 4, 5)),
                "y": jnp.asarray(train.y[sel].transpose(3, 0, 1, 2, 4)),
            }
            st, _ = rf(st, batches)
            if not use_corr:
                st = st._replace(nus=jax.tree.map(jnp.zeros_like, st.nus))
            if (t + 1) % 5 == 0 or t == rounds - 1:
                acc = accuracy(apply, multilevel_global_model(st),
                               jnp.asarray(test.x), test.y)
                accs.append((t + 1, float(acc)))
        name = "mtgc3" if use_corr else "hfedavg3"
        for r, a in accs:
            rows.append([name, r, a])
    report("fig11_three_level", rows, ["algorithm", "round", "test_acc"])
    fin = {n: a for n, r, a in rows if r == rounds}
    print(f"[fig11] final: {fin} "
          f"{'OK' if fin.get('mtgc3', 0) >= fin.get('hfedavg3', 1) - 0.02 else 'VIOLATED'}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
