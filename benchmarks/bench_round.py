"""Round-engine microbenchmark: flat-buffer state vs the pytree path.

The repo's first perf-trajectory point. For every combination of
{tree, flat} x {mtgc, hfedavg} x {full, C=0.5 participation} this measures,
on the same model / data / schedule:

* post-compile wall-clock per global round (min over interleaved reps --
  tree and flat alternate rep-by-rep so background load hits both paths
  equally),
* trace+compile time of the first call (where per-leaf dispatch hurts most),
* local steps/s,

and cross-checks flat vs tree numerics (allclose, rtol 1e-5, after 3
rounds) before timing. Results land in ``benchmarks/results/BENCH_round.json``
(uploaded as a CI artifact by the non-blocking job) and as a printed table.

Workloads:

* ``ragged`` (default): the paper-style synthetic quadratic consensus
  objective over a ragged many-leaf parameter tree. This is the
  engine-bound regime -- hundreds of small tensors (LSTM gates, norm
  scales/biases, per-layer heads), where per-leaf dispatch in the
  aggregation/correction phases dominates and the flat path collapses it
  into whole-model ops. The aggregation-heavy quick schedule (E=4, H=2)
  mirrors the paper's fast-timescale regime.
* ``mlp``: the deep narrow ``deep_mlp`` classifier -- a model-bound control
  where the sequential grad chain (identical in both paths) dominates;
  expect the flat win to show up mostly in trace+compile time here.

The second perf-trajectory point rides along as the **horizon section**
(``benchmarks/results/BENCH_horizon.json``): whole-horizon compiled
training (``core/driver.py`` -- scan over T rounds in one donated jit with
on-device batch packing) against the per-round host loop it replaces, on
the quick CPU config of the fig/table benchmarks (``benchmarks/common``)
at T=30, min-of-reps post-compile, with driver/loop parity (rtol 1e-5)
asserted before timing and peak-memory numbers (device ``memory_stats()``
or host peak RSS) for the donated vs un-donated driver.

    PYTHONPATH=src python -m benchmarks.bench_round --quick
    PYTHONPATH=src python -m benchmarks.bench_round --full --model mlp
    PYTHONPATH=src python -m benchmarks.bench_round --quick --horizon-only
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import resource
import sys
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HFLConfig,
    as_tree,
    hfl_init,
    make_global_round,
    make_packer,
    make_round_step,
    pack_client_shards,
    run_rounds,
)
from repro.models.small import deep_mlp, make_loss

RESULTS = Path(__file__).parent / "results"
PARITY_ROUNDS = 3
HORIZON_TARGET_SPEEDUP = 1.5


def _host_peak_rss_bytes() -> int:
    """Peak RSS: VmHWM where available (resettable via ``_reset_peak_rss``),
    getrusage as the portable fallback."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    # ru_maxrss is kilobytes on Linux but bytes on macOS.
    scale = 1 if sys.platform == "darwin" else 1024
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * scale


def peak_memory() -> dict:
    """Peak device memory via ``memory_stats()``, host peak RSS as the
    CPU-safe fallback (the CPU backend reports no device stats)."""
    out = {"host_peak_rss_bytes": _host_peak_rss_bytes()}
    stats = jax.local_devices()[0].memory_stats()
    if stats:
        out["device"] = {k: int(v) for k, v in stats.items()
                         if isinstance(v, (int, np.integer))}
    return out


@dataclasses.dataclass
class BenchConfig:
    model: str = "ragged"     # "ragged" | "mlp"
    num_groups: int = 2
    clients_per_group: int = 2
    group_rounds: int = 4     # E
    local_steps: int = 2      # H
    # ragged: number of leaves and their size range
    num_blocks: int = 300
    min_block: int = 16
    max_block: int = 256
    # mlp: depth/width/batch
    depth: int = 48
    hidden: int = 32
    dim: int = 32
    num_classes: int = 10
    batch: int = 8
    reps: int = 9
    seed: int = 0

    @classmethod
    def full(cls, model: str = "ragged"):
        return cls(model=model, num_groups=4, clients_per_group=5,
                   num_blocks=600, depth=48, hidden=64, batch=16, reps=9)


def _ragged_problem(bc: BenchConfig):
    """Quadratic consensus objective over a ragged many-leaf tree:
    F_i(x) = 0.5 * ||a_i * x - b_i||^2 leafwise, heterogeneous (a, b)."""
    rng = np.random.default_rng(bc.seed)
    sizes = rng.integers(bc.min_block, bc.max_block, size=bc.num_blocks)
    params0 = {f"b{i:03d}": jnp.zeros((int(s),), jnp.float32)
               for i, s in enumerate(sizes)}

    def loss_fn(p, batch):
        return 0.5 * sum(jnp.sum((batch["a"][k] * v - batch["b"][k]) ** 2)
                         for k, v in p.items())

    lead = (bc.group_rounds, bc.local_steps, bc.num_groups,
            bc.clients_per_group)
    batches = {
        "a": {k: jnp.asarray(rng.normal(size=lead + v.shape) * 0.3 + 1.0,
                             jnp.float32) for k, v in params0.items()},
        "b": {k: jnp.asarray(rng.normal(size=lead + v.shape), jnp.float32)
              for k, v in params0.items()},
    }
    return params0, loss_fn, batches


def _mlp_problem(bc: BenchConfig):
    init, apply = deep_mlp(bc.num_classes, bc.dim, hidden=bc.hidden,
                           depth=bc.depth)
    loss_fn = make_loss(apply)
    params0 = init(jax.random.PRNGKey(bc.seed))
    rng = np.random.default_rng(bc.seed)
    shape = (bc.group_rounds, bc.local_steps, bc.num_groups,
             bc.clients_per_group, bc.batch)
    batches = {
        "x": jnp.asarray(rng.normal(size=shape + (bc.dim,)), jnp.float32),
        "y": jnp.asarray(rng.integers(0, bc.num_classes, size=shape)),
    }
    return params0, loss_fn, batches


def _cfg(bc: BenchConfig, algorithm: str, participation: float, flat: bool):
    return HFLConfig(
        num_groups=bc.num_groups, clients_per_group=bc.clients_per_group,
        local_steps=bc.local_steps, group_rounds=bc.group_rounds, lr=0.05,
        algorithm=algorithm, client_participation=participation,
        participation_mode="fixed", use_flat_state=flat,
    )


def _run_combo(params0, loss_fn, batches, cfg_tree, cfg_flat, reps: int):
    """One compile per path: parity check (PARITY_ROUNDS from fresh states,
    timing the first call as trace+compile), then alternating timed reps so
    background load hits tree and flat equally."""
    rfs, states, compile_s, finals = {}, {}, {}, {}
    for cfg in (cfg_tree, cfg_flat):
        flat = cfg.use_flat_state
        state = hfl_init(params0, cfg)
        # State donated: the loop never holds two copies of the replicas.
        rfs[flat] = jax.jit(make_global_round(loss_fn, cfg),
                            donate_argnums=0)
        t0 = time.perf_counter()
        state, m = rfs[flat](state, batches)
        jax.block_until_ready(m.loss)
        compile_s[flat] = time.perf_counter() - t0
        for _ in range(PARITY_ROUNDS - 1):
            state, _ = rfs[flat](state, batches)
        finals[flat] = as_tree(state.params)
        states[flat] = state
    errs, oks = [], []
    for t_leaf, f_leaf in zip(jax.tree.leaves(finals[False]),
                              jax.tree.leaves(finals[True])):
        errs.append(float(jnp.max(jnp.abs(t_leaf - f_leaf))))
        oks.append(bool(jnp.allclose(t_leaf, f_leaf, rtol=1e-5, atol=1e-6)))

    times = {False: [], True: []}
    for _ in range(reps):
        for flat in (False, True):
            t0 = time.perf_counter()
            states[flat], m = rfs[flat](states[flat], batches)
            jax.block_until_ready(m.loss)
            times[flat].append(time.perf_counter() - t0)
    steps = cfg_tree.group_rounds * cfg_tree.local_steps
    timed = {}
    for flat in (False, True):
        round_s = float(np.min(times[flat]))
        timed[flat] = {
            "round_ms": round_s * 1e3,
            "trace_compile_s": compile_s[flat],
            "steps_per_s": steps / round_s,
        }
    return timed, max(errs), all(oks)


# ------------------------------------------------------- horizon section


def _sampled_peak_rss(fn, interval: float = 0.001):
    """Run ``fn()`` while a daemon thread samples *current* RSS; returns
    (fn's result, peak sampled bytes).

    Lifetime watermarks (ru_maxrss / VmHWM) are monotone, so after the
    timed benchmark phases any earlier, higher peak would mask a
    measurement and read ~0; resetting them (Linux ``clear_refs``) needs
    privileges, and a fresh subprocess inherits the parent's resident
    pages across fork, so its watermark is poisoned too. Sampling current
    RSS is unprivileged and immune to history; the quantities measured
    here (parameter-sized buffer copies) stay live for whole rounds, far
    longer than the sampling interval.
    """
    stop = threading.Event()
    peak = [0]
    page = os.sysconf("SC_PAGESIZE")

    def read_rss() -> int:
        try:
            with open("/proc/self/statm") as f:
                return int(f.read().split()[1]) * page
        except OSError:       # non-Linux: lifetime watermark fallback
            return _host_peak_rss_bytes()

    def loop():
        while not stop.is_set():
            peak[0] = max(peak[0], read_rss())
            stop.wait(interval)

    t = threading.Thread(target=loop, daemon=True)
    t.start()
    try:
        result = fn()
    finally:
        stop.set()
        t.join()
    peak[0] = max(peak[0], read_rss())
    return result, peak[0]


def _donation_memory(T: int = 4, n: int = 3_000_000) -> dict:
    """Peak-memory cost of the driver with and without buffer donation.

    A deliberately state-heavy workload (single [G, K, n] flat leaf,
    E=H=1 scalar-batch quadratic) so the round-to-round state hand-off
    dominates: without donation every chunk dispatch holds input and output
    copies of the [G, K, n] buffers, with donation the outputs reuse the
    inputs. Each variant's peak is sampled live (``_sampled_peak_rss``),
    so the comparison is valid no matter what ran earlier in the process.
    """
    from repro.core import PackedBatches

    cfg = HFLConfig(num_groups=2, clients_per_group=2, local_steps=1,
                    group_rounds=1, lr=0.1, algorithm="mtgc",
                    use_flat_state=True)

    def loss_fn(p, b):
        return 0.5 * jnp.sum((b["a"] * p["w"] - b["b"]) ** 2)

    round_fn = make_global_round(loss_fn, cfg)
    rng = np.random.default_rng(0)
    arrays = {
        "a": jnp.asarray(rng.normal(size=(2, 2, 2, 1)).astype(np.float32) + 1.0),
        "b": jnp.asarray(rng.normal(size=(2, 2, 2, 1)).astype(np.float32)),
    }
    data = PackedBatches(arrays, jax.random.PRNGKey(0), 1, 1, None)

    # State size from the Packer segment table (params + z + dyn at
    # [G, K], y at [G]) -- the same arithmetic the population benchmark's
    # memory claims use, instead of hand-multiplied shapes.
    packer = make_packer({"w": jnp.zeros(n, jnp.float32)})
    state_bytes = (3 * packer.state_bytes((2, 2)) + packer.state_bytes((2,)))
    out = {"rounds": T, "state_mb": state_bytes / 1e6,
           "state_size_report": packer.size_report((2, 2))}
    for donate in (True, False):
        state = hfl_init({"w": jnp.zeros(n, jnp.float32)}, cfg)
        jax.block_until_ready(state)

        def run(state=state, donate=donate):
            out_state, _, _ = run_rounds(round_fn, state, data, T,
                                         donate=donate)
            jax.block_until_ready(out_state)
            return out_state

        _, peak = _sampled_peak_rss(run)
        mem = peak_memory()
        mem["sampled_peak_rss_bytes"] = int(peak)
        out["donate" if donate else "no_donate"] = mem
    saved = (out["no_donate"]["sampled_peak_rss_bytes"]
             - out["donate"]["sampled_peak_rss_bytes"])
    if "device" in out["no_donate"]:
        saved = max(saved, out["no_donate"]["device"].get("peak_bytes_in_use", 0)
                    - out["donate"]["device"].get("peak_bytes_in_use", 0))
    out["peak_bytes_saved_by_donation"] = int(saved)
    return out


def bench_horizon(T: int = 30, reps: int = 3) -> dict:
    """Whole-horizon compiled driver vs the per-round host loop it replaces.

    The workload is the fig/table benchmark path (``benchmarks/common``:
    MLP on the synthetic non-i.i.d. partition, G4 K5, T=30, accuracy
    evaluated every round as ``run_algorithm`` defaults to) on its
    fast-timescale quick CPU schedule -- E=2, H=2, batch 8, hidden 32 --
    the regime where the per-round loop's fixed costs (host batch packing,
    host->device transfer, dispatch, host-side eval sync) are comparable to
    the round's compute and the compiled horizon pays off. Compute-heavy
    schedules (E4 H5, batch 32) run the identical driver and simply see a
    smaller, compute-bound win. Three drivers of the same round function:

    * ``host_loop``   -- the pre-driver ``run_algorithm`` loop: numpy
      ``sample_round_batches`` + one (un-donated) jitted dispatch + host
      streaming-accuracy eval, per round.
    * ``device_loop`` -- per-round dispatch, but batches gathered on device
      from the packed dataset, the state donated (core.make_round_step),
      and eval as a second jitted dispatch.
    * ``driver`` / ``driver_chunked`` -- ``core.run_rounds``: scan over all
      T rounds (or chunks of 10) inside one donated jit, eval compiled in.

    device_loop and driver consume identical packed data + rng streams, so
    their parity (states, stacked metrics and eval accuracies, rtol 1e-5)
    is asserted before anything is timed; host_loop samples on the host so
    it is timed, not parity-gated. Timings are min-of-reps, interleaved,
    post-compile.
    """
    from benchmarks.common import BenchSetup
    from repro.data.partition import partition, sample_round_batches
    from repro.data.synthetic import make_classification, train_test_split
    from repro.models.small import accuracy, jit_accuracy, mlp

    setup = BenchSetup(group_rounds=2, local_steps=2, batch=8, hidden=32)
    G, K = setup.num_groups, setup.clients_per_group
    E, H = setup.group_rounds, setup.local_steps
    rng = np.random.default_rng(setup.seed)
    ds = make_classification(rng, num_samples=setup.samples,
                             num_classes=setup.num_classes, dim=setup.dim,
                             noise=1.0)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode=setup.mode, alpha=setup.alpha,
                    seed=setup.seed)
    init, apply = mlp(setup.num_classes, setup.dim, hidden=setup.hidden)
    loss_fn = make_loss(apply)
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=setup.lr, algorithm="mtgc")
    round_fn = make_global_round(loss_fn, cfg)
    params0 = init(jax.random.PRNGKey(setup.seed))
    data0 = pack_client_shards({"x": train.x, "y": train.y}, idx,
                               group_rounds=E, local_steps=H,
                               batch_size=setup.batch, shards=setup.shards,
                               rng=np.random.default_rng(setup.seed + 1),
                               key=jax.random.PRNGKey(setup.seed + 1))
    test_x = jnp.asarray(test.x)
    acc_of = jit_accuracy(apply, test_x, jnp.asarray(test.y))
    print(f"[bench_horizon] backend={jax.default_backend()} T={T} "
          f"G={G} K={K} E={E} H={H} batch={setup.batch} "
          f"shards={setup.shards} reps={reps}")

    def eval_fn(prev, state):
        params = as_tree(jax.tree.map(lambda v: v[0, 0], state.params))
        return {"acc": acc_of(params)}

    legacy_rf = jax.jit(round_fn)

    def run_host_loop():
        from repro.core import global_model
        state = hfl_init(params0, cfg)
        brng = np.random.default_rng(setup.seed + 1)
        hist = []
        for _ in range(T):
            b = sample_round_batches(train.x, train.y, idx, brng, E, H,
                                     setup.batch)
            state, m = legacy_rf(state, jax.tree.map(jnp.asarray, b))
            acc = accuracy(apply, global_model(state), test_x, test.y)
            hist.append((float(acc), float(np.mean(m.loss))))
        return state, hist

    step = make_round_step(round_fn, donate=True)
    jitted_eval = jax.jit(eval_fn)

    def run_device_loop(collect: bool = False):
        state, data = hfl_init(params0, cfg), data0
        mets, accs = [], []
        for _ in range(T):
            state, data, m = step(state, data)
            # The pre-round state was donated into the step dispatch; this
            # full-participation eval_fn only reads the post-round state,
            # so pass it for both slots rather than a consumed buffer.
            accs.append(float(jitted_eval(state, state)["acc"]))
            if collect:
                mets.append(m)
        jax.block_until_ready(state)
        return state, mets, accs

    def run_driver(chunk=None):
        state, _, hz = run_rounds(round_fn, hfl_init(params0, cfg), data0, T,
                                  chunk=chunk, eval_fn=eval_fn)
        jax.block_until_ready(state)
        return state, hz

    # ---- parity gate: device loop vs compiled driver, before timing ------
    state_l, mets, accs = run_device_loop(collect=True)
    state_d, hz = run_driver()
    stacked = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                           *mets)
    pairs = list(zip(jax.tree.leaves(as_tree(state_l.params)),
                     jax.tree.leaves(as_tree(state_d.params))))
    pairs += list(zip(jax.tree.leaves(stacked), jax.tree.leaves(hz.metrics)))
    pairs.append((np.asarray(accs, np.float32), hz.evals["acc"]))
    max_err = max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                        - jnp.asarray(b, jnp.float32))))
                  for a, b in pairs)
    parity_ok = all(np.allclose(np.asarray(a), np.asarray(b),
                                rtol=1e-5, atol=1e-6) for a, b in pairs)
    print(f"[bench_horizon] driver/loop parity "
          f"{'OK' if parity_ok else 'FAIL'} (max err {max_err:.2e})")
    if not parity_ok:
        raise SystemExit("driver/loop parity FAILED")

    # ---- timing: interleaved min-of-reps, everything compiled ------------
    variants = {
        "host_loop": run_host_loop,
        "device_loop": run_device_loop,
        "driver": lambda: run_driver(None),
        "driver_chunked": lambda: run_driver(10),
    }
    for fn in variants.values():   # warm every path (compile + remainder)
        fn()
    times = {name: [] for name in variants}
    for _ in range(reps):
        for name, fn in variants.items():
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)

    timed = {name: {"total_s": float(np.min(ts)),
                    "per_round_ms": float(np.min(ts)) / T * 1e3}
             for name, ts in times.items()}
    speedup_host = timed["host_loop"]["total_s"] / timed["driver"]["total_s"]
    speedup_loop = timed["device_loop"]["total_s"] / timed["driver"]["total_s"]
    for name, t in timed.items():
        print(f"  {name:14s} {t['total_s']*1e3:9.1f} ms "
              f"({t['per_round_ms']:6.2f} ms/round)")
    print(f"[bench_horizon] driver speedup: {speedup_host:.2f}x vs host loop, "
          f"{speedup_loop:.2f}x vs device per-round loop")

    mem_lifetime = peak_memory()
    mem = _donation_memory()
    print(f"[bench_horizon] donation saves "
          f"{mem['peak_bytes_saved_by_donation']/1e6:.1f} MB peak "
          f"(state {mem['state_mb']:.0f} MB)")

    out = {
        "backend": jax.default_backend(),
        "T": T,
        "reps": reps,
        "config": dataclasses.asdict(setup),
        "variants": timed,
        "speedup_vs_host_loop": speedup_host,
        "speedup_vs_device_loop": speedup_loop,
        "target_speedup": HORIZON_TARGET_SPEEDUP,
        "meets_target": speedup_host >= HORIZON_TARGET_SPEEDUP,
        "parity_ok": parity_ok,
        "parity_max_err": max_err,
        "donation_memory": mem,
        "memory": mem_lifetime,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_horizon.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_horizon] {'meets' if out['meets_target'] else 'MISSES'} "
          f"the >={HORIZON_TARGET_SPEEDUP}x target -> {path}")
    return out


def main(quick: bool = True, model: str = "ragged") -> dict:
    bc = BenchConfig(model=model) if quick else BenchConfig.full(model)
    params0, loss_fn, batches = (
        _ragged_problem(bc) if bc.model == "ragged" else _mlp_problem(bc))
    n_leaves = len(jax.tree.leaves(params0))
    n_params = sum(x.size for x in jax.tree.leaves(params0))
    print(f"[bench_round] backend={jax.default_backend()} model={bc.model} "
          f"leaves={n_leaves} params={n_params} "
          f"G={bc.num_groups} K={bc.clients_per_group} "
          f"E={bc.group_rounds} H={bc.local_steps}")

    combos = []
    for algorithm in ("mtgc", "hfedavg"):
        for participation in (1.0, 0.5):
            cfg_t = _cfg(bc, algorithm, participation, flat=False)
            cfg_f = _cfg(bc, algorithm, participation, flat=True)
            timed, max_err, parity_ok = _run_combo(
                params0, loss_fn, batches, cfg_t, cfg_f, bc.reps)
            tree, flat = timed[False], timed[True]
            speedup = tree["round_ms"] / flat["round_ms"]
            trace_speedup = tree["trace_compile_s"] / flat["trace_compile_s"]
            combos.append({
                "algorithm": algorithm,
                "participation": participation,
                "tree": tree,
                "flat": flat,
                "speedup": speedup,
                "trace_compile_speedup": trace_speedup,
                "parity_max_err": max_err,
                "parity_ok": parity_ok,
            })
            print(f"  {algorithm:8s} C={participation:3.1f}: "
                  f"tree {tree['round_ms']:8.2f} ms  "
                  f"flat {flat['round_ms']:8.2f} ms  "
                  f"speedup {speedup:4.2f}x  "
                  f"(trace+compile {tree['trace_compile_s']:.1f}s -> "
                  f"{flat['trace_compile_s']:.1f}s, {trace_speedup:.1f}x)  "
                  f"parity {'OK' if parity_ok else 'FAIL'} "
                  f"(max err {max_err:.2e})")

    speedups = [c["speedup"] for c in combos]
    # Replica state footprint from the segment table: what [G, K] copies
    # of this model cost, reported next to the observational RSS numbers.
    lead = (bc.num_groups, bc.clients_per_group)
    out = {
        "backend": jax.default_backend(),
        "config": dataclasses.asdict(bc),
        "model": {"kind": bc.model, "leaves": n_leaves, "params": n_params,
                  "state_size_report": make_packer(params0).size_report(lead)},
        "parity_rounds": PARITY_ROUNDS,
        "combos": combos,
        "min_speedup": min(speedups),
        "geomean_speedup": float(np.exp(np.mean(np.log(speedups)))),
        "all_parity_ok": all(c["parity_ok"] for c in combos),
        "memory": peak_memory(),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_round.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_round] min speedup {out['min_speedup']:.2f}x, "
          f"geomean {out['geomean_speedup']:.2f}x -> {path}")
    if not out["all_parity_ok"]:
        raise SystemExit("flat/tree parity FAILED")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--quick", action="store_true", default=True,
                       help="CI-sized config (default)")
    group.add_argument("--full", action="store_true",
                       help="larger topology / batches")
    ap.add_argument("--model", choices=("ragged", "mlp"), default="ragged")
    ap.add_argument("--no-horizon", action="store_true",
                    help="skip the whole-horizon driver benchmark")
    ap.add_argument("--horizon-only", action="store_true",
                    help="run only the whole-horizon driver benchmark")
    args = ap.parse_args()
    if not args.horizon_only:
        main(quick=not args.full, model=args.model)
    if not args.no_horizon:
        bench_horizon()
