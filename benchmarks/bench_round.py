"""Round-engine microbenchmark: flat-buffer state vs the pytree path.

The repo's first perf-trajectory point. For every combination of
{tree, flat} x {mtgc, hfedavg} x {full, C=0.5 participation} this measures,
on the same model / data / schedule:

* post-compile wall-clock per global round (min over interleaved reps --
  tree and flat alternate rep-by-rep so background load hits both paths
  equally),
* trace+compile time of the first call (where per-leaf dispatch hurts most),
* local steps/s,

and cross-checks flat vs tree numerics (allclose, rtol 1e-5, after 3
rounds) before timing. Results land in ``benchmarks/results/BENCH_round.json``
(uploaded as a CI artifact by the non-blocking job) and as a printed table.

Workloads:

* ``ragged`` (default): the paper-style synthetic quadratic consensus
  objective over a ragged many-leaf parameter tree. This is the
  engine-bound regime -- hundreds of small tensors (LSTM gates, norm
  scales/biases, per-layer heads), where per-leaf dispatch in the
  aggregation/correction phases dominates and the flat path collapses it
  into whole-model ops. The aggregation-heavy quick schedule (E=4, H=2)
  mirrors the paper's fast-timescale regime.
* ``mlp``: the deep narrow ``deep_mlp`` classifier -- a model-bound control
  where the sequential grad chain (identical in both paths) dominates;
  expect the flat win to show up mostly in trace+compile time here.

    PYTHONPATH=src python -m benchmarks.bench_round --quick
    PYTHONPATH=src python -m benchmarks.bench_round --full --model mlp
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HFLConfig, as_tree, hfl_init, make_global_round
from repro.models.small import deep_mlp, make_loss

RESULTS = Path(__file__).parent / "results"
PARITY_ROUNDS = 3


@dataclasses.dataclass
class BenchConfig:
    model: str = "ragged"     # "ragged" | "mlp"
    num_groups: int = 2
    clients_per_group: int = 2
    group_rounds: int = 4     # E
    local_steps: int = 2      # H
    # ragged: number of leaves and their size range
    num_blocks: int = 300
    min_block: int = 16
    max_block: int = 256
    # mlp: depth/width/batch
    depth: int = 48
    hidden: int = 32
    dim: int = 32
    num_classes: int = 10
    batch: int = 8
    reps: int = 9
    seed: int = 0

    @classmethod
    def full(cls, model: str = "ragged"):
        return cls(model=model, num_groups=4, clients_per_group=5,
                   num_blocks=600, depth=48, hidden=64, batch=16, reps=9)


def _ragged_problem(bc: BenchConfig):
    """Quadratic consensus objective over a ragged many-leaf tree:
    F_i(x) = 0.5 * ||a_i * x - b_i||^2 leafwise, heterogeneous (a, b)."""
    rng = np.random.default_rng(bc.seed)
    sizes = rng.integers(bc.min_block, bc.max_block, size=bc.num_blocks)
    params0 = {f"b{i:03d}": jnp.zeros((int(s),), jnp.float32)
               for i, s in enumerate(sizes)}

    def loss_fn(p, batch):
        return 0.5 * sum(jnp.sum((batch["a"][k] * v - batch["b"][k]) ** 2)
                         for k, v in p.items())

    lead = (bc.group_rounds, bc.local_steps, bc.num_groups,
            bc.clients_per_group)
    batches = {
        "a": {k: jnp.asarray(rng.normal(size=lead + v.shape) * 0.3 + 1.0,
                             jnp.float32) for k, v in params0.items()},
        "b": {k: jnp.asarray(rng.normal(size=lead + v.shape), jnp.float32)
              for k, v in params0.items()},
    }
    return params0, loss_fn, batches


def _mlp_problem(bc: BenchConfig):
    init, apply = deep_mlp(bc.num_classes, bc.dim, hidden=bc.hidden,
                           depth=bc.depth)
    loss_fn = make_loss(apply)
    params0 = init(jax.random.PRNGKey(bc.seed))
    rng = np.random.default_rng(bc.seed)
    shape = (bc.group_rounds, bc.local_steps, bc.num_groups,
             bc.clients_per_group, bc.batch)
    batches = {
        "x": jnp.asarray(rng.normal(size=shape + (bc.dim,)), jnp.float32),
        "y": jnp.asarray(rng.integers(0, bc.num_classes, size=shape)),
    }
    return params0, loss_fn, batches


def _cfg(bc: BenchConfig, algorithm: str, participation: float, flat: bool):
    return HFLConfig(
        num_groups=bc.num_groups, clients_per_group=bc.clients_per_group,
        local_steps=bc.local_steps, group_rounds=bc.group_rounds, lr=0.05,
        algorithm=algorithm, client_participation=participation,
        participation_mode="fixed", use_flat_state=flat,
    )


def _run_combo(params0, loss_fn, batches, cfg_tree, cfg_flat, reps: int):
    """One compile per path: parity check (PARITY_ROUNDS from fresh states,
    timing the first call as trace+compile), then alternating timed reps so
    background load hits tree and flat equally."""
    rfs, states, compile_s, finals = {}, {}, {}, {}
    for cfg in (cfg_tree, cfg_flat):
        flat = cfg.use_flat_state
        state = hfl_init(params0, cfg)
        rfs[flat] = jax.jit(make_global_round(loss_fn, cfg))
        t0 = time.perf_counter()
        state, m = rfs[flat](state, batches)
        jax.block_until_ready(m.loss)
        compile_s[flat] = time.perf_counter() - t0
        for _ in range(PARITY_ROUNDS - 1):
            state, _ = rfs[flat](state, batches)
        finals[flat] = as_tree(state.params)
        states[flat] = state
    errs, oks = [], []
    for t_leaf, f_leaf in zip(jax.tree.leaves(finals[False]),
                              jax.tree.leaves(finals[True])):
        errs.append(float(jnp.max(jnp.abs(t_leaf - f_leaf))))
        oks.append(bool(jnp.allclose(t_leaf, f_leaf, rtol=1e-5, atol=1e-6)))

    times = {False: [], True: []}
    for _ in range(reps):
        for flat in (False, True):
            t0 = time.perf_counter()
            states[flat], m = rfs[flat](states[flat], batches)
            jax.block_until_ready(m.loss)
            times[flat].append(time.perf_counter() - t0)
    steps = cfg_tree.group_rounds * cfg_tree.local_steps
    timed = {}
    for flat in (False, True):
        round_s = float(np.min(times[flat]))
        timed[flat] = {
            "round_ms": round_s * 1e3,
            "trace_compile_s": compile_s[flat],
            "steps_per_s": steps / round_s,
        }
    return timed, max(errs), all(oks)


def main(quick: bool = True, model: str = "ragged") -> dict:
    bc = BenchConfig(model=model) if quick else BenchConfig.full(model)
    params0, loss_fn, batches = (
        _ragged_problem(bc) if bc.model == "ragged" else _mlp_problem(bc))
    n_leaves = len(jax.tree.leaves(params0))
    n_params = sum(x.size for x in jax.tree.leaves(params0))
    print(f"[bench_round] backend={jax.default_backend()} model={bc.model} "
          f"leaves={n_leaves} params={n_params} "
          f"G={bc.num_groups} K={bc.clients_per_group} "
          f"E={bc.group_rounds} H={bc.local_steps}")

    combos = []
    for algorithm in ("mtgc", "hfedavg"):
        for participation in (1.0, 0.5):
            cfg_t = _cfg(bc, algorithm, participation, flat=False)
            cfg_f = _cfg(bc, algorithm, participation, flat=True)
            timed, max_err, parity_ok = _run_combo(
                params0, loss_fn, batches, cfg_t, cfg_f, bc.reps)
            tree, flat = timed[False], timed[True]
            speedup = tree["round_ms"] / flat["round_ms"]
            trace_speedup = tree["trace_compile_s"] / flat["trace_compile_s"]
            combos.append({
                "algorithm": algorithm,
                "participation": participation,
                "tree": tree,
                "flat": flat,
                "speedup": speedup,
                "trace_compile_speedup": trace_speedup,
                "parity_max_err": max_err,
                "parity_ok": parity_ok,
            })
            print(f"  {algorithm:8s} C={participation:3.1f}: "
                  f"tree {tree['round_ms']:8.2f} ms  "
                  f"flat {flat['round_ms']:8.2f} ms  "
                  f"speedup {speedup:4.2f}x  "
                  f"(trace+compile {tree['trace_compile_s']:.1f}s -> "
                  f"{flat['trace_compile_s']:.1f}s, {trace_speedup:.1f}x)  "
                  f"parity {'OK' if parity_ok else 'FAIL'} "
                  f"(max err {max_err:.2e})")

    speedups = [c["speedup"] for c in combos]
    out = {
        "backend": jax.default_backend(),
        "config": dataclasses.asdict(bc),
        "model": {"kind": bc.model, "leaves": n_leaves, "params": n_params},
        "parity_rounds": PARITY_ROUNDS,
        "combos": combos,
        "min_speedup": min(speedups),
        "geomean_speedup": float(np.exp(np.mean(np.log(speedups)))),
        "all_parity_ok": all(c["parity_ok"] for c in combos),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_round.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_round] min speedup {out['min_speedup']:.2f}x, "
          f"geomean {out['geomean_speedup']:.2f}x -> {path}")
    if not out["all_parity_ok"]:
        raise SystemExit("flat/tree parity FAILED")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--quick", action="store_true", default=True,
                       help="CI-sized config (default)")
    group.add_argument("--full", action="store_true",
                       help="larger topology / batches")
    ap.add_argument("--model", choices=("ragged", "mlp"), default="ragged")
    args = ap.parse_args()
    main(quick=not args.full, model=args.model)
