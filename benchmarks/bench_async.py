"""Async group rounds: convergence vs staleness MC bench -> BENCH_async.json.

A Monte-Carlo sweep over the stale-merge policies activated by PR 6
(``ExperimentSpec.staleness``, per-group ``RoundSchedule.group_rounds``):
R independent heterogeneous-quadratic HFL instances -- same topology,
different per-client curvatures/optima -- run simultaneously (the engine
round function vmapped over the instance axis), with one straggler group
at E_g = 1 while every other group runs ``E = s + 1`` rounds per window.
Under an async policy the straggler then reports every ``s + 1`` windows,
``tau = s`` global aggregations stale.

For each staleness level s in {1, 2, 4} and each policy the harness
tracks the mean distance of the global model to the instance's exact
joint optimum over T windows, read out as the average over the last
report cycle (so the report-phase oscillation of the async policies
does not alias into the final number):

* ``"sync"``: the zero-staleness baseline -- the straggler reports its
  single round every window (heterogeneous work, no late reports).
* ``"naive"``: stale reports merge at full weight -- the control the
  staleness-aware policies are measured against.
* ``"discount"``: stale reports down-weighted by ``1 / (1 + tau)``.
* ``"delay_compensated"``: reports shifted by the global progress the
  group missed (``xbar_g + (glob - snap_g)``).

The instances are built so every group's curvature-weighted optimum is
*identical* (client heterogeneity only): with heterogeneous group
optima a straggler's reports also carry its group's data into the
global model, and that representation effect -- which full-weight naive
merging preserves best -- swamps the staleness damage the policies
differ on. Equalizing the group optima isolates the stale-merge
handling as the only differentiator.

Claims gated into the artifact: at staleness >= 2 both staleness-aware
policies converge markedly (>= 1.25x) closer to the optimum than naive
stale aggregation, and naive's gap to the zero-staleness sync baseline
grows monotonically with s (raw cross-s distances are not comparable:
a window at staleness s carries s + 1 fast-group rounds of work).
Everything is built through ``repro.api.build(spec)`` -- the first
capability bench with no constructor-stack plumbing.

    PYTHONPATH=src python -m benchmarks.bench_async
    PYTHONPATH=src python -m benchmarks.bench_async --full
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import api

RESULTS = Path(__file__).parent / "results"

# Topology of the MC study: G groups of K heterogeneous quadratic
# clients in D dims. The learning rate sits in the weak-contraction
# regime (lr * curvature * H * e_pad well below 1): a straggler's cycle
# then ends anchor-dominated -- mostly the stale global model it
# downloaded, not its locally-converged optimum -- which is the regime
# where merging stale reports actually costs (a strongly-contracted
# stale report is nearly fresh information and naive merging is fine).
MC_G, MC_K, MC_D = 3, 8, 6
MC_H = 2          # local steps per group round
MC_LR = 0.05
STALENESS_LEVELS = (1, 2, 4)
POLICIES = ("sync", "naive", "discount", "delay_compensated")


def _quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def _mc_instances(R: int, seed: int = 0):
    """R independent problem instances: heterogeneous per-client
    quadratics whose *group-level* optima are all equal (see the module
    docstring), plus each instance's exact joint optimum.

    Returns ``(a [R,G,K,D], b [R,G,K,D], w_opt [R,D])``; client (g, k)
    of instance r minimizes ``0.5 * sum((a * w - b)**2)`` at
    ``w = targ[r, g, k]`` with curvature ``a**2 = curv[r, g, k]``.
    """
    rng = np.random.default_rng(seed)
    curv = rng.normal(size=(R, MC_G, MC_K, MC_D)) ** 2 * 0.5 + 0.3
    targ = rng.normal(size=(R, MC_G, MC_K, MC_D))
    # Center each group's curvature-weighted optimum, then shift all of
    # them to one shared per-instance target: every group optimum (and
    # the joint optimum) coincides, so no policy gains by representing
    # the straggler's data more or less in the global mean.
    gmean = ((curv * targ).sum(axis=2, keepdims=True)
             / curv.sum(axis=2, keepdims=True))
    targ = targ - gmean + rng.normal(size=(R, 1, 1, MC_D)) * 2.0
    a = np.sqrt(curv)
    b = a * targ
    w_opt = (curv * targ).sum(axis=(1, 2)) / curv.sum(axis=(1, 2))
    return (a.astype(np.float32), b.astype(np.float32),
            w_opt.astype(np.float32))


def _batches(a, b, e_pad):
    """[R, e_pad, H, G, K, D] deterministic per-round batches (the same
    data every window, so convergence differences are pure policy)."""
    R = a.shape[0]
    shape = (R, e_pad, MC_H, MC_G, MC_K, MC_D)
    return {
        "a": jnp.asarray(np.broadcast_to(a[:, None, None], shape)),
        "b": jnp.asarray(np.broadcast_to(b[:, None, None], shape)),
    }


def async_convergence(policy: str, s: int, *, R: int, T: int,
                      seed: int = 0) -> np.ndarray:
    """[T] mean distance of the global model to the joint optimum after
    each window.

    One straggler group at E_g = 1, the rest at E = s + 1; under an
    async policy the straggler's report cadence is s + 1 windows
    (staleness tau = s). All policies at a given s see identical data
    and an identical padded inner loop -- only the stale-merge differs.
    """
    e_pad = s + 1
    group_rounds = (e_pad,) * (MC_G - 1) + (1,)
    spec = api.ExperimentSpec(
        levels=(MC_G, MC_K), algorithm="mtgc", lr=MC_LR,
        state_layout="tree",
        schedule=api.RoundSchedule(group_rounds=group_rounds,
                                   local_steps=MC_H),
        staleness=policy)
    engine = api.build(spec, _quad_loss)
    fg = spec.staleness_plan().fastest_group

    a, b, w_opt = _mc_instances(R, seed)
    batches = _batches(a, b, e_pad)
    params0 = {"w": jnp.zeros(MC_D)}
    states = jax.vmap(lambda _: engine.init(params0))(jnp.arange(R))
    round_fn = jax.jit(jax.vmap(engine.round_fn))

    dists = []
    for _ in range(T):
        states, _ = round_fn(states, batches)
        # A cadence-1 group's replicas hold the fresh global model.
        glob = np.asarray(states.params["w"])[:, fg, 0]
        dists.append(float(np.linalg.norm(glob - w_opt, axis=-1).mean()))
    return np.asarray(dists)


def main(quick: bool = True) -> dict:
    R = 256 if quick else 1024
    T = 24
    out = {
        "config": {"G": MC_G, "K": MC_K, "D": MC_D, "H": MC_H, "lr": MC_LR,
                   "algorithm": "mtgc", "R": R, "T": T,
                   "staleness_levels": list(STALENESS_LEVELS),
                   "policies": list(POLICIES),
                   "straggler": "last group at E_g=1, others at E=s+1",
                   "readout": "mean dist over the last report cycle"},
        "sweep": {},
    }
    for s in STALENESS_LEVELS:
        row = {}
        for policy in POLICIES:
            d = async_convergence(policy, s, R=R, T=T)
            row[policy] = {"dist": [round(float(x), 6) for x in d],
                           "final": float(d[-(s + 1):].mean())}
        out["sweep"][f"staleness_{s}"] = row

    finals = {(s, p): out["sweep"][f"staleness_{s}"][p]["final"]
              for s in STALENESS_LEVELS for p in POLICIES}
    out["claims"] = {
        # The tentpole gate: staleness-aware merging beats naive stale
        # aggregation once reports are >= 2 windows old, with margin.
        "discount_beats_naive_at_staleness_ge2": bool(all(
            finals[(s, "discount")] < 0.8 * finals[(s, "naive")]
            for s in STALENESS_LEVELS if s >= 2)),
        "delay_compensated_beats_naive_at_staleness_ge2": bool(all(
            finals[(s, "delay_compensated")] < 0.8 * finals[(s, "naive")]
            for s in STALENESS_LEVELS if s >= 2)),
        # Staleness actually hurts the naive control (the sweep is not
        # measuring noise): its gap to the zero-staleness sync baseline
        # widens monotonically with s.
        "naive_gap_to_sync_grows_with_staleness": bool(all(
            finals[(s0, "naive")] - finals[(s0, "sync")]
            < finals[(s1, "naive")] - finals[(s1, "sync")]
            for s0, s1 in zip(STALENESS_LEVELS, STALENESS_LEVELS[1:]))),
    }

    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_async.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_async] -> {path}")
    for s in STALENESS_LEVELS:
        row = out["sweep"][f"staleness_{s}"]
        print(f"  staleness={s}: " + "  ".join(
            f"{p}={row[p]['final']:.4f}" for p in POLICIES))
    print(f"[bench_async] claims: {out['claims']}")
    return out


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
