"""Shared harness for the paper-experiment benchmarks.

Each ``fig*/table*`` module reproduces one paper table/figure on the
synthetic stand-in datasets (offline container; see DESIGN.md §2 change 3)
with the same partition protocol, algorithms and schedule as the paper.
``--quick`` (the default under ``python -m benchmarks.run``) shrinks the
topology/rounds so the whole suite finishes on a 1-core CPU; ``--full``
uses the paper's 100-client/10-group setting.

Training runs through the compiled horizon driver (``core/driver.py``):
the dataset is packed per client and uploaded once, every round's batches
are gathered on device, T rounds run as chunked donated scans, and test
accuracy is evaluated inside the compiled program at the ``eval_every``
cadence -- so every fig/table module inherits the whole-horizon speedup
with no host work in the round loop (host batch packing is gone entirely,
including the packs the old loop wasted on participation-masked clients).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.core import as_tree
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp

RESULTS = Path(__file__).parent / "results"


@dataclasses.dataclass
class BenchSetup:
    num_groups: int = 4
    clients_per_group: int = 5
    group_rounds: int = 4      # E
    local_steps: int = 5       # H
    rounds: int = 30           # T
    lr: float = 0.1
    batch: int = 32
    dim: int = 32
    num_classes: int = 10
    samples: int = 6000
    alpha: float = 0.1
    mode: str = "both_noniid"
    seed: int = 0
    hidden: int = 64
    shards: int = 16           # packed batch blocks per client (driver)
    chunk: int | None = None   # rounds per compiled dispatch (None = all)

    @classmethod
    def paper(cls):
        """Sec. 5.1 scale: 100 clients over 10 groups, batch 50, lr 0.1."""
        return cls(num_groups=10, clients_per_group=10, group_rounds=10,
                   local_steps=20, rounds=100, batch=50, dim=64,
                   samples=20000, hidden=200)


def run_algorithm(setup: BenchSetup, algorithm: str, *, eval_every: int = 1,
                  mode: str | None = None, alpha: float | None = None,
                  E: int | None = None, H: int | None = None,
                  G: int | None = None, K: int | None = None,
                  seed: int | None = None, rounds: int | None = None,
                  client_participation: float = 1.0,
                  group_participation: float = 1.0,
                  participation_mode: str = "uniform",
                  participation_weighting: str = "none",
                  compression=None,
                  chunk: int | None = None):
    """Train one algorithm; returns dict(acc=[...], loss=[...], rounds=[...],
    comm_bytes=[...]) -- ``comm_bytes`` is the engine-measured upload bytes
    per round (every round, not just eval rounds), so cost axes come from
    the wire model, not hand-written per-algorithm multiples.

    Construction goes through the unified front door (``repro.api``): one
    ``ExperimentSpec`` declares the experiment, ``build``/``fit`` compose
    the engine with the compiled horizon driver -- batches gathered on
    device from the once-uploaded packed partition, state buffers donated
    round to round, accuracy evaluated inside the compiled scan. Under
    partial participation the evaluated replica is the first active client
    of the round (re-derived from the pre-round ``state.rng``, exactly the
    masks the engine uses); on the rare empty round under 'uniform'
    sampling this falls back to replica (0, 0).
    """
    G = G or setup.num_groups
    K = K or setup.clients_per_group
    E = E or setup.group_rounds
    H = H or setup.local_steps
    seed = setup.seed if seed is None else seed
    rounds = rounds or setup.rounds
    rng = np.random.default_rng(seed)

    ds = make_classification(rng, num_samples=setup.samples,
                             num_classes=setup.num_classes, dim=setup.dim,
                             noise=1.0)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode=mode or setup.mode,
                    alpha=alpha if alpha is not None else setup.alpha,
                    seed=seed)

    init, apply = mlp(setup.num_classes, setup.dim, hidden=setup.hidden)
    loss_fn = make_loss(apply)
    spec = ExperimentSpec(
        levels=(G, K),
        schedule=RoundSchedule(group_rounds=E, local_steps=H),
        algorithm=algorithm, lr=setup.lr,
        prox_mu=0.01 if algorithm == "fedprox" else 0.0,
        feddyn_alpha=0.1 if algorithm == "feddyn" else 0.0,
        client_participation=client_participation,
        group_participation=group_participation,
        participation_mode=participation_mode,
        participation_weighting=participation_weighting,
        compression=compression)
    engine = build(spec, loss_fn)
    data = engine.pack_arrays({"x": train.x, "y": train.y}, idx,
                              batch_size=setup.batch, shards=setup.shards,
                              rng=rng, key=jax.random.PRNGKey(seed + 1))
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    def eval_fn(prev, state):
        if spec.full_participation:
            params = engine.global_model(state)
        else:
            # Frozen replicas hold stale params: evaluate the first client
            # that received this round's dissemination (argmax of the
            # round's mask, re-derived from the pre-round rng).
            cmask = engine.participation_masks(prev.rng)[0].client
            i = jnp.argmax(cmask.reshape(-1))
            params = as_tree(jax.tree.map(lambda v: v[i // K, i % K],
                                          state.params))
        return {"acc": acc_of(params)}

    state, hz = fit(engine, data, rounds,
                    params=init(jax.random.PRNGKey(seed)),
                    chunk=chunk or setup.chunk,
                    eval_every=eval_every, eval_fn=eval_fn)
    loss_t = np.asarray(hz.metrics.loss).reshape(rounds, -1).mean(axis=1)
    comm_t = np.asarray(hz.metrics.comm_bytes, dtype=np.float64).reshape(-1)
    return {"round": [int(r) for r in hz.eval_rounds],
            "acc": [float(a) for a in hz.evals["acc"]],
            "loss": [float(loss_t[r - 1]) for r in hz.eval_rounds],
            "comm_bytes": [float(b) for b in comm_t]}


def rounds_to_accuracy(hist: dict, target: float) -> float:
    for r, a in zip(hist["round"], hist["acc"]):
        if a >= target:
            return r
    return float("inf")


def write_csv(name: str, header: list[str], rows: list[list]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(x) for x in row) + "\n")
    return path


def report(name: str, rows: list[list], header: list[str]):
    path = write_csv(name, header, rows)
    print(f"[{name}] -> {path}")
    print(",".join(header))
    for row in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x)
                       for x in row))
