"""Shared harness for the paper-experiment benchmarks.

Each ``fig*/table*`` module reproduces one paper table/figure on the
synthetic stand-in datasets (offline container; see DESIGN.md §2 change 3)
with the same partition protocol, algorithms and schedule as the paper.
``--quick`` (the default under ``python -m benchmarks.run``) shrinks the
topology/rounds so the whole suite finishes on a 1-core CPU; ``--full``
uses the paper's 100-client/10-group setting.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HFLConfig, as_tree, global_model, hfl_init, make_global_round, round_masks
from repro.data.partition import partition, sample_round_batches
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp

RESULTS = Path(__file__).parent / "results"


@dataclasses.dataclass
class BenchSetup:
    num_groups: int = 4
    clients_per_group: int = 5
    group_rounds: int = 4      # E
    local_steps: int = 5       # H
    rounds: int = 30           # T
    lr: float = 0.1
    batch: int = 32
    dim: int = 32
    num_classes: int = 10
    samples: int = 6000
    alpha: float = 0.1
    mode: str = "both_noniid"
    seed: int = 0
    hidden: int = 64

    @classmethod
    def paper(cls):
        """Sec. 5.1 scale: 100 clients over 10 groups, batch 50, lr 0.1."""
        return cls(num_groups=10, clients_per_group=10, group_rounds=10,
                   local_steps=20, rounds=100, batch=50, dim=64,
                   samples=20000, hidden=200)


def run_algorithm(setup: BenchSetup, algorithm: str, *, eval_every: int = 1,
                  mode: str | None = None, alpha: float | None = None,
                  E: int | None = None, H: int | None = None,
                  G: int | None = None, K: int | None = None,
                  seed: int | None = None, rounds: int | None = None,
                  client_participation: float = 1.0,
                  group_participation: float = 1.0,
                  participation_mode: str = "uniform"):
    """Train one algorithm; returns dict(acc=[...], loss=[...], rounds=[...])."""
    G = G or setup.num_groups
    K = K or setup.clients_per_group
    E = E or setup.group_rounds
    H = H or setup.local_steps
    seed = setup.seed if seed is None else seed
    rounds = rounds or setup.rounds
    rng = np.random.default_rng(seed)

    ds = make_classification(rng, num_samples=setup.samples,
                             num_classes=setup.num_classes, dim=setup.dim,
                             noise=1.0)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode=mode or setup.mode,
                    alpha=alpha if alpha is not None else setup.alpha,
                    seed=seed)

    init, apply = mlp(setup.num_classes, setup.dim, hidden=setup.hidden)
    loss_fn = make_loss(apply)
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=setup.lr, algorithm=algorithm,
                    prox_mu=0.01, feddyn_alpha=0.1,
                    client_participation=client_participation,
                    group_participation=group_participation,
                    participation_mode=participation_mode)
    state = hfl_init(init(jax.random.PRNGKey(seed)), cfg)
    round_fn = jax.jit(make_global_round(loss_fn, cfg))

    hist = {"round": [], "acc": [], "loss": []}
    # Frozen replicas hold stale params: evaluate a client that received the
    # most recent dissemination (on an empty round, nobody received and the
    # last recipient still holds the current global model).
    eval_gk = (0, 0)
    for t in range(rounds):
        # Under partial participation, mirror the engine's masks on the host
        # and skip packing batches for the clients sitting this round out.
        client_mask = (None if cfg.full_participation
                       else np.asarray(round_masks(state.rng, cfg)[0].client))
        batches = sample_round_batches(train.x, train.y, idx, rng, E, H,
                                       setup.batch, client_mask=client_mask)
        state, metrics = round_fn(state, jax.tree.map(jnp.asarray, batches))
        if client_mask is not None and client_mask.any():
            eval_gk = tuple(np.argwhere(client_mask > 0)[0])
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            if client_mask is None:
                params_eval = global_model(state)
            else:
                g_a, k_a = eval_gk
                params_eval = as_tree(
                    jax.tree.map(lambda x: x[g_a, k_a], state.params))
            acc = accuracy(apply, params_eval, jnp.asarray(test.x), test.y)
            hist["round"].append(t + 1)
            hist["acc"].append(float(acc))
            hist["loss"].append(float(np.mean(metrics.loss)))
    return hist


def rounds_to_accuracy(hist: dict, target: float) -> float:
    for r, a in zip(hist["round"], hist["acc"]):
        if a >= target:
            return r
    return float("inf")


def write_csv(name: str, header: list[str], rows: list[list]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(x) for x in row) + "\n")
    return path


def report(name: str, rows: list[list], header: list[str]):
    path = write_csv(name, header, rows)
    print(f"[{name}] -> {path}")
    print(",".join(header))
    for row in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x)
                       for x in row))
