"""Paper Fig. 4: correction-level ablation (none / local z / group y / both)
across the three data-distribution settings. Expected orderings:
  group_iid & client non-iid -> local correction > group correction
  group non-iid & client iid -> group correction > local correction
  both non-iid               -> MTGC (both) best everywhere."""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, run_algorithm

ALGOS = ("hfedavg", "local_corr", "group_corr", "mtgc")
MODES = ("group_iid", "client_iid", "both_noniid")


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    rows, final = [], {}
    for mode in MODES:
        for algo in ALGOS:
            hist = run_algorithm(setup, algo, mode=mode, eval_every=2)
            final[(mode, algo)] = hist["acc"][-1]
            for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
                rows.append([mode, algo, r, a, l])
    report("fig4_corrections", rows,
           ["mode", "algorithm", "round", "test_acc", "train_loss"])
    print("[fig4] final accuracy grid:")
    for mode in MODES:
        line = "  " + mode.ljust(14) + " ".join(
            f"{algo}={final[(mode, algo)]:.4f}" for algo in ALGOS)
        print(line)
    ok1 = final[("group_iid", "local_corr")] >= final[("group_iid", "group_corr")] - 0.02
    ok2 = final[("client_iid", "group_corr")] >= final[("client_iid", "local_corr")] - 0.02
    ok3 = all(final[(m, "mtgc")] >= max(final[(m, a)] for a in ALGOS) - 0.02
              for m in MODES)
    print(f"[fig4] claim checks: local-dominates-when-client-noniid={ok1} "
          f"group-dominates-when-group-noniid={ok2} mtgc-best-or-tied={ok3}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
