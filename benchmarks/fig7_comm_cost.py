"""Paper Fig. 7a: testing accuracy versus client-side communication cost.

MTGC's per-global-round client traffic is (E+1)/E model transmissions per
group round pair (the extra one initializes z and broadcasts y, App. B);
HFedAvg pays E. We charge each algorithm its own bill and compare accuracy
at equal bytes."""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, run_algorithm

# uplink+downlink model-multiples per global round, per client
COST_PER_ROUND = {
    "hfedavg": lambda E: 2.0 * E,          # E group-agg up/down pairs
    "local_corr": lambda E: 2.0 * E + 1.0, # + z init broadcastback
    "group_corr": lambda E: 2.0 * E + 1.0, # + y broadcast
    "mtgc": lambda E: 2.0 * E + 2.0,       # + both (App. B: (E+1)/E factor)
}


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    E = setup.group_rounds
    rows = []
    at_budget = {}
    budget = COST_PER_ROUND["mtgc"](E) * setup.rounds * 0.8
    for algo, cost in COST_PER_ROUND.items():
        hist = run_algorithm(setup, algo, eval_every=2)
        best = 0.0
        for r, a in zip(hist["round"], hist["acc"]):
            c = cost(E) * r
            rows.append([algo, r, c, a])
            if c <= budget:
                best = max(best, a)
        at_budget[algo] = best
    report("fig7_comm_cost", rows,
           ["algorithm", "round", "model_transmissions", "test_acc"])
    best = max(at_budget, key=at_budget.get)
    print(f"[fig7] accuracy at equal comm budget: "
          f"{ {k: round(v, 4) for k, v in at_budget.items()} } "
          f"best={best} {'OK' if best == 'mtgc' else 'VIOLATED'}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
