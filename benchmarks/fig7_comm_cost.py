"""Paper Fig. 7a: testing accuracy versus client-side communication cost.

Cost comes from the engine's measured ``comm_bytes`` metric (bytes on the
wire for every upload actually sent that round), not hand-written
per-algorithm multiples. Uploads are measured; the symmetric downlink
broadcast is charged at the same price, and the correction-state
dissemination each algorithm needs on top (App. B: z init for local_corr,
y broadcast for group_corr, both for MTGC) is charged one model-upload
each per client per round. At an equal byte budget MTGC is expected to
win on accuracy despite the correction overhead.

A second sweep runs MTGC under ``CompressionPlan``s (int8 + error
feedback, top-k + error feedback) -- same training, cheaper measured
uploads -- and reports accuracy at the same byte budget.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchSetup, report, run_algorithm
from repro.api import CompressionPlan

# Correction-state broadcasts per client per global round, on top of the
# measured upload + symmetric downlink (App. B).
EXTRA_BROADCASTS = {
    "hfedavg": 0.0,
    "local_corr": 1.0,   # z init broadcast
    "group_corr": 1.0,   # y broadcast
    "mtgc": 2.0,         # both
}

COMPRESSED_PLANS = {
    "mtgc_int8_ef": CompressionPlan(client_mode="int8_stochastic",
                                    group_mode="int8_stochastic"),
    "mtgc_topk_ef": CompressionPlan(client_mode="topk", group_mode="bf16",
                                    topk_frac=0.1),
}


def cost_curve(hist: dict, *, extra: float, E: int, G: int, K: int):
    """Cumulative megabytes on the wire at each eval round.

    ``comm_bytes[t]`` measures the round's uploads (E*G*K client uploads
    plus G group uploads when everyone participates). Downlink is charged
    equal to uplink; correction broadcasts are charged at the per-client
    model-upload price implied by the same measurement.
    """
    comm = np.asarray(hist["comm_bytes"], dtype=np.float64)
    per_upload = comm / (E * G * K + G)        # modeled client-upload bytes
    per_round = 2.0 * comm + extra * per_upload * G * K
    cum_mb = np.cumsum(per_round) / 1e6
    return [float(cum_mb[r - 1]) for r in hist["round"]]


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    E, G, K = setup.group_rounds, setup.num_groups, setup.clients_per_group
    rows = []
    curves = {}
    for algo, extra in EXTRA_BROADCASTS.items():
        hist = run_algorithm(setup, algo, eval_every=2)
        curves[algo] = (cost_curve(hist, extra=extra, E=E, G=G, K=K), hist)
    for name, plan in COMPRESSED_PLANS.items():
        hist = run_algorithm(setup, "mtgc", eval_every=2, compression=plan)
        curves[name] = (cost_curve(hist, extra=EXTRA_BROADCASTS["mtgc"],
                                   E=E, G=G, K=K), hist)

    # Equal budget: 80% of what uncompressed MTGC spends over the run.
    budget = 0.8 * curves["mtgc"][0][-1]
    at_budget = {}
    for name, (mb, hist) in curves.items():
        best = 0.0
        for r, a, c in zip(hist["round"], hist["acc"], mb):
            rows.append([name, r, c, a])
            if c <= budget:
                best = max(best, a)
        at_budget[name] = best
    report("fig7_comm_cost", rows,
           ["algorithm", "round", "comm_mbytes", "test_acc"])
    base_algos = {k: v for k, v in at_budget.items()
                  if k in EXTRA_BROADCASTS}
    best = max(base_algos, key=base_algos.get)
    print(f"[fig7] accuracy at equal comm budget ({budget:.1f} MB): "
          f"{ {k: round(v, 4) for k, v in at_budget.items()} } "
          f"best_algorithm={best} {'OK' if best == 'mtgc' else 'VIOLATED'}")
    for name in COMPRESSED_PLANS:
        ratio = curves["mtgc"][0][-1] / max(curves[name][0][-1], 1e-12)
        print(f"[fig7] {name}: {ratio:.1f}x cheaper wire bytes than "
              f"uncompressed mtgc over the run")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
