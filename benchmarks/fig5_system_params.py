"""Paper Fig. 5 (App. B): effect of the number of groups / clients-per-group
on each correction level."""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, run_algorithm

ALGOS = ("local_corr", "group_corr", "mtgc")


def main(quick: bool = True) -> None:
    setup = BenchSetup(rounds=25) if quick else BenchSetup.paper()
    topos = [(2, 8), (4, 4), (8, 2)] if quick else [(5, 20), (10, 10), (20, 5)]
    rows = []
    for (G, K) in topos:
        for algo in ALGOS:
            hist = run_algorithm(setup, algo, G=G, K=K, eval_every=5)
            rows.append([G, K, algo, hist["acc"][-1]])
    report("fig5_system_params", rows,
           ["groups", "clients_per_group", "algorithm", "final_acc"])
    by = {(g, k, a): acc for g, k, a, acc in rows}
    g0, k0 = topos[0]
    gn, kn = topos[-1]
    wide = by[(g0, k0, "local_corr")] - by[(g0, k0, "group_corr")]
    many = by[(gn, kn, "group_corr")] - by[(gn, kn, "local_corr")]
    print(f"[fig5] many-clients favours local corr (delta {wide:+.4f}); "
          f"many-groups favours group corr (delta {many:+.4f})")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
