"""Paper Fig. 3: MTGC vs conventional-FL baselines extended to HFL
(HFedAvg, FedProx, SCAFFOLD-within-group = local_corr, FedDyn) in the
group non-i.i.d. & client non-i.i.d. setting."""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, run_algorithm

ALGOS = ("mtgc", "hfedavg", "fedprox", "local_corr", "feddyn")


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    rows = []
    finals = {}
    for algo in ALGOS:
        hist = run_algorithm(setup, algo, eval_every=2)
        finals[algo] = hist["acc"][-1]
        for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
            rows.append([algo, r, a, l])
    report("fig3_fl_baselines", rows, ["algorithm", "round", "test_acc", "train_loss"])
    best = max(finals, key=finals.get)
    print(f"[fig3] final accuracies: { {k: round(v, 4) for k, v in finals.items()} }")
    print(f"[fig3] paper claim check (MTGC best): best={best} "
          f"{'OK' if best == 'mtgc' else 'VIOLATED'}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
