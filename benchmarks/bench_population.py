"""Virtual-population benchmark: O(cohort) device memory + hidden transfers.

The claims behind ``core.population`` (ISSUE 7), measured on a
deliberately state-heavy workload (single contiguous ``[G, K, n]`` flat
leaf, scalar-coefficient quadratic -- transfers and state dominate, like
``bench_round._donation_memory``):

1. **Memory** (claim ``memory_flat_ok``): device state bytes at a fixed
   cohort K are *constant* as the population P grows 10x-1000x, while
   materializing all P clients grows linearly with P. Both curves come
   from the ``Packer`` segment table (``state_bytes``) -- the same
   arithmetic that sizes the actual buffers -- plus an observational
   sampled-RSS series per P as a cross-check that nothing device-side
   secretly scales with P.
2. **Wall time** (claim ``walltime_independent_ok``): per-round wall time
   at fixed cohort is independent of P (max/min across P within
   ``WALLTIME_TOLERANCE``), because only host-store indexing sees P.
3. **Overlap** (claim ``overhead_ok``): the gather/scatter overhead of
   the overlapped path over plain materialized ``run_rounds`` stays under
   ``OVERHEAD_TARGET`` (30%) of round time; the non-overlapped sequential
   path is also timed so the report shows how much the double-buffering
   actually hides.

One round function is compiled and shared across every P (the population
only changes the host store, never the compiled program), so the wall-time
comparison isolates exactly the population effect. Timed reps interleave
across P so background load hits every population equally.

Results land in ``benchmarks/results/BENCH_population.json`` (uploaded by
the non-blocking CI bench job); tests/test_population.py re-runs the
measurement functions at small scale and gates the claims.

    PYTHONPATH=src python -m benchmarks.bench_population --quick
    PYTHONPATH=src python -m benchmarks.bench_population --full
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import PackedBatches, PopulationStore, run_rounds
from repro.core.population import run_population_rounds

RESULTS = Path(__file__).parent / "results"
WALLTIME_TOLERANCE = 1.3
OVERHEAD_TARGET = 0.30


def build_problem(G: int = 4, K: int = 16, n: int = 50_000, E: int = 2,
                  H: int = 2, shards: int = 4, seed: int = 0):
    """(engine, state_factory, data) for the state-heavy quadratic.

    ``state_factory()`` returns a fresh flat ``[G, K, n]`` state (the
    driver donates state buffers, so every timed run needs its own);
    the single engine/round function is shared across all populations.
    """

    def loss_fn(p, batch):
        return 0.5 * jnp.mean((batch["a"] * p["w"] - batch["b"]) ** 2)

    spec = api.ExperimentSpec(
        levels=(G, K),
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        algorithm="mtgc", lr=0.05, backend="simulator", state_layout="flat")
    engine = api.build(spec, loss_fn)
    rng = np.random.default_rng(seed)
    steps = E * H
    arrays = {
        "a": jnp.asarray(rng.normal(size=(G, K, shards, steps, 1)) * 0.3 + 1.0,
                         jnp.float32),
        "b": jnp.asarray(rng.normal(size=(G, K, shards, steps, 1)),
                         jnp.float32),
    }
    data = PackedBatches(arrays, jax.random.PRNGKey(seed + 1), E, H, None)
    params0 = {"w": jnp.zeros((n,), jnp.float32)}

    def state_factory():
        return engine.init(params0, jax.random.PRNGKey(seed + 2))

    return engine, state_factory, data


def _store_for(engine, state, population: int) -> PopulationStore:
    return PopulationStore.from_state(state, population,
                                      engine.population_fields)


def measure_memory(engine, state_factory, populations, K: int,
                   T: int = 4, chunk: int = 2) -> dict:
    """Claim 1: cohort device bytes flat in P, materialized bytes linear.

    Segment-table bytes (exact, what the buffers actually allocate) per
    population, plus a sampled-RSS observation of a short horizon at each
    P as the nothing-scales-with-P cross-check.
    """
    from benchmarks.bench_round import _sampled_peak_rss

    state = state_factory()
    packer = state.z.packer
    G = state.z.lead_shape[0]
    # Full HFLState: params + z + dyn at [G, K], y at [G].
    per_cohort = 3 * packer.state_bytes((G, K)) + packer.state_bytes((G,))
    series = []
    for P in populations:
        store = _store_for(engine, state, P)

        def run(store=store):
            s = state_factory()
            out, _, _ = run_population_rounds(
                engine.round_fn, s, store, _MEM_DATA[0], T, chunk=chunk)
            jax.block_until_ready(out.params.bufs)
            return out

        _, peak_rss = _sampled_peak_rss(run)
        series.append({
            "population": int(P),
            "cohort_device_bytes": per_cohort,
            "materialized_device_bytes":
                3 * packer.state_bytes((G, P)) + packer.state_bytes((G,)),
            "host_store_bytes": store.state_bytes(),
            "sampled_peak_rss_bytes": int(peak_rss),
            "store_report": store.size_report(K),
        })
    cohort = [s["cohort_device_bytes"] for s in series]
    mat = [s["materialized_device_bytes"] for s in series]
    # Exactly linear in P: every pairwise slope equals the per-client byte
    # cost (the y term at [G] is the constant offset, not part of the slope).
    slopes = [(mat[i + 1] - mat[i]) / (populations[i + 1] - populations[i])
              for i in range(len(series) - 1)]
    claims = {
        # Flat means *identical*: the segment table sizes the real buffers.
        "cohort_bytes_flat": max(cohort) == min(cohort),
        "materialized_bytes_linear": max(slopes) == min(slopes) > 0,
    }
    claims["memory_flat_ok"] = all(claims.values())
    return {"series": series, "claims": claims}


_MEM_DATA = []  # set by bench(); keeps measure_memory's signature small


def measure_walltime(engine, state_factory, data, populations, T: int = 12,
                     chunk: int = 4, reps: int = 3,
                     tolerance: float = WALLTIME_TOLERANCE) -> dict:
    """Claims 2 + 3: P-independent round time, overlap overhead < target.

    Interleaved min-of-reps of the overlapped population path per P; at
    the largest P, plain materialized ``run_rounds`` (the no-store floor)
    and the non-overlapped sequential path complete the overhead picture.
    """
    state = state_factory()
    stores = {P: _store_for(engine, state, P) for P in populations}

    def run_pop(P, overlap=True):
        s = state_factory()
        out, _, _ = run_population_rounds(
            engine.round_fn, s, stores[P], data, T, chunk=chunk,
            overlap=overlap)
        jax.block_until_ready(out.params.bufs)

    def run_plain():
        s = state_factory()
        out, _, _ = run_rounds(engine.round_fn, s, data, T, chunk=chunk)
        jax.block_until_ready(out.params.bufs)

    variants = {f"population_{P}": (lambda P=P: run_pop(P))
                for P in populations}
    P_max = populations[-1]
    variants["sequential"] = lambda: run_pop(P_max, overlap=False)
    variants["materialized"] = run_plain
    for fn in variants.values():        # warm every path (compile)
        fn()
    times = {name: [] for name in variants}
    for _ in range(reps):
        for name, fn in variants.items():
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    timed = {name: float(np.min(ts)) / T * 1e3 for name, ts in times.items()}

    # The independence claim covers the *virtual* populations: P == K takes
    # the degenerate fast path (no draws, no per-chunk refresh), so its
    # timing is a different code path, reported but not part of the ratio.
    K = state.z.lead_shape[1]
    virtual = [P for P in populations if P > K] or list(populations)
    pop_times = [timed[f"population_{P}"] for P in virtual]
    plain = timed["materialized"]
    overhead_overlap = (timed[f"population_{P_max}"] - plain) / plain
    overhead_seq = (timed["sequential"] - plain) / plain
    claims = {
        "walltime_independent_ok":
            max(pop_times) / min(pop_times) <= tolerance,
        "overhead_ok": overhead_overlap < OVERHEAD_TARGET,
    }
    return {
        "per_round_ms": timed,
        "populations": [int(P) for P in populations],
        "walltime_ratio_max_over_min": max(pop_times) / min(pop_times),
        "walltime_tolerance": tolerance,
        "overhead_overlapped": overhead_overlap,
        "overhead_sequential": overhead_seq,
        "overhead_hidden_by_overlap": overhead_seq - overhead_overlap,
        "overhead_target": OVERHEAD_TARGET,
        "claims": claims,
    }


def bench(G: int = 4, K: int = 16, n: int = 50_000, T: int = 12,
          chunk: int = 4, reps: int = 3,
          populations: tuple[int, ...] = (16, 160, 1_600, 16_000)) -> dict:
    engine, state_factory, data = build_problem(G=G, K=K, n=n)
    _MEM_DATA.clear()
    _MEM_DATA.append(data)
    print(f"[bench_population] backend={jax.default_backend()} G={G} K={K} "
          f"n={n} T={T} chunk={chunk} populations={populations}")

    memory = measure_memory(engine, state_factory, populations, K)
    for s in memory["series"]:
        print(f"  P={s['population']:>7d}: cohort device "
              f"{s['cohort_device_bytes']/1e6:8.1f} MB (flat), "
              f"materialized {s['materialized_device_bytes']/1e6:8.1f} MB, "
              f"host store {s['host_store_bytes']/1e6:6.1f} MB")

    walltime = measure_walltime(engine, state_factory, data, populations,
                                T=T, chunk=chunk, reps=reps)
    for name, ms in walltime["per_round_ms"].items():
        print(f"  {name:18s} {ms:8.2f} ms/round")
    print(f"[bench_population] walltime max/min "
          f"{walltime['walltime_ratio_max_over_min']:.2f} "
          f"(tolerance {WALLTIME_TOLERANCE}), overlapped overhead "
          f"{walltime['overhead_overlapped']*100:.1f}% vs materialized "
          f"(sequential {walltime['overhead_sequential']*100:.1f}%, "
          f"target <{OVERHEAD_TARGET*100:.0f}%)")

    claims = {**memory["claims"], **walltime["claims"]}
    out = {
        "backend": jax.default_backend(),
        "config": {"G": G, "K": K, "n": n, "T": T, "chunk": chunk,
                   "reps": reps, "populations": list(populations)},
        "memory": memory,
        "walltime": walltime,
        "claims": claims,
        "all_claims_ok": all(claims.values()),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_population.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_population] claims "
          f"{'all OK' if out['all_claims_ok'] else 'FAILED: ' + str(claims)} "
          f"-> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--quick", action="store_true", default=True,
                       help="CI-sized config (default)")
    group.add_argument("--full", action="store_true",
                       help="bigger state and a 100k-client population")
    args = ap.parse_args()
    if args.full:
        out = bench(n=200_000, populations=(16, 1_000, 10_000, 100_000))
    else:
        out = bench()
    if not out["all_claims_ok"]:
        raise SystemExit("population claims FAILED")
