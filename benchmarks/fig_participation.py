"""Beyond-paper: partial-participation sweep (GC-Fed's stress regime).

Trains MTGC against HFedAvg and the single-correction ablations while only a
fraction C of each group's clients participates per round (fixed-count
sampling, so every round has the same budget), at C in {0.25, 0.5, 1.0}.
The paper's claims all assume C = 1.0; correction-based methods are known to
degrade fastest when participation drops (Seo et al., 2025), so this chart
is the scenario axis the reproduction adds.

Also sweeps group participation at C_g in {0.5, 1.0} for MTGC vs HFedAvg:
whole groups sitting out rounds is the hierarchical-specific failure mode
(async/offline aggregators, Wang & Wang 2022).

Bias/variance section (``--bias-bench``, also run by CI's non-blocking
bench job -> ``benchmarks/results/BENCH_participation.json``): a
Monte-Carlo study of the participation-weighting estimators under Bernoulli
(``uniform``) sampling. R independent trajectories -- identical data and
init, different mask streams -- run *simultaneously* through the compiled
horizon driver (the round function vmapped over the trajectory axis, one
``run_rounds`` scan per weighting), and each round's disseminated global
aggregate is read out inside the compiled program by an eval_fn that
re-derives the trajectory's mask from its pre-round rng. Two sections,
each against the full-participation reference on the same data:

* ``one_round`` (E=1, a single group round per global round): here
  inverse-probability weighting is *exactly* unbiased -- every client's
  local trajectory is mask-independent, so its measured bias is pure MC
  noise (~1/sqrt(R); the claim checks it sits within a few noise floors).
  The realized-count estimator is also unbiased in this single-timescale
  setting (subset symmetry), which is exactly why the distinction only
  shows up when aggregates feed back across timescales;
* ``compounded`` (E=2 group rounds, T=4 global rounds of MTGC): the
  realized-count denominator noise feeds the z/y corrections across both
  timescales and accumulates into a systematic offset many sigma above
  the noise, which inverse_prob cuts by ~3x -- at the price of a larger
  per-round aggregate variance (the ``std`` column).

The same MC harness (``mc_participation_aggregates`` /
``full_participation_reference`` below) backs the hard statistical gates
in tests/test_weighting.py, so the published artifact and the test gate
measure the same estimator readout by construction.

    PYTHONPATH=src python -m benchmarks.fig_participation
    PYTHONPATH=src python -m benchmarks.fig_participation --bias-bench
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchSetup, report, run_algorithm
from repro.core import (
    HFLConfig,
    PackedBatches,
    hfl_init,
    make_global_round,
    round_masks,
    run_rounds,
)

RESULTS = Path(__file__).parent / "results"

ALGOS = ("hfedavg", "local_corr", "group_corr", "mtgc")
CLIENT_FRACS = (0.25, 0.5, 1.0)
GROUP_FRACS = (0.5, 1.0)


# ------------------------------------------------- bias/variance MC harness

# Topology of the MC study: heterogeneous quadratics in the
# slow-contraction regime where the count-noise of realized-count
# weighting visibly compounds (curvature a^2 ~ chi^2 + 0.3, lr * curvature
# well below 1, per-client optima spread ~2 sigma apart).
MC_G, MC_K, MC_D = 3, 8, 6


def _quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def _mc_data(E, H, seed=0):
    """Deterministic per-client quadratic data: same batches every round,
    so the full-participation trajectory is an exact reference and the
    only randomness across trajectories is the mask stream."""
    G, K, D = MC_G, MC_K, MC_D
    rng = np.random.default_rng(seed)
    curv = rng.normal(size=(G, K, D)) ** 2 + 0.3
    targ = rng.normal(size=(G, K, D)) * 2.0
    a = np.broadcast_to(np.sqrt(curv)[:, :, None, None], (G, K, 1, H, D))
    b = np.broadcast_to((targ / np.sqrt(curv))[:, :, None, None],
                        (G, K, 1, H, D))
    arrays = {"a": jnp.asarray(a, jnp.float32),
              "b": jnp.asarray(b, jnp.float32)}
    return PackedBatches(arrays, jax.random.PRNGKey(1), E, H, None)


def mc_participation_aggregates(weighting: str, *, E: int, H: int, T: int,
                                R: int, frac: float = 0.5, lr: float = 0.1,
                                seed: int = 0, traj_key: int = 2):
    """R MTGC trajectories with independent mask streams, one compiled
    horizon: the round function vmapped over the trajectory axis through
    ``run_rounds``, each round's disseminated global aggregate read out by
    an in-scan eval_fn from an active replica (mask re-derived from the
    trajectory's pre-round rng). Returns ``(agg [T, R, D], ok [T, R])``
    -- ``ok`` flags rounds with at least one active client; all-empty
    rounds hold a stale readout and are dropped by callers.

    Shared between the BENCH_participation.json artifact and the
    statistical gates in tests/test_weighting.py so both measure the same
    estimator readout.
    """
    K = MC_K
    cfg = HFLConfig(
        num_groups=MC_G, clients_per_group=K, local_steps=H, group_rounds=E,
        lr=lr, algorithm="mtgc", client_participation=frac,
        participation_mode="uniform", participation_weighting=weighting,
        use_flat_state=False)
    round_fn = jax.vmap(make_global_round(_quad_loss, cfg), in_axes=(0, None))

    def eval_one(prev, state):
        cmask = round_masks(prev.rng, cfg)[0].client
        i = jnp.argmax(cmask.reshape(-1))
        return {"agg": state.params["w"][i // K, i % K],
                "n_active": jnp.sum(cmask)}

    keys = jax.random.split(jax.random.PRNGKey(traj_key), R)
    states = jax.vmap(
        lambda k: hfl_init({"w": jnp.zeros(MC_D)}, cfg, rng=k))(keys)
    _, _, hz = run_rounds(round_fn, states, _mc_data(E, H, seed), T,
                          eval_every=1, eval_fn=jax.vmap(eval_one))
    return (np.asarray(hz.evals["agg"]),          # [T, R, D]
            np.asarray(hz.evals["n_active"]) > 0)  # [T, R]


def full_participation_reference(*, E: int, H: int, T: int, lr: float = 0.1,
                                 seed: int = 0):
    """[T, D] exact full-participation aggregates on the same data."""
    cfg = HFLConfig(
        num_groups=MC_G, clients_per_group=MC_K, local_steps=H,
        group_rounds=E, lr=lr, algorithm="mtgc", use_flat_state=False)
    _, _, hz = run_rounds(
        make_global_round(_quad_loss, cfg),
        hfl_init({"w": jnp.zeros(MC_D)}, cfg), _mc_data(E, H, seed), T,
        eval_every=1,
        eval_fn=lambda prev, state: {"agg": state.params["w"][0, 0]})
    return np.asarray(hz.evals["agg"])


def _mc_stats(weighting, full, *, E, H, T, R, report_rounds):
    agg, ok = mc_participation_aggregates(weighting, E=E, H=H, T=T, R=R)
    rounds = {}
    for t in report_rounds:
        a = agg[t][ok[t]]
        rounds[f"round_{t + 1}"] = {
            "n": int(ok[t].sum()),
            "bias": float(np.linalg.norm(a.mean(axis=0) - full[t])),
            # MC noise floor of the bias norm: sqrt(sum_d var_d / n).
            "mc_se": float(np.sqrt((a.var(axis=0) / len(a)).sum())),
            "std": float(a.std(axis=0).mean()),
        }
    return rounds


def bias_variance_bench(quick: bool = True) -> dict:
    """MC bias/variance of none vs inverse_prob weighting vs the exact
    full-participation reference; see the module docstring for the two
    sections. Emits BENCH_participation.json."""
    R = 512 if quick else 2048
    out = {
        "config": {"G": MC_G, "K": MC_K, "D": MC_D, "lr": 0.1,
                   "client_participation": 0.5, "mode": "uniform",
                   "algorithm": "mtgc", "R": R,
                   "one_round": {"E": 1, "H": 2, "T": 1},
                   "compounded": {"E": 2, "H": 2, "T": 4}},
        "one_round": {},
        "compounded": {},
    }
    full1 = full_participation_reference(E=1, H=2, T=1)
    for w in ("none", "inverse_prob"):
        out["one_round"][w] = _mc_stats(w, full1, E=1, H=2, T=1, R=R,
                                        report_rounds=(0,))
    fullT = full_participation_reference(E=2, H=2, T=4)
    for w in ("none", "inverse_prob"):
        out["compounded"][w] = _mc_stats(w, fullT, E=2, H=2, T=4, R=R,
                                         report_rounds=(0, 3))

    one = out["one_round"]["inverse_prob"]["round_1"]
    b_none = out["compounded"]["none"]["round_4"]
    b_ht = out["compounded"]["inverse_prob"]["round_4"]
    out["claims"] = {
        # Exact unbiasedness at the single timescale: within a few noise
        # floors (the hard 1/sqrt(R) gate lives in tests/test_weighting.py).
        "one_round_inverse_prob_unbiased": bool(
            one["bias"] < 4.0 * one["mc_se"]),
        "none_bias_measurable_at_T": bool(
            b_none["bias"] > 5 * b_none["mc_se"]),
        "inverse_prob_reduces_compounded_bias": bool(
            b_ht["bias"] < 0.67 * b_none["bias"]),
    }

    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_participation.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[fig_participation] bias/variance -> {path}")
    for section in ("one_round", "compounded"):
        for w, rounds in out[section].items():
            for rnd, s in rounds.items():
                print(f"  {section:10s} {w:13s} {rnd}: bias={s['bias']:.5f} "
                      f"mc_se={s['mc_se']:.5f} std={s['std']:.5f} "
                      f"(n={s['n']})")
    print(f"[fig_participation] claims: {out['claims']}")
    return out


# ------------------------------------------------------ accuracy sweep


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    rows, final = [], {}
    for frac in CLIENT_FRACS:
        for algo in ALGOS:
            hist = run_algorithm(setup, algo, eval_every=2,
                                 client_participation=frac,
                                 participation_mode="fixed")
            final[(frac, algo)] = hist["acc"][-1]
            for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
                rows.append(["client", frac, algo, r, a, l])
    for gfrac in GROUP_FRACS:
        for algo in ("hfedavg", "mtgc"):
            hist = run_algorithm(setup, algo, eval_every=2,
                                 group_participation=gfrac,
                                 participation_mode="fixed")
            final[(f"g{gfrac}", algo)] = hist["acc"][-1]
            for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
                rows.append(["group", gfrac, algo, r, a, l])
    report("fig_participation", rows,
           ["axis", "fraction", "algorithm", "round", "test_acc", "train_loss"])

    print("[fig_participation] final accuracy by client fraction:")
    for frac in CLIENT_FRACS:
        print("  C=" + f"{frac:<5}" + " ".join(
            f"{algo}={final[(frac, algo)]:.4f}" for algo in ALGOS))
    print("[fig_participation] final accuracy by group fraction:")
    for gfrac in GROUP_FRACS:
        print("  Cg=" + f"{gfrac:<4}" + " ".join(
            f"{algo}={final[(f'g{gfrac}', algo)]:.4f}"
            for algo in ("hfedavg", "mtgc")))
    # Sanity claims: every method should improve with participation, and at
    # full participation MTGC should remain best-or-tied (paper Fig. 4).
    mono = all(final[(0.25, a)] <= final[(1.0, a)] + 0.05 for a in ALGOS)
    best = final[(1.0, "mtgc")] >= max(final[(1.0, a)] for a in ALGOS) - 0.02
    print(f"[fig_participation] claim checks: monotone-ish={mono} "
          f"mtgc-best-at-full={best}")

    bias_variance_bench(quick=quick)


if __name__ == "__main__":
    import sys
    quick = "--full" not in sys.argv
    if "--bias-bench" in sys.argv:
        bias_variance_bench(quick=quick)
    else:
        main(quick=quick)
