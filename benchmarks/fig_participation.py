"""Beyond-paper: partial-participation sweep (GC-Fed's stress regime).

Trains MTGC against HFedAvg and the single-correction ablations while only a
fraction C of each group's clients participates per round (fixed-count
sampling, so every round has the same budget), at C in {0.25, 0.5, 1.0}.
The paper's claims all assume C = 1.0; correction-based methods are known to
degrade fastest when participation drops (Seo et al., 2025), so this chart
is the scenario axis the reproduction adds.

Also sweeps group participation at C_g in {0.5, 1.0} for MTGC vs HFedAvg:
whole groups sitting out rounds is the hierarchical-specific failure mode
(async/offline aggregators, Wang & Wang 2022).
"""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, run_algorithm

ALGOS = ("hfedavg", "local_corr", "group_corr", "mtgc")
CLIENT_FRACS = (0.25, 0.5, 1.0)
GROUP_FRACS = (0.5, 1.0)


def main(quick: bool = True) -> None:
    setup = BenchSetup() if quick else BenchSetup.paper()
    rows, final = [], {}
    for frac in CLIENT_FRACS:
        for algo in ALGOS:
            hist = run_algorithm(setup, algo, eval_every=2,
                                 client_participation=frac,
                                 participation_mode="fixed")
            final[(frac, algo)] = hist["acc"][-1]
            for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
                rows.append(["client", frac, algo, r, a, l])
    for gfrac in GROUP_FRACS:
        for algo in ("hfedavg", "mtgc"):
            hist = run_algorithm(setup, algo, eval_every=2,
                                 group_participation=gfrac,
                                 participation_mode="fixed")
            final[(f"g{gfrac}", algo)] = hist["acc"][-1]
            for r, a, l in zip(hist["round"], hist["acc"], hist["loss"]):
                rows.append(["group", gfrac, algo, r, a, l])
    report("fig_participation", rows,
           ["axis", "fraction", "algorithm", "round", "test_acc", "train_loss"])

    print("[fig_participation] final accuracy by client fraction:")
    for frac in CLIENT_FRACS:
        print("  C=" + f"{frac:<5}" + " ".join(
            f"{algo}={final[(frac, algo)]:.4f}" for algo in ALGOS))
    print("[fig_participation] final accuracy by group fraction:")
    for gfrac in GROUP_FRACS:
        print("  Cg=" + f"{gfrac:<4}" + " ".join(
            f"{algo}={final[(f'g{gfrac}', algo)]:.4f}"
            for algo in ("hfedavg", "mtgc")))
    # Sanity claims: every method should improve with participation, and at
    # full participation MTGC should remain best-or-tied (paper Fig. 4).
    mono = all(final[(0.25, a)] <= final[(1.0, a)] + 0.05 for a in ALGOS)
    best = final[(1.0, "mtgc")] >= max(final[(1.0, a)] for a in ALGOS) - 0.02
    print(f"[fig_participation] claim checks: monotone-ish={mono} "
          f"mtgc-best-at-full={best}")


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
