"""Deliverable (g): assemble the roofline table from the dry-run JSONs.

Reads ``benchmarks/results/dryrun/<arch>__<shape>__<mesh>__<tag>.json``
(produced by ``python -m repro.launch.dryrun``) and emits the §Roofline
table: the three terms in seconds, the dominant term, MODEL_FLOPS/HLO_FLOPs
(useful-compute ratio), and per-device memory -- one row per
(arch x shape x mesh).
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results"
DRYRUN = RESULTS / "dryrun"


def load(tag: str = "baseline") -> list[dict]:
    recs = []
    for f in sorted(DRYRUN.glob(f"*__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def row(r: dict) -> list:
    if r["status"] != "ok":
        return [r["arch"], r["shape"], r["mesh"], r["status"],
                r.get("reason", r.get("error", ""))[:60], "", "", "", "", ""]
    t = r["terms"]
    mem = r.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
           - mem.get("alias_size_in_bytes", 0))
    return [
        r["arch"], r["shape"], r["mesh"], "ok",
        f"{t['compute_s']:.4g}", f"{t['memory_s']:.4g}",
        f"{t['collective_s']:.4g}", r["dominant"].replace("_s", ""),
        f"{r['useful_flops_ratio']:.3f}", f"{hbm / 1e9:.2f}",
    ]


HEADER = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
          "collective_s", "dominant", "useful_ratio", "hbm_GB_per_dev"]


def main(tag: str = "baseline") -> None:
    recs = load(tag)
    if not recs:
        print(f"[roofline] no dry-run records with tag {tag!r}; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    rows = [row(r) for r in recs]
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"roofline_{tag}.csv"
    with open(out, "w") as f:
        f.write(",".join(HEADER) + "\n")
        for rr in rows:
            f.write(",".join(str(x) for x in rr) + "\n")
    print(f"[roofline] {len(rows)} rows -> {out}")
    w = [22, 12, 9, 6, 10, 10, 12, 10, 12, 14]
    print(" ".join(h.ljust(x) for h, x in zip(HEADER, w)))
    for rr in rows:
        print(" ".join(str(x).ljust(y) for x, y in zip(rr, w)))
    ok = [r for r in recs if r["status"] == "ok"]
    dom = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    print(f"[roofline] ok={len(ok)} skip={sum(r['status'] == 'skip' for r in recs)} "
          f"error={sum(r['status'] == 'error' for r in recs)} dominant histogram={dom}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "baseline")
