"""Compression benchmark: wire savings + error-feedback necessity.

The claims behind ``core.compression`` (ISSUE 10), measured on the
state-heavy ``[G, K, n]`` flat quadratic from ``bench_faults`` (shared
optimum ``w* ~= 1.5`` with a small noise floor, heterogeneous per-client
coefficients so the corrections work):

1. **int8 + EF is free accuracy-wise** (claim ``int8_ef_loss_ok``):
   stochastic-rounding int8 uploads on both links with per-link error
   feedback end within ``LOSS_GAP`` (2%) of the uncompressed final loss.
2. **...at a real wire discount** (claim ``int8_bytes_ratio_ok``): the
   *measured* ``comm_bytes`` metric (not the analytic model) shrinks by
   at least ``BYTES_RATIO`` (3.5x) per round vs the uncompressed run.
3. **Error feedback is load-bearing** (claim ``ef_off_worse``): the same
   top-k plan with ``error_feedback=False`` ends at least
   ``EF_WORSE_FACTOR``x worse than with EF on -- biased sparsification
   needs the residual memory; int8 stochastic rounding is unbiased, so
   top-k is the ablation that isolates EF.

Results land in ``benchmarks/results/BENCH_comm.json`` (uploaded by the
non-blocking CI bench job).

    PYTHONPATH=src python -m benchmarks.bench_compression --quick
    PYTHONPATH=src python -m benchmarks.bench_compression --full
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import PackedBatches

RESULTS = Path(__file__).parent / "results"
LOSS_GAP = 0.02
BYTES_RATIO = 3.5
EF_WORSE_FACTOR = 1.2
TOPK_FRAC = 0.25


def build_problem(G: int = 4, K: int = 16, n: int = 20_000, E: int = 2,
                  H: int = 8, shards: int = 4, seed: int = 0,
                  compression: api.CompressionPlan | None = None):
    """(engine, params0, data) for one compression scenario.

    Same problem family as ``bench_faults.build_problem`` -- scalar-
    coefficient sum-loss quadratic on a flat ``[G, K, n]`` state with a
    shared optimum (``b = 1.5 a + noise``) -- except for a fixed
    per-coordinate curvature ``c``: without it every coordinate of ``w``
    evolves identically (the batch coefficients broadcast one scalar over
    ``n``), uploads are constant rows, and top-k's keep-ties rule keeps
    *everything* -- compression would be a no-op. With ``c`` spread over
    ``[0.5, 1.5]`` the per-coordinate deltas differ, so sparsification
    actually drops mass and the EF ablation has something to recover. All
    scenarios share the data and init rng; only the plan differs.
    """
    c = jnp.linspace(0.5, 1.5, n, dtype=jnp.float32)

    def loss_fn(p, batch):
        return 0.5 * jnp.sum((batch["a"] * c * p["w"] - batch["b"]) ** 2)

    spec = api.ExperimentSpec(
        levels=(G, K),
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        algorithm="mtgc", lr=0.1, backend="simulator", state_layout="flat",
        compression=compression)
    engine = api.build(spec, loss_fn)
    rng = np.random.default_rng(seed)
    steps = E * H
    a = rng.normal(size=(G, K, shards, steps, 1)) * 0.3 + 1.0
    b = 1.5 * a + 0.05 * rng.normal(size=a.shape)
    arrays = {"a": jnp.asarray(a, jnp.float32),
              "b": jnp.asarray(b, jnp.float32)}
    data = PackedBatches(arrays, jax.random.PRNGKey(seed + 1), E, H, None)
    params0 = {"w": jnp.zeros((n,), jnp.float32)}
    return engine, params0, data


def _run(scenario: str, T: int, chunk: int, *,
         compression: api.CompressionPlan | None = None,
         **problem_kw) -> dict:
    engine, params0, data = build_problem(compression=compression,
                                          **problem_kw)
    state, hz = api.fit(engine, data, T, params=params0,
                        rng=jax.random.PRNGKey(7), chunk=chunk)
    loss = np.asarray(hz.metrics.loss, dtype=np.float64)
    comm = np.asarray(hz.metrics.comm_bytes, dtype=np.float64).reshape(-1)
    return {
        "scenario": scenario,
        "initial_loss": float(np.mean(loss[0])),
        "final_loss": float(np.mean(loss[-1])),
        "bytes_per_round": float(np.mean(comm)),
        "total_bytes": float(np.sum(comm)),
    }


def bench(G: int = 4, K: int = 16, n: int = 20_000, T: int = 12,
          chunk: int = 4) -> dict:
    kw = dict(G=G, K=K, n=n)
    print(f"[bench_compression] backend={jax.default_backend()} G={G} "
          f"K={K} n={n} T={T} chunk={chunk}")

    int8 = api.CompressionPlan(client_mode="int8_stochastic",
                               group_mode="int8_stochastic")
    topk = api.CompressionPlan(client_mode="topk", group_mode="topk",
                               topk_frac=TOPK_FRAC)
    runs = {
        "uncompressed": _run("uncompressed", T, chunk, **kw),
        "int8_ef": _run("int8_ef", T, chunk, compression=int8, **kw),
        "int8_noef": _run("int8_noef", T, chunk, **kw, compression=(
            api.CompressionPlan(client_mode="int8_stochastic",
                                group_mode="int8_stochastic",
                                error_feedback=False))),
        "topk_ef": _run("topk_ef", T, chunk, compression=topk, **kw),
        "topk_noef": _run("topk_noef", T, chunk, **kw, compression=(
            api.CompressionPlan(client_mode="topk", group_mode="topk",
                                topk_frac=TOPK_FRAC,
                                error_feedback=False))),
    }
    for name, r in runs.items():
        print(f"  {name:14s} loss {r['initial_loss']:10.3e} -> "
              f"{r['final_loss']:10.3e}  "
              f"{r['bytes_per_round'] / 1e6:8.3f} MB/round")

    base = runs["uncompressed"]
    rel_gap = (runs["int8_ef"]["final_loss"] - base["final_loss"]) \
        / max(base["final_loss"], 1e-12)
    bytes_ratio = base["bytes_per_round"] \
        / max(runs["int8_ef"]["bytes_per_round"], 1.0)
    ef_factor = runs["topk_noef"]["final_loss"] \
        / max(runs["topk_ef"]["final_loss"], 1e-12)
    claims = {
        "int8_ef_loss_ok": rel_gap <= LOSS_GAP,
        "int8_bytes_ratio_ok": bytes_ratio >= BYTES_RATIO,
        "ef_off_worse": ef_factor >= EF_WORSE_FACTOR,
    }
    print(f"[bench_compression] int8+EF rel loss gap {rel_gap:+.4f} "
          f"(target <= {LOSS_GAP}), bytes ratio {bytes_ratio:.2f}x "
          f"(target >= {BYTES_RATIO}), EF-off worse {ef_factor:.2f}x "
          f"(target >= {EF_WORSE_FACTOR})")

    out = {
        "backend": jax.default_backend(),
        "config": {"G": G, "K": K, "n": n, "T": T, "chunk": chunk,
                   "topk_frac": TOPK_FRAC},
        "runs": runs,
        "int8_ef_rel_loss_gap": rel_gap,
        "int8_bytes_ratio": bytes_ratio,
        "ef_off_factor": ef_factor,
        "targets": {"loss_gap": LOSS_GAP, "bytes_ratio": BYTES_RATIO,
                    "ef_worse_factor": EF_WORSE_FACTOR},
        "claims": claims,
        "all_claims_ok": all(claims.values()),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "BENCH_comm.json"
    path.write_text(json.dumps(out, indent=2))
    print(f"[bench_compression] claims "
          f"{'all OK' if out['all_claims_ok'] else 'FAILED: ' + str(claims)} "
          f"-> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--quick", action="store_true", default=True,
                       help="CI-sized config (default)")
    group.add_argument("--full", action="store_true",
                       help="bigger state, longer horizon")
    args = ap.parse_args()
    if args.full:
        out = bench(n=100_000, T=24)
    else:
        out = bench()
    if not out["all_claims_ok"]:
        raise SystemExit("compression claims FAILED")
