"""Paper Table 5.1: global rounds to reach a target accuracy as (E, H)
grow, per algorithm; speedups relative to HFedAvg; plus the
heterogeneity-immunity claim (alpha sweep)."""
from __future__ import annotations

from benchmarks.common import BenchSetup, report, rounds_to_accuracy, run_algorithm

ALGOS = ("hfedavg", "local_corr", "group_corr", "mtgc")


def main(quick: bool = True) -> None:
    setup = BenchSetup(rounds=40) if quick else BenchSetup.paper()
    target = 0.70 if quick else 0.80
    grid = [(2, 5), (4, 5), (4, 10)] if quick else [(10, 20), (20, 20), (10, 40)]
    rows = []
    speedup_growth = {}
    for (E, H) in grid:
        base = None
        for algo in ALGOS:
            hist = run_algorithm(setup, algo, E=E, H=H)
            r = rounds_to_accuracy(hist, target)
            if algo == "hfedavg":
                base = r
            sp = (base / r) if r not in (0, float("inf")) else float("nan")
            rows.append([E, H, algo, r, round(sp, 2)])
            if algo == "mtgc":
                speedup_growth[(E, H)] = sp
    report("table51_speedup", rows,
           ["E", "H", "algorithm", f"rounds_to_{target}", "speedup_vs_hfedavg"])
    sps = list(speedup_growth.values())
    print(f"[table51] MTGC speedup across growing (E,H): "
          f"{[round(s, 2) for s in sps]} "
          f"(claim: speedup grows with E*H -> {'OK' if sps[-1] >= sps[0] else 'MIXED'})")

    # heterogeneity immunity (Sec. 4 discussion): MTGC's rounds-to-target
    # stays flat as alpha drops (more non-iid); HFedAvg degrades.
    rows2 = []
    for alpha in ([10.0, 0.1] if quick else [100.0, 1.0, 0.1]):
        for algo in ("hfedavg", "mtgc"):
            hist = run_algorithm(setup, algo, alpha=alpha)
            rows2.append([alpha, algo, rounds_to_accuracy(hist, target),
                          hist["acc"][-1]])
    report("table51_heterogeneity", rows2,
           ["alpha", "algorithm", f"rounds_to_{target}", "final_acc"])


if __name__ == "__main__":
    import sys
    main(quick="--full" not in sys.argv)
