"""Benchmark orchestrator: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run             # quick (CPU) profile
    PYTHONPATH=src python -m benchmarks.run --full      # paper-scale
    PYTHONPATH=src python -m benchmarks.run --only fig3_fl_baselines

The dry-run-derived roofline table is assembled from
benchmarks/results/dryrun (see ``python -m repro.launch.dryrun --all``).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale settings")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        ablation_beyond,
        fig11_three_level,
        fig3_fl_baselines,
        fig4_corrections,
        fig5_system_params,
        fig7_comm_cost,
        fig_participation,
        roofline,
        table51_speedup,
    )

    suites = {
        "fig3_fl_baselines": lambda: fig3_fl_baselines.main(quick=not args.full),
        "fig4_corrections": lambda: fig4_corrections.main(quick=not args.full),
        "fig_participation": lambda: fig_participation.main(quick=not args.full),
        "table51_speedup": lambda: table51_speedup.main(quick=not args.full),
        "fig5_system_params": lambda: fig5_system_params.main(quick=not args.full),
        "fig7_comm_cost": lambda: fig7_comm_cost.main(quick=not args.full),
        "fig11_three_level": lambda: fig11_three_level.main(quick=not args.full),
        "ablation_beyond": lambda: ablation_beyond.main(quick=not args.full),
        "roofline": lambda: (roofline.main("baseline"),
                             roofline.main("optimized")),
    }
    if args.only:
        suites = {args.only: suites[args.only]}
    for name, fn in suites.items():
        t0 = time.time()
        print(f"\n===== {name} =====")
        fn()
        print(f"===== {name} done in {time.time() - t0:.1f}s =====")


if __name__ == "__main__":
    main()
