"""End-to-end system tests: the production launchers on reduced configs,
the multi-pod dry-run machinery (in a subprocess -- it forces 512 host
devices), and the partition-spec rules."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_train_cli_smoke():
    """examples deliverable (b): train a reduced arch end-to-end, loss drops."""
    from repro.configs import get_arch
    from repro.data.lm import lm_batches, make_lm_tokens
    from repro.launch.train import make_sharded_round, sharded_init
    from repro.models.transformer import build_model

    cfg = get_arch("glm4-9b").reduced()
    bundle = build_model(cfg)
    rng = np.random.default_rng(0)
    toks, _ = make_lm_tokens(rng, cfg.vocab_size, 50_000, num_domains=4)
    params = bundle.init(jax.random.PRNGKey(0))
    state = sharded_init(params, 2, 2)
    rf = jax.jit(make_sharded_round(bundle.loss, E=2, H=2, lr=0.1))
    losses = []
    for _ in range(4):
        batch = lm_batches(toks, rng, (2, 2, 1, 2, 2, 2), 64)
        state, m = rf(state, batch)
        losses.append(float(m.loss.mean()))
    assert losses[-1] < losses[0], losses


def test_param_specs_cover_every_leaf():
    """Every parameter leaf of every arch gets a valid PartitionSpec whose
    sharded dims divide evenly on the planned mesh."""
    from repro.configs import ARCH_IDS, get_arch, get_plan
    from repro.models.transformer import build_model
    from repro.sharding import specs as sp

    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        plan = get_plan(arch)
        g, k, f, m = plan.train_factors
        axis_sizes = {"group": g, "client": k, "fsdp": f, "model": m}
        bundle = build_model(cfg)
        shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        tree = sp.param_spec_tree(shapes, axis_sizes=axis_sizes, cfg=cfg)

        def check(path, spec, leaf):
            assert len(spec) <= len(leaf.shape), (arch, path)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is not None:
                    assert dim % axis_sizes[ax] == 0, (arch, path, dim, ax)

        jax.tree_util.tree_map_with_path(check, tree, shapes)


def test_mesh_plans_factor_the_pod():
    from repro.configs import ARCH_IDS, get_plan
    for arch in ARCH_IDS:
        plan = get_plan(arch)
        plan.validate(256)
        g, k, f, m = plan.train_factors
        assert g * k * f * m == 256


def test_shape_skip_rules():
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.shapes import SkipShape, serve_specs

    expected_skips = {"internvl2-26b", "whisper-medium", "glm4-9b",
                      "qwen2.5-32b", "qwen3-14b", "granite-moe-1b-a400m"}
    skipped = set()
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        try:
            serve_specs(cfg, "long_500k")
        except SkipShape:
            skipped.add(arch)
    assert skipped == expected_skips


@pytest.mark.slow
def test_dryrun_subprocess_smoke():
    """One real (arch x shape x mesh) dry-run in a subprocess (the forced
    512-device env must not leak into this test process)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-1b-a400m", "--shape", "decode_32k", "--mesh", "pod",
         "--tag", "pytest", "--out", "/tmp/dryrun_pytest"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.loads(open(
        "/tmp/dryrun_pytest/granite-moe-1b-a400m__decode_32k__pod__pytest.json").read())
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["terms"]["compute_s"] > 0
    assert rec["memory"]["temp_size_in_bytes"] < 16e9  # fits v5e HBM


@pytest.mark.slow
def test_serve_generation_loop():
    """batched serving: prefill + greedy decode stays finite and identical
    across batch entries with identical prompts."""
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models.transformer import build_model

    cfg = get_arch("rwkv6-1.6b").reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, T, GEN = 3, 12, 6
    toks = np.tile(np.arange(T, dtype=np.int32)[None], (B, 1))
    cache = bundle.init_cache(B, T + GEN)
    lg, cache = bundle.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    for i in range(GEN - 1):
        lg, cache = bundle.decode_step(
            params, {"token": tok, "index": jnp.asarray(T + i, jnp.int32)}, cache)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = np.asarray(jnp.concatenate(outs, 1))
    assert (gen == gen[0]).all()  # identical prompts -> identical streams


def test_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import latest_step, restore, save
    from repro.launch.train import sharded_init

    state = sharded_init({"w": jnp.arange(6, dtype=jnp.float32)}, 2, 2)
    save(str(tmp_path / "ck"), 7, state._asdict())
    assert latest_step(str(tmp_path / "ck")) == 7
    like = jax.tree.map(np.zeros_like, state._asdict())
    got = restore(str(tmp_path / "ck"), 7, like)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(state.params["w"]))


def test_serve_specs_kv_split_alignment():
    """kv-split serve meshes: head dims shard over 'kv' only, dense dims
    over ('kv','tp'); cache kv-head dim matches the attention sharding."""
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.shapes import serve_specs
    from repro.launch.mesh import serve_kv_split
    from repro.models.transformer import build_model
    from repro.sharding import specs as sp

    for arch in ("qwen2.5-32b", "glm4-9b", "mixtral-8x22b", "gemma3-27b"):
        cfg = get_arch(arch)
        kv = serve_kv_split(cfg.num_heads, cfg.num_kv_heads)
        assert kv > 1, arch
        axis_sizes = {"data": 16, "kv": kv, "tp": 16 // kv}
        shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        tree = sp.serve_param_specs(cfg, shapes, axis_sizes)

        def check(path, spec, leaf):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                sz = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    sz *= axis_sizes[a]
                assert dim % sz == 0, (arch, path, dim, ax)

        jax.tree_util.tree_map_with_path(check, tree, shapes)
