"""Cross-backend conformance of the unified front door (repro.api).

``build(spec)`` must be *the same program* as the legacy constructors:
for every algorithm x backend x state layout the adapter-built engine is
driven over the identical packed dataset as the legacy
``make_*_round`` path and must match state-for-state (bit-exact) after 2
global rounds. Combinations a backend does not implement must be rejected
by ``spec.validate()`` with a ``ValueError`` -- never built silently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (
    ALGORITHMS,
    HFLConfig,
    PackedBatches,
    hfl_init,
    make_global_round,
    make_multilevel_round,
    multilevel_init,
    run_rounds,
    select_round,
)
from repro.launch.train import make_sharded_round, sharded_init

from test_mtgc_engine import D, quad_loss

G, K, E, H, T = 2, 3, 2, 2, 2


def make_data(microbatches=None, seed=0, key=1):
    rng = np.random.default_rng(seed)
    steps = H * (microbatches or 1)
    shape = (G, K, 4, steps, D)
    arrays = {
        "a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
        "b": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
    }
    return PackedBatches(arrays, jax.random.PRNGKey(key), E, H, microbatches)


def make_spec(algo, backend, layout, **kw):
    return api.ExperimentSpec(
        levels=(G, K),
        schedule=api.RoundSchedule(
            group_rounds=E, local_steps=H,
            microbatches=1 if backend == "sharded" else None),
        algorithm=algo, lr=0.05, backend=backend, state_layout=layout,
        prox_mu=0.1 if algo == "fedprox" else 0.0,
        feddyn_alpha=0.1 if algo == "feddyn" else 0.0,
        **kw)


def assert_states_equal(got, want, tag):
    leaves_got = jax.tree.leaves(got)
    leaves_want = jax.tree.leaves(want)
    assert len(leaves_got) == len(leaves_want), tag
    for i, (a, b) in enumerate(zip(leaves_got, leaves_want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag}[leaf {i}]")


def run_legacy_multilevel(round_fn, state, data, rounds):
    """Drive the legacy [P_1, *dims, ...] contract over the same packed
    dataset / selection keys as the driver."""
    rng = data.rng
    for _ in range(rounds):
        key, rng = jax.random.split(rng)
        batches = select_round(data, key)
        merged = jax.tree.map(lambda b: b.reshape((E * H,) + b.shape[2:]),
                              batches)
        state, _ = round_fn(state, merged)
    return state


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("backend", api.BACKENDS)
@pytest.mark.parametrize("algo", ALGORITHMS)
def test_build_matches_legacy_constructor(algo, backend, layout):
    spec = make_spec(algo, backend, layout)
    if algo not in api.BACKEND_ALGORITHMS[backend]:
        with pytest.raises(ValueError):
            api.build(spec, quad_loss)
        return

    engine = api.build(spec, quad_loss)
    assert isinstance(engine, api.Engine)
    assert "loss" in engine.metric_fields
    params0 = {"w": jnp.zeros(D)}
    tag = f"{algo}/{backend}/{layout}"

    if backend == "simulator":
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.05, algorithm=algo,
                        prox_mu=spec.prox_mu, feddyn_alpha=spec.feddyn_alpha,
                        use_flat_state=layout == "flat")
        legacy_rf = make_global_round(quad_loss, cfg)
        legacy_state, _, _ = run_rounds(
            legacy_rf, hfl_init(params0, cfg), make_data(), T, donate=False)
    elif backend == "sharded":
        legacy_rf = make_sharded_round(quad_loss, E=E, H=H, lr=0.05,
                                       algorithm=algo)
        legacy_state, _, _ = run_rounds(
            legacy_rf,
            sharded_init(params0, G, K, use_flat_state=layout == "flat"),
            make_data(microbatches=1), T, donate=False)
    else:
        legacy_rf = make_multilevel_round(quad_loss, (G, K), (E * H, H), 0.05)
        legacy_state = run_legacy_multilevel(
            jax.jit(legacy_rf),
            multilevel_init(params0, (G, K), use_flat_state=layout == "flat"),
            make_data(), T)

    data = make_data(microbatches=1 if backend == "sharded" else None)
    state, _ = api.fit(engine, data, T, params=params0, donate=False)
    assert_states_equal(state, legacy_state, tag)

    # The global model is readable through the uniform surface either way.
    gm = engine.global_model(state)
    assert np.asarray(gm["w"]).shape == (D,)


@pytest.mark.parametrize("backend", ["simulator", "sharded"])
@pytest.mark.parametrize("weighting", ["none", "inverse_prob"])
def test_partial_participation_conformance(backend, weighting):
    """Masks, weighting and rng advance identically through build() and the
    legacy constructors (both levels partially sampled)."""
    kw = dict(client_participation=0.5, group_participation=0.75,
              participation_mode="uniform", participation_weighting=weighting)
    spec = make_spec("mtgc", backend, "flat", **kw)
    engine = api.build(spec, quad_loss)
    params0 = {"w": jnp.zeros(D)}
    rng0 = jax.random.PRNGKey(9)

    if backend == "simulator":
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.05, algorithm="mtgc",
                        use_flat_state=True, **kw)
        legacy_rf = make_global_round(quad_loss, cfg)
        legacy_state = hfl_init(params0, cfg, rng0)
        data = make_data()
    else:
        legacy_rf = make_sharded_round(quad_loss, E=E, H=H, lr=0.05, **kw)
        legacy_state = sharded_init(params0, G, K, use_flat_state=True,
                                    rng=rng0)
        data = make_data(microbatches=1)
    legacy_state, _, _ = run_rounds(legacy_rf, legacy_state, data, T,
                                    donate=False)

    data = make_data(microbatches=1 if backend == "sharded" else None)
    state, _ = api.fit(engine, data, T, params=params0, rng=rng0,
                       donate=False)
    assert_states_equal(state, legacy_state, f"partial/{backend}/{weighting}")


def test_multilevel_partial_participation_conformance():
    spec = make_spec("mtgc", "multilevel", "tree",
                     level_participation=(0.75, 0.5),
                     participation_weighting="inverse_prob")
    engine = api.build(spec, quad_loss)
    params0 = {"w": jnp.zeros(D)}
    rng0 = jax.random.PRNGKey(4)

    legacy_rf = make_multilevel_round(
        quad_loss, (G, K), (E * H, H), 0.05, participation=(0.75, 0.5),
        participation_weighting="inverse_prob")
    legacy_state = run_legacy_multilevel(
        jax.jit(legacy_rf), multilevel_init(params0, (G, K), rng0),
        make_data(), T)

    state, _ = api.fit(engine, make_data(), T, params=params0, rng=rng0,
                       donate=False)
    assert_states_equal(state, legacy_state, "partial/multilevel")


def test_sharded_correction_dtype_conformance():
    spec = make_spec("mtgc", "sharded", "tree", correction_dtype="bfloat16")
    engine = api.build(spec, quad_loss)
    state = engine.init({"w": jnp.zeros(D)})
    want = sharded_init({"w": jnp.zeros(D)}, G, K,
                        correction_dtype=jnp.bfloat16)
    assert state.z["w"].dtype == want.z["w"].dtype == jnp.bfloat16
    state2, _ = api.fit(engine, make_data(microbatches=1), T, state=state,
                        donate=False)
    legacy_rf = make_sharded_round(quad_loss, E=E, H=H, lr=0.05)
    want2, _, _ = run_rounds(legacy_rf, want, make_data(microbatches=1), T,
                             donate=False)
    assert_states_equal(state2, want2, "correction_dtype")


def test_three_level_fit_runs_and_preserves_invariants():
    """The generalized driver packing drives a 3-level topology end to end
    through build()/fit(); level-1 corrections sum to zero over groups."""
    dims, periods = (2, 2, 2), (4, 2, 1)
    spec = api.ExperimentSpec(levels=dims, backend="multilevel", lr=0.05,
                              schedule=api.RoundSchedule(periods=periods),
                              state_layout="tree")
    engine = api.build(spec, quad_loss)
    rng = np.random.default_rng(3)
    shape = dims + (3, periods[-1], D)
    data = PackedBatches(
        {"a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
         "b": jnp.asarray(rng.normal(size=shape).astype(np.float32))},
        jax.random.PRNGKey(1), periods[0] // periods[-1], periods[-1],
        None, topo_ndim=3)
    state, hz = api.fit(engine, data, 3, params={"w": jnp.zeros(D)},
                        donate=False)
    assert np.asarray(hz.metrics.loss).shape == (3, periods[0])
    nu1 = state.nus[0]["w"]
    np.testing.assert_allclose(np.asarray(nu1).sum(axis=0), 0.0, atol=1e-5)


# ------------------------------------------------- validation (satellite)


def test_hfl_config_validate_raises_value_error():
    """Bare asserts vanish under ``python -O``; config validation must be
    real raises (mirrored by ExperimentSpec.validate below)."""
    bad = [
        dict(num_groups=0),
        dict(local_steps=0),
        dict(correction_init="warm"),
        dict(client_participation=0.0),
        dict(group_participation=1.5),
        dict(participation_mode="roundrobin"),
        dict(participation_weighting="ht"),
        dict(use_fused_update=True, algorithm="hfedavg"),
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            HFLConfig(**kw).validate()
    assert HFLConfig().validate() is not None


def test_experiment_spec_validate_raises_value_error():
    good = api.ExperimentSpec()
    assert good.validate() is good
    bad = [
        dict(levels=(0, 2)),
        dict(levels=(4,)),
        dict(backend="tpu"),
        dict(algorithm="sgd"),
        dict(algorithm="fedprox", backend="sharded"),
        dict(algorithm="hfedavg", backend="multilevel"),
        dict(levels=(2, 2, 2), backend="simulator"),
        dict(state_layout="packed"),
        dict(fusion="fused", algorithm="hfedavg"),
        dict(fusion="fused", backend="multilevel"),
        dict(fused_mode="interpret"),                    # simulator backend
        dict(correction_dtype="bfloat16"),               # simulator backend
        dict(correction_dtype="bfloat16", backend="sharded"),  # flat layout
        dict(correction_init="gradient", backend="sharded"),
        dict(prox_mu=0.1, backend="sharded", algorithm="mtgc"),
        dict(server_lr=0.5, backend="sharded"),
        dict(client_participation=0.0),
        dict(participation_mode="roundrobin"),
        dict(participation_weighting="ht"),
        dict(level_participation=(0.5, 0.5)),            # simulator backend
        dict(level_participation=(0.5,), backend="multilevel"),
        dict(schedule=api.RoundSchedule(group_rounds=0)),
        dict(schedule=api.RoundSchedule(local_steps=0)),
        dict(schedule=api.RoundSchedule(microbatches=2)),  # simulator
        dict(schedule=api.RoundSchedule(periods=(4, 3)), backend="multilevel"),
        dict(schedule=api.RoundSchedule(periods=(4, 2, 1))),  # 2 levels
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            api.ExperimentSpec(**kw).validate()


def test_async_schedule_hook_is_live():
    """Per-group E -- the async-rounds hook -- is implemented: a uniform
    tuple collapses to the scalar schedule, a non-uniform tuple validates
    (async group rounds; see tests/test_async_rounds.py), and a
    wrong-length tuple still raises."""
    uni = api.ExperimentSpec(
        schedule=api.RoundSchedule(group_rounds=(3, 3))).validate()
    assert uni.schedule.uniform_group_rounds == 3
    het = api.ExperimentSpec(
        schedule=api.RoundSchedule(group_rounds=(2, 3))).validate()
    assert het.schedule.max_group_rounds == 3
    with pytest.raises(ValueError):  # one entry per group
        api.ExperimentSpec(
            schedule=api.RoundSchedule(group_rounds=(2, 2, 2))).validate()


def test_fit_horizon_data_continues_the_run():
    """hz.data carries the advanced selection rng: two chained fits are
    bit-exact against one long horizon (reusing the original data object
    would replay the first segment's shard draws)."""
    spec = make_spec("mtgc", "simulator", "flat")
    engine = api.build(spec, quad_loss)
    params0 = {"w": jnp.zeros(D)}

    s_long, hz_long = api.fit(engine, make_data(), 4, params=params0,
                              donate=False)
    s_a, hz_a = api.fit(engine, make_data(), 2, params=params0, donate=False)
    s_b, hz_b = api.fit(engine, hz_a.data, 2, state=s_a, donate=False)
    assert_states_equal(s_b, s_long, "continued-horizon")
    np.testing.assert_array_equal(np.asarray(hz_b.data.rng),
                                  np.asarray(hz_long.data.rng))


def test_schedule_periods_conflict_rejected():
    """periods are authoritative; an explicitly different E/H must raise
    instead of being silently ignored (defaults count as unset)."""
    ok_default = api.ExperimentSpec(
        levels=(2, 2), backend="multilevel",
        schedule=api.RoundSchedule(periods=(8, 4)))
    assert ok_default.validate() is ok_default
    ok_consistent = api.ExperimentSpec(
        levels=(2, 2), backend="multilevel",
        schedule=api.RoundSchedule(group_rounds=2, local_steps=4,
                                   periods=(8, 4)))
    ok_consistent.validate()
    with pytest.raises(ValueError):
        api.ExperimentSpec(
            levels=(2, 2), backend="multilevel",
            schedule=api.RoundSchedule(group_rounds=5, local_steps=2,
                                       periods=(8, 4))).validate()


def test_participation_masks_match_round_mask_schedule():
    """engine.participation_masks reproduces exactly the draw the round
    functions make from a pre-round state rng."""
    from repro.core import round_masks

    spec = make_spec("mtgc", "simulator", "flat", client_participation=0.5,
                     group_participation=0.75)
    engine = api.build(spec, quad_loss)
    rng = jax.random.PRNGKey(21)
    masks, nxt = engine.participation_masks(rng)
    want, want_nxt = round_masks(rng, spec.to_hfl_config())
    np.testing.assert_array_equal(np.asarray(masks.client),
                                  np.asarray(want.client))
    np.testing.assert_array_equal(np.asarray(masks.group),
                                  np.asarray(want.group))
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(want_nxt))

    with pytest.raises(ValueError):
        api.build(api.ExperimentSpec(
            levels=(2, 2, 2), backend="multilevel",
            schedule=api.RoundSchedule(periods=(4, 2, 1)),
        ), quad_loss).participation_masks(rng)


def test_uniform_tuple_schedule_builds_identically():
    import dataclasses

    params0 = {"w": jnp.zeros(D)}
    base = make_spec("mtgc", "simulator", "flat")
    tup = dataclasses.replace(
        base, schedule=api.RoundSchedule(group_rounds=(E,) * G,
                                         local_steps=H))
    s1, _ = api.fit(api.build(base, quad_loss), make_data(), T,
                    params=params0, donate=False)
    s2, _ = api.fit(api.build(tup, quad_loss), make_data(), T,
                    params=params0, donate=False)
    assert_states_equal(s1, s2, "uniform-tuple-schedule")
