"""Participation-weighting semantics: unbiasedness gates, engine/sharded
parity, and the empty-group freeze guard.

The statistical gates run Monte-Carlo batches of *whole engine rounds* --
R trajectories with independent mask streams vmapped into one compiled
horizon (``run_rounds`` over a vmapped round function) -- and compare the
disseminated global aggregate against the exact full-participation
reference on the same deterministic quadratic data:

* one group round per global round (E=1), synced start: the masked global
  aggregate under ``inverse_prob`` is *exactly* unbiased, so its MC error
  is pure noise shrinking ~1/sqrt(R);
* multi-round MTGC (E=2, T=4): the realized-count estimator's denominator
  noise feeds the z/y corrections and compounds into a systematic bias
  many sigma above the MC noise, which ``inverse_prob`` cuts by ~3x on the
  same seed set.

All seeds are fixed, so the gates are deterministic; thresholds carry wide
margins relative to the measured values. The MC harness itself lives in
``benchmarks.fig_participation`` (the same code that emits the
BENCH_participation.json CI artifact), so the gated statistic and the
published numbers measure the same estimator readout by construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fig_participation import (
    full_participation_reference,
    mc_participation_aggregates,
)
from repro.core import (
    ALGORITHMS,
    HFLConfig,
    as_tree,
    hfl_init,
    make_global_round,
    round_masks,
    run_rounds,
)
from repro.core import multilevel as ml
from repro.launch.train import make_sharded_round, sharded_init

from test_mtgc_engine import D, make_batches, quad_loss


def _mc_aggregates(weighting, *, E, H, T, R):
    # traj_key pinned: the gate thresholds below were calibrated on it.
    return mc_participation_aggregates(weighting, E=E, H=H, T=T, R=R,
                                       traj_key=7)


_full_reference = full_participation_reference


# --------------------------------------------------- statistical gates


def test_inverse_prob_unbiased_single_timescale():
    """E=1 from a synced start: each client's local trajectory is mask-
    independent, so the Horvitz-Thompson aggregate is exactly unbiased --
    the MC error of its mean is pure noise, shrinking ~1/sqrt(R)."""
    R = 2048
    agg, ok = _mc_aggregates("inverse_prob", E=1, H=2, T=1, R=R)
    full = _full_reference(E=1, H=2, T=1)[0]
    a = agg[0]
    assert ok[0].all()  # p=0.5 over 24 clients: empty rounds are ~1e-8

    errs = {}
    for r in (128, 512, 2048):
        errs[r] = np.linalg.norm(a[:r].mean(axis=0) - full)
    # Analytic MC noise floor for the full batch: sqrt(sum_d var_d / R).
    se = np.sqrt((a.var(axis=0) / R).sum())
    assert errs[2048] < 4.0 * se, (errs, se)
    # ~1/sqrt(R): 16x the samples should shrink the error ~4x; require 2x.
    assert errs[2048] < 0.5 * errs[128], errs


def test_none_bias_compounds_and_inverse_prob_reduces_it():
    """Multi-round MTGC (E=2, T=4): realized-count weighting accumulates a
    systematic bias far above the MC noise; inverse_prob cuts it well below
    half on the same seeds (measured ~3.8x at large R; the HT trajectory
    distribution is heavy-tailed, so the gate uses R large enough for its
    mean-norm to stabilize; see BENCH_participation.json)."""
    R, T = 1536, 4
    full = _full_reference(E=2, H=2, T=T)[T - 1]
    bias, se = {}, {}
    for w in ("none", "inverse_prob"):
        agg, ok = _mc_aggregates(w, E=2, H=2, T=T, R=R)
        a = agg[T - 1][ok[T - 1]]
        bias[w] = np.linalg.norm(a.mean(axis=0) - full)
        se[w] = np.sqrt((a.var(axis=0) / len(a)).sum())
    # 'none' is measurably biased: many sigma above its noise floor.
    assert bias["none"] > 8.0 * se["none"], (bias, se)
    # inverse_prob's compounded bias is at most ~half of none's.
    assert bias["inverse_prob"] < 0.55 * bias["none"], (bias, se)


# ------------------------------------------- exactness / coincidence gates


def test_full_participation_bitexact_with_weighting_enabled():
    """C=1 compiles the weighting machinery out entirely: the program is
    bit-for-bit the unweighted engine for every algorithm."""
    Gs, Ks, E, H = 2, 3, 2, 2
    _, _, batches = make_batches(Gs, Ks, E, H, seed=5)
    jb = jax.tree.map(jnp.asarray, batches)
    for algo in ALGORITHMS:
        kw = dict(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                  group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
                  feddyn_alpha=0.1)
        st0 = hfl_init({"w": jnp.zeros(D)}, HFLConfig(**kw))
        s_plain, _ = jax.jit(make_global_round(quad_loss, HFLConfig(**kw)))(
            st0, jb)
        s_w, _ = jax.jit(make_global_round(
            quad_loss,
            HFLConfig(**kw, participation_weighting="inverse_prob")))(st0, jb)
        for name in ("params", "z", "y", "dyn"):
            np.testing.assert_array_equal(
                np.asarray(as_tree(getattr(s_plain, name))["w"]),
                np.asarray(as_tree(getattr(s_w, name))["w"]),
                err_msg=f"{algo}.{name}")


@pytest.mark.parametrize("algo", ["mtgc", "hfedavg"])
def test_fixed_mode_weightings_coincide(algo):
    """Under 'fixed' sampling the realized count equals the expected count,
    so both weightings compute the identical program output."""
    Gs, Ks, E, H = 2, 4, 2, 2
    _, _, batches = make_batches(Gs, Ks, E, H, seed=9)
    jb = jax.tree.map(jnp.asarray, batches)
    outs = {}
    for w in ("none", "inverse_prob"):
        cfg = HFLConfig(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                        group_rounds=E, lr=0.05, algorithm=algo,
                        client_participation=0.5, group_participation=0.5,
                        participation_mode="fixed",
                        participation_weighting=w)
        st = hfl_init({"w": jnp.zeros(D)}, cfg)
        rf = jax.jit(make_global_round(quad_loss, cfg))
        for _ in range(3):
            st, _ = rf(st, jb)
        outs[w] = st
    for name in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(as_tree(getattr(outs["none"], name))["w"]),
            np.asarray(as_tree(getattr(outs["inverse_prob"], name))["w"]),
            rtol=1e-6, atol=1e-7, err_msg=name)


def test_multilevel_fixed_mode_weightings_coincide():
    dims, periods, lr = (2, 2, 3), (8, 4, 2), 0.05
    rng = np.random.default_rng(12)
    sh = (8,) + dims + (D,)
    batches = {"a": jnp.asarray(rng.normal(size=sh).astype(np.float32) + 2.0),
               "b": jnp.asarray(rng.normal(size=sh).astype(np.float32))}
    outs = {}
    for w in ("none", "inverse_prob"):
        st = ml.multilevel_init({"w": jnp.zeros(D)}, dims)
        rf = jax.jit(ml.make_multilevel_round(
            quad_loss, dims, periods, lr, participation=(0.5, 1.0, 0.5),
            participation_mode="fixed", participation_weighting=w))
        for _ in range(3):
            st, losses = rf(st, batches)
        outs[w] = st
        assert np.isfinite(np.asarray(losses)).all()
    np.testing.assert_allclose(np.asarray(outs["none"].params["w"]),
                               np.asarray(outs["inverse_prob"].params["w"]),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("weighting", ["none", "inverse_prob"])
def test_multilevel_two_level_matches_engine_under_partial(weighting):
    """M=2 multilevel under uniform partial participation reproduces the
    two-level engine replica-for-replica for both weightings (same rng =>
    same masks; the key schedules coincide). In particular the multilevel
    hierarchy must apply the HT denominator only at estimation steps --
    re-aggregating already-disseminated values is recovery and must be
    count-normalized (regression: a fixed denominator there rescales the
    model by realized/expected count)."""
    Gs, Ks, E, H, lr = 2, 3, 2, 2, 0.05
    _, _, batches = make_batches(Gs, Ks, E, H, seed=17)
    jb = jax.tree.map(jnp.asarray, batches)
    mb = {k: v.reshape((E * H,) + v.shape[2:]) for k, v in jb.items()}

    cfg = HFLConfig(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc",
                    client_participation=0.5, group_participation=0.75,
                    participation_mode="uniform",
                    participation_weighting=weighting, use_flat_state=False)
    key = jax.random.PRNGKey(13)
    st2 = hfl_init({"w": jnp.zeros(D)}, cfg, rng=key)
    rf2 = jax.jit(make_global_round(quad_loss, cfg))
    stM = ml.multilevel_init({"w": jnp.zeros(D)}, (Gs, Ks), rng=key)
    rfM = jax.jit(ml.make_multilevel_round(
        quad_loss, (Gs, Ks), (E * H, H), lr,
        participation=(0.75, 0.5), participation_mode="uniform",
        participation_weighting=weighting))
    for _ in range(3):
        st2, _ = rf2(st2, jb)
        stM, _ = rfM(stM, mb)
        np.testing.assert_allclose(
            np.asarray(stM.params["w"]),
            np.asarray(as_tree(st2.params)["w"]),
            rtol=1e-5, atol=1e-6)
        # nu_1 is the engine's y (same update, same gating).
        np.testing.assert_allclose(
            np.asarray(stM.nus[0]["w"]),
            np.asarray(as_tree(st2.y)["w"]),
            rtol=1e-5, atol=1e-6)


def test_multilevel_inverse_prob_freezes_inactive_subtree():
    """The frozen-subtree invariant survives HT weighting (uniform mode)."""
    from repro.core import participation as pp

    dims, periods, lr = (2, 2, 2), (8, 4, 2), 0.05
    rng = np.random.default_rng(13)
    a = rng.normal(size=dims + (D,)).astype(np.float32) + 2.0
    b = rng.normal(size=dims + (D,)).astype(np.float32)
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (8,) + a.shape).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (8,) + b.shape).copy()),
    }
    st = ml.multilevel_init({"w": jnp.zeros(D)}, dims)
    rf = jax.jit(ml.make_multilevel_round(
        quad_loss, dims, periods, lr, participation=(0.5, 1.0, 1.0),
        participation_mode="fixed", participation_weighting="inverse_prob"))
    for _ in range(3):
        mkey, _ = jax.random.split(st.rng)
        keys = jax.random.split(mkey, 3)
        m1 = np.asarray(pp.sample_axis_mask(keys[0], (2,), 0.5, "fixed"))
        off = int(np.argmin(m1))
        p0 = np.asarray(st.params["w"])
        nu0 = np.asarray(st.nus[0]["w"])
        st, losses = rf(st, batches)
        np.testing.assert_array_equal(np.asarray(st.params["w"])[off], p0[off])
        np.testing.assert_array_equal(np.asarray(st.nus[0]["w"])[off], nu0[off])
        assert not np.allclose(np.asarray(st.params["w"])[1 - off],
                               p0[1 - off])
        assert np.isfinite(np.asarray(losses)).all()


# --------------------------------------------------- empty-group freeze


def _empty_group_seed(cfg, want_empty=0, tries=256):
    """A PRNG seed whose round-0 draw leaves group ``want_empty`` with no
    active clients while the other group has at least one."""
    for s in range(tries):
        masks, _ = round_masks(jax.random.PRNGKey(s), cfg)
        cm = np.asarray(masks.client)
        if cm[want_empty].sum() == 0 and cm[1 - want_empty].sum() > 0:
            return s
    raise AssertionError("no seed found")


@pytest.mark.parametrize("weighting", ["none", "inverse_prob"])
@pytest.mark.parametrize("algo", ALGORITHMS)
def test_all_empty_group_round_freezes_group_bitexact(algo, weighting):
    """A reachable group whose Bernoulli client draws all came up empty
    keeps params, z, y and dyn bit-exactly frozen -- proving the
    tree_masked_mean empty-slice fallback value is never observed under
    either weighting (it exists only to keep the program NaN-free)."""
    Gs, Ks, E, H = 2, 3, 2, 2
    _, _, batches = make_batches(Gs, Ks, E, H, seed=3)
    jb = jax.tree.map(jnp.asarray, batches)
    cfg = HFLConfig(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
                    feddyn_alpha=0.1, client_participation=0.02,
                    participation_mode="uniform",
                    participation_weighting=weighting)
    seed = _empty_group_seed(cfg)
    key = jax.random.PRNGKey(seed)
    # Start from a post-round-like state with nonzero corrections so a
    # spurious update cannot hide as 0 == 0.
    warm_cfg = HFLConfig(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                         group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
                         feddyn_alpha=0.1)
    st = hfl_init({"w": jnp.zeros(D)}, warm_cfg)
    st, _ = jax.jit(make_global_round(quad_loss, warm_cfg))(st, jb)
    st = st._replace(rng=key)

    before = {name: np.asarray(as_tree(getattr(st, name))["w"]).copy()
              for name in ("params", "z", "y", "dyn")}
    st2, m = jax.jit(make_global_round(quad_loss, cfg))(st, jb)
    assert np.isfinite(np.asarray(m.loss)).all()
    for name in ("params", "z", "dyn"):
        np.testing.assert_array_equal(
            np.asarray(as_tree(getattr(st2, name))["w"])[0],
            before[name][0], err_msg=f"{algo}/{weighting}.{name}")
    np.testing.assert_array_equal(
        np.asarray(as_tree(st2.y)["w"])[0], before["y"][0],
        err_msg=f"{algo}/{weighting}.y")


# --------------------------------------------------- sharded round parity


@pytest.mark.parametrize("weighting", ["none", "inverse_prob"])
@pytest.mark.parametrize("flat", [False, True], ids=["tree", "flat"])
def test_sharded_partial_matches_engine(weighting, flat):
    """The production round under partial participation computes exactly
    the simulator engine, state-for-state, for both weightings and both
    state layouts (same rng => same masks; Bernoulli client + group
    sampling)."""
    Gs, Ks, E, H, lr = 2, 3, 2, 2, 0.05
    _, _, batches = make_batches(Gs, Ks, E, H, seed=21)
    jb = jax.tree.map(jnp.asarray, batches)
    pb = {k: v[:, :, None] for k, v in jb.items()}
    kw = dict(client_participation=0.5, group_participation=0.75,
              participation_mode="uniform", participation_weighting=weighting)

    cfg = HFLConfig(num_groups=Gs, clients_per_group=Ks, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc",
                    use_flat_state=False, **kw)
    key = jax.random.PRNGKey(3)
    st_c = hfl_init({"w": jnp.zeros(D)}, cfg, rng=key)
    rf_c = jax.jit(make_global_round(quad_loss, cfg))
    st_p = sharded_init({"w": jnp.zeros(D)}, Gs, Ks, rng=key,
                        use_flat_state=flat)
    rf_p = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=lr, **kw))
    for _ in range(4):
        st_c, m_c = rf_c(st_c, jb)
        st_p, m_p = rf_p(st_p, pb)
    for name in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(as_tree(getattr(st_p, name))["w"]),
            np.asarray(as_tree(getattr(st_c, name))["w"]),
            rtol=1e-5, atol=1e-6, err_msg=name)
    # Same masks were drawn on both sides (rng streams advanced in lockstep).
    np.testing.assert_array_equal(np.asarray(st_p.rng), np.asarray(st_c.rng))
    np.testing.assert_allclose(float(m_p.participation),
                               float(m_c.participation), rtol=1e-6)


def test_sharded_partial_fused_matches_unfused():
    """The fused Pallas path (interpret off-TPU) applies the participation
    mask in-register identically to the where-gated reference."""
    Gs, Ks, E, H, lr = 2, 3, 2, 2, 0.05
    _, _, batches = make_batches(Gs, Ks, E, H, seed=22)
    pb = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}
    kw = dict(client_participation=0.5, participation_mode="uniform",
              participation_weighting="inverse_prob")
    key = jax.random.PRNGKey(5)
    states = {}
    for fused, flat in ((False, False), (True, False), (True, True)):
        st = sharded_init({"w": jnp.zeros(D)}, Gs, Ks, rng=key,
                          use_flat_state=flat)
        rf = jax.jit(make_sharded_round(
            quad_loss, E=E, H=H, lr=lr, use_fused_update=fused,
            fused_mode="interpret" if fused else None, **kw))
        for _ in range(3):
            st, _ = rf(st, pb)
        states[(fused, flat)] = st
    for combo in ((True, False), (True, True)):
        for name in ("params", "z", "y"):
            np.testing.assert_allclose(
                np.asarray(as_tree(getattr(states[combo], name))["w"]),
                np.asarray(as_tree(getattr(states[(False, False)], name))["w"]),
                rtol=1e-5, atol=1e-6, err_msg=f"{combo}/{name}")


def test_sharded_partial_requires_rng():
    rf = make_sharded_round(quad_loss, E=1, H=1, lr=0.1,
                            client_participation=0.5)
    st = sharded_init({"w": jnp.zeros(D)}, 2, 2)  # rng=None
    batches = {"a": jnp.ones((1, 1, 1, 2, 2, D)),
               "b": jnp.ones((1, 1, 1, 2, 2, D))}
    with pytest.raises(ValueError, match="rng"):
        rf(st, batches)


def test_sharded_full_participation_ignores_rng_default():
    """Default (full participation) rounds still run on rng-less states --
    the pre-weighting construction path keeps working."""
    Gs, Ks, E, H = 2, 2, 1, 2
    _, _, batches = make_batches(Gs, Ks, E, H, seed=23)
    pb = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}
    st = sharded_init({"w": jnp.zeros(D)}, Gs, Ks)
    assert st.rng is None
    rf = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=0.05))
    st, m = rf(st, pb)
    assert float(m.participation) == 1.0
    assert np.isfinite(np.asarray(m.loss)).all()


def test_driver_runs_sharded_partial_round():
    """The compiled horizon drives the masked production round; loop vs
    scan bit-exact (the participation rng lives in the donated state)."""
    from test_driver import _assert_bitexact, _loop, make_data

    rf = make_sharded_round(quad_loss, E=2, H=2, lr=0.05,
                            client_participation=0.5,
                            participation_weighting="inverse_prob")

    def init():
        return sharded_init({"w": jnp.zeros(D)}, 2, 3,
                            rng=jax.random.PRNGKey(11))

    state_l, data_l, metrics_l = _loop(rf, init(), make_data(microbatches=2),
                                       rounds=3)
    state_d, data_d, hz = run_rounds(rf, init(), make_data(microbatches=2),
                                     3, chunk=2, donate=False)
    _assert_bitexact(state_l, state_d, metrics_l, hz.metrics,
                     ("params", "z", "y"), "sharded-partial")
    np.testing.assert_array_equal(np.asarray(state_l.rng),
                                  np.asarray(state_d.rng))
