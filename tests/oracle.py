"""Pure-python (numpy, loops) oracle of paper Algorithm 1.

Deliberately written as literal transcription of the pseudocode -- no
vectorization tricks -- so the jax engines can be validated against it
bit-for-bit (full-batch deterministic gradients).
"""
from __future__ import annotations

import numpy as np


def mtgc_round(x0, grads, G, K, E, H, lr, z=None, y=None, use_z=True, use_y=True):
    """One global round of Algorithm 1 on a d-dimensional model.

    x0: [d] round-start model; grads(g, k, x) -> [d] full-batch gradient of
    client (g, k). z: [G, K, d], y: [G, d] (zero-initialized if None).
    Returns (x_new [d], z, y, client_traj dict for deeper checks).
    """
    d = x0.shape[0]
    z = np.zeros((G, K, d)) if z is None else z.copy()
    y = np.zeros((G, d)) if y is None else y.copy()
    xbar_j = np.stack([x0.copy() for _ in range(G)])     # group models

    for e in range(E):
        x = np.stack([[xbar_j[g].copy() for _ in range(K)] for g in range(G)])
        for h in range(H):
            for g in range(G):
                for k in range(K):
                    grad = grads(g, k, x[g, k])
                    x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
        new_xbar = np.stack([x[g].mean(axis=0) for g in range(G)])
        if use_z:
            for g in range(G):
                for k in range(K):
                    z[g, k] = z[g, k] + (x[g, k] - new_xbar[g]) / (H * lr)
        xbar_j = new_xbar
    xbar = xbar_j.mean(axis=0)
    if use_y:
        for g in range(G):
            y[g] = y[g] + (xbar_j[g] - xbar) / (H * E * lr)
    return xbar, z, y


def mtgc_async_run(x0, grads, G, K, group_rounds, H, lr, windows, *,
                   policy="naive", max_staleness=None):
    """``windows`` async MTGC global rounds (core/staleness.py semantics),
    as literal loops: per-group E_g over a padded max(E_g) window, report
    cadence r_g = ceil(e_pad / E_g) (clipped to max_staleness + 1), stale
    reports merged per ``policy``. Full participation only.

    Returns (x [G, K, d] replicas, z [G, K, d], y [G, d]).
    """
    import math

    d = x0.shape[0]
    e_pad = max(group_rounds)
    if policy == "sync":
        periods = [1] * G
    else:
        periods = [math.ceil(e_pad / e) for e in group_rounds]
        if max_staleness is not None:
            periods = [min(r, max_staleness + 1) for r in periods]
    dw = [1.0 / r if policy == "discount" else 1.0 for r in periods]
    e_eff = [e * r for e, r in zip(group_rounds, periods)]

    x = np.stack([[x0.copy() for _ in range(K)] for _ in range(G)])
    z = np.zeros((G, K, d))
    y = np.zeros((G, d))
    snap = np.stack([x0.copy() for _ in range(G)])
    glob = x0.copy()

    for t in range(windows):
        for g in range(G):
            if t % periods[g] == 0:                     # fresh download
                z[g] = 0.0
        for e in range(e_pad):
            for g in range(G):
                if e >= group_rounds[g]:                # past its E_g: frozen
                    continue
                for h in range(H):
                    for k in range(K):
                        grad = grads(g, k, x[g, k])
                        x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
                xbar_g = x[g].mean(axis=0)
                for k in range(K):
                    z[g, k] = z[g, k] + (x[g, k] - xbar_g) / (H * lr)
                    x[g, k] = xbar_g.copy()
        rep = [(t + 1) % r == 0 for r in periods]
        xbar_used = np.stack([
            x[g, 0] + (glob - snap[g]) if policy == "delay_compensated"
            else x[g, 0] for g in range(G)])
        w = np.array([r * dwg for r, dwg in zip(rep, dw)])
        xbar = (w[:, None] * xbar_used).sum(axis=0) / w.sum()
        for g in range(G):
            if rep[g]:
                y[g] = y[g] + (xbar_used[g] - xbar) / (e_eff[g] * H * lr)
                for k in range(K):
                    x[g, k] = xbar.copy()
                snap[g] = xbar.copy()
        glob = xbar.copy()
    return x, z, y
