"""Pure-python (numpy, loops) oracle of paper Algorithm 1.

Deliberately written as literal transcription of the pseudocode -- no
vectorization tricks -- so the jax engines can be validated against it
bit-for-bit (full-batch deterministic gradients).
"""
from __future__ import annotations

import numpy as np


def mtgc_round(x0, grads, G, K, E, H, lr, z=None, y=None, use_z=True, use_y=True):
    """One global round of Algorithm 1 on a d-dimensional model.

    x0: [d] round-start model; grads(g, k, x) -> [d] full-batch gradient of
    client (g, k). z: [G, K, d], y: [G, d] (zero-initialized if None).
    Returns (x_new [d], z, y, client_traj dict for deeper checks).
    """
    d = x0.shape[0]
    z = np.zeros((G, K, d)) if z is None else z.copy()
    y = np.zeros((G, d)) if y is None else y.copy()
    xbar_j = np.stack([x0.copy() for _ in range(G)])     # group models

    for e in range(E):
        x = np.stack([[xbar_j[g].copy() for _ in range(K)] for g in range(G)])
        for h in range(H):
            for g in range(G):
                for k in range(K):
                    grad = grads(g, k, x[g, k])
                    x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
        new_xbar = np.stack([x[g].mean(axis=0) for g in range(G)])
        if use_z:
            for g in range(G):
                for k in range(K):
                    z[g, k] = z[g, k] + (x[g, k] - new_xbar[g]) / (H * lr)
        xbar_j = new_xbar
    xbar = xbar_j.mean(axis=0)
    if use_y:
        for g in range(G):
            y[g] = y[g] + (xbar_j[g] - xbar) / (H * E * lr)
    return xbar, z, y
