"""Pure-python (numpy, loops) oracle of paper Algorithm 1.

Deliberately written as literal transcription of the pseudocode -- no
vectorization tricks -- so the jax engines can be validated against it
bit-for-bit (full-batch deterministic gradients).
"""
from __future__ import annotations

import numpy as np


def mtgc_round(x0, grads, G, K, E, H, lr, z=None, y=None, use_z=True, use_y=True):
    """One global round of Algorithm 1 on a d-dimensional model.

    x0: [d] round-start model; grads(g, k, x) -> [d] full-batch gradient of
    client (g, k). z: [G, K, d], y: [G, d] (zero-initialized if None).
    Returns (x_new [d], z, y, client_traj dict for deeper checks).
    """
    d = x0.shape[0]
    z = np.zeros((G, K, d)) if z is None else z.copy()
    y = np.zeros((G, d)) if y is None else y.copy()
    xbar_j = np.stack([x0.copy() for _ in range(G)])     # group models

    for e in range(E):
        x = np.stack([[xbar_j[g].copy() for _ in range(K)] for g in range(G)])
        for h in range(H):
            for g in range(G):
                for k in range(K):
                    grad = grads(g, k, x[g, k])
                    x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
        new_xbar = np.stack([x[g].mean(axis=0) for g in range(G)])
        if use_z:
            for g in range(G):
                for k in range(K):
                    z[g, k] = z[g, k] + (x[g, k] - new_xbar[g]) / (H * lr)
        xbar_j = new_xbar
    xbar = xbar_j.mean(axis=0)
    if use_y:
        for g in range(G):
            y[g] = y[g] + (xbar_j[g] - xbar) / (H * E * lr)
    return xbar, z, y


def mtgc_faulty_run(x0, grads, G, K, E, H, lr, rounds, *, crash=None,
                    timeout=None, corrupt=None, corrupt_kind="nan",
                    explode_factor=1e4, screen_nonfinite=False,
                    screen_norm=None, clip_norm=None):
    """``rounds`` sync MTGC global rounds under explicit fault masks
    (core/faults.py semantics), as literal loops. Full participation.

    crash [rounds, G, K] / timeout [rounds, G] / corrupt [rounds, G, K]
    are 0/1 masks (replay the engine's ``fault_masks`` draws to get the
    identical realization). A crashed client is frozen exactly like an
    unsampled one; a timed-out group works locally but misses the global
    exchange (no upload, no y update, no download); a corrupted client's
    upload is rewritten at the upload boundary. The defense keywords
    mirror ``DefensePlan``: screened uploads never enter a mean or a
    correction, a screened-but-active client still downloads (heals);
    if its whole group was screened out it reverts to the group-round
    start model instead, so no screened upload survives in a replica.

    Returns (x [G, K, d] replicas, z, y, screened) -- ``screened`` is the
    total screened-contribution count across all rounds (the engine's
    ``screened`` metric summed).
    """
    d = x0.shape[0]
    defended = (screen_nonfinite or screen_norm is not None
                or clip_norm is not None)
    crash = np.zeros((rounds, G, K)) if crash is None else np.asarray(crash)
    timeout = np.zeros((rounds, G)) if timeout is None else np.asarray(timeout)
    corrupt = np.zeros((rounds, G, K)) if corrupt is None else np.asarray(corrupt)

    x = np.stack([[x0.copy() for _ in range(K)] for _ in range(G)])
    z = np.zeros((G, K, d))
    y = np.zeros((G, d))
    screened = 0.0

    for t in range(rounds):
        cmask = 1.0 - crash[t]
        tm_keep = 1.0 - timeout[t]
        for g in range(G):
            for k in range(K):
                if cmask[g, k]:
                    z[g, k] = 0.0                       # participants only
        for e in range(E):
            x_start = x.copy()
            for h in range(H):
                for g in range(G):
                    for k in range(K):
                        if cmask[g, k]:
                            grad = grads(g, k, x[g, k])
                            x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
            x_up = x.copy()
            for g in range(G):
                for k in range(K):
                    if corrupt[t, g, k] and cmask[g, k]:
                        delta = x_up[g, k] - x_start[g, k]
                        if corrupt_kind == "explode":
                            payload = delta * explode_factor
                        else:
                            bad = np.nan if corrupt_kind == "nan" else np.inf
                            payload = delta + bad
                        x_up[g, k] = x_start[g, k] + payload
            if defended:
                ok = np.ones((G, K))
                for g in range(G):
                    for k in range(K):
                        delta = x_up[g, k] - x_start[g, k]
                        sqn = float(np.sum(delta * delta))
                        if screen_nonfinite and not np.isfinite(x_up[g, k]).all():
                            ok[g, k] = 0.0
                        if screen_norm is not None and not (sqn <= screen_norm ** 2):
                            ok[g, k] = 0.0              # NaN norms fail too
                        if (clip_norm is not None and np.isfinite(sqn)
                                and sqn > clip_norm ** 2):
                            scale = clip_norm / np.sqrt(max(sqn, clip_norm ** 2))
                            x_up[g, k] = x_start[g, k] + scale * delta
                smask = cmask * ok
                screened += float(np.sum(cmask) - np.sum(smask))
            else:
                smask = cmask
            xbar_g = np.zeros((G, d))
            for g in range(G):
                n = smask[g].sum()
                if n > 0:
                    xbar_g[g] = (smask[g][:, None] * np.where(
                        smask[g][:, None] != 0, x_up[g], 0)).sum(axis=0) / n
            for g in range(G):
                for k in range(K):
                    if smask[g, k]:
                        z[g, k] = z[g, k] + (x_up[g, k] - xbar_g[g]) / (H * lr)
            for g in range(G):
                has_srv = smask[g].sum() > 0
                for k in range(K):
                    if cmask[g, k] and (has_srv or not defended):
                        x[g, k] = xbar_g[g].copy()
                    elif cmask[g, k]:
                        # Defended, whole group screened: revert to the
                        # group-round start model so the screened upload
                        # never survives into the global recovery mean.
                        x[g, k] = x_start[g, k].copy()
                    else:
                        x[g, k] = x_up[g, k]
        # Global exchange: recovery over active replicas, then the
        # estimation mask composes activity, timeouts and the group-level
        # finite backstop.
        xbar_j = np.zeros((G, d))
        gact = np.zeros(G)
        for g in range(G):
            n = cmask[g].sum()
            if n > 0:
                xbar_j[g] = (np.where(cmask[g][:, None] != 0, x[g], 0)).sum(
                    axis=0) / n
                gact[g] = 1.0
        gact = gact * tm_keep
        if defended and screen_nonfinite:
            for g in range(G):
                if gact[g] and not np.isfinite(xbar_j[g]).all():
                    screened += float(cmask[g].sum())
                    gact[g] = 0.0
        ng = gact.sum()
        xbar = ((gact[:, None] * np.where(gact[:, None] != 0, xbar_j, 0))
                .sum(axis=0) / ng if ng > 0 else np.zeros(d))
        for g in range(G):
            if gact[g]:
                y[g] = y[g] + (xbar_j[g] - xbar) / (H * E * lr)
        any_g = ng > 0
        for g in range(G):
            for k in range(K):
                if cmask[g, k] and any_g and tm_keep[g]:
                    x[g, k] = xbar.copy()
    return x, z, y, screened


def mtgc_async_run(x0, grads, G, K, group_rounds, H, lr, windows, *,
                   policy="naive", max_staleness=None):
    """``windows`` async MTGC global rounds (core/staleness.py semantics),
    as literal loops: per-group E_g over a padded max(E_g) window, report
    cadence r_g = ceil(e_pad / E_g) (clipped to max_staleness + 1), stale
    reports merged per ``policy``. Full participation only.

    Returns (x [G, K, d] replicas, z [G, K, d], y [G, d]).
    """
    import math

    d = x0.shape[0]
    e_pad = max(group_rounds)
    if policy == "sync":
        periods = [1] * G
    else:
        periods = [math.ceil(e_pad / e) for e in group_rounds]
        if max_staleness is not None:
            periods = [min(r, max_staleness + 1) for r in periods]
    dw = [1.0 / r if policy == "discount" else 1.0 for r in periods]
    e_eff = [e * r for e, r in zip(group_rounds, periods)]

    x = np.stack([[x0.copy() for _ in range(K)] for _ in range(G)])
    z = np.zeros((G, K, d))
    y = np.zeros((G, d))
    snap = np.stack([x0.copy() for _ in range(G)])
    glob = x0.copy()

    for t in range(windows):
        for g in range(G):
            if t % periods[g] == 0:                     # fresh download
                z[g] = 0.0
        for e in range(e_pad):
            for g in range(G):
                if e >= group_rounds[g]:                # past its E_g: frozen
                    continue
                for h in range(H):
                    for k in range(K):
                        grad = grads(g, k, x[g, k])
                        x[g, k] = x[g, k] - lr * (grad + z[g, k] + y[g])
                xbar_g = x[g].mean(axis=0)
                for k in range(K):
                    z[g, k] = z[g, k] + (x[g, k] - xbar_g) / (H * lr)
                    x[g, k] = xbar_g.copy()
        rep = [(t + 1) % r == 0 for r in periods]
        xbar_used = np.stack([
            x[g, 0] + (glob - snap[g]) if policy == "delay_compensated"
            else x[g, 0] for g in range(G)])
        w = np.array([r * dwg for r, dwg in zip(rep, dw)])
        xbar = (w[:, None] * xbar_used).sum(axis=0) / w.sum()
        for g in range(G):
            if rep[g]:
                y[g] = y[g] + (xbar_used[g] - xbar) / (e_eff[g] * H * lr)
                for k in range(K):
                    x[g, k] = xbar.copy()
                snap[g] = xbar.copy()
        glob = xbar.copy()
    return x, z, y
