"""The static program auditor (repro.analysis + repro.launch.audit).

Every gate must be able to FAIL: each test seeds the violation the pass
exists to catch (missing donation aliases, an unfused program under the
fused contract, a doubled-E FLOPs blowout, f64 leakage, a host callback
in a scanned body) and asserts the finding fires -- plus the clean-path
assertions that the shipped matrix passes.
"""
import dataclasses
import json
import types

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import budgets, invariants, specs
from repro.launch import audit


@pytest.fixture(scope="module")
def sim_case():
    return specs.case_by_name("sim_mtgc_tree")


@pytest.fixture(scope="module")
def sim_lowered(sim_case):
    engine = sim_case.build_engine()
    params = specs.abstract_params()
    state = engine.abstract_state(params)
    data = specs.abstract_data(engine)
    lc = engine.lower_chunk(data, state=state)
    return engine, state, data, lc


# ------------------------------------------------------------ artifacts


def test_lower_chunk_is_abstract_and_complete(sim_lowered):
    engine, state, data, lc = sim_lowered
    # never executed: inputs stayed ShapeDtypeStructs
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree.leaves(state))
    assert lc.jaxpr.eqns
    assert "HloModule" in lc.hlo
    # the output state mirrors the input structure (scan carry contract)
    assert (jax.tree.structure(lc.out_state) == jax.tree.structure(state))
    for a, b in zip(jax.tree.leaves(lc.out_state), jax.tree.leaves(state)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_lowered_chunk_exported_on_api_surface():
    from repro import api

    assert "LoweredChunk" in api.__all__
    assert hasattr(api.SimulatorEngine, "lower_chunk")


# ------------------------------------------------------------- donation


def test_donation_clean_and_tripped_by_missing_aliases(sim_lowered):
    engine, state, data, lc = sim_lowered
    assert invariants.check_donation("c", lc) == []

    # Seed the violation: a runner that claims donation but whose
    # compiled module carries no aliases (traced with donate=False).
    undonated = engine.lower_chunk(data, state=state, donate=False)
    broken = undonated._replace(donate=True)
    found = invariants.check_donation("c", broken)
    assert found and found[0].check == "donation"
    assert found[0].severity == "error"
    n_leaves = len(jax.tree.leaves(state))
    assert f"{n_leaves}/{n_leaves}" in found[0].message

    # donate=False is an audited *choice*, reported as a note, not a fail
    noted = invariants.check_donation("c", undonated)
    assert [f.severity for f in noted] == ["note"]


# --------------------------------------------------------------- fusion


def test_fusion_contract_both_directions(sim_lowered):
    engine, state, data, lc = sim_lowered
    # unfused spec: exactly zero pallas_call sites
    assert invariants.check_fusion("c", lc, expected=0) == []
    # the same unfused program audited under a fused contract trips
    trip = invariants.check_fusion("c", lc, expected=1)
    assert trip and "expected 1" in trip[0].message

    fused = specs.case_by_name("sim_mtgc_flat_fused")
    eng_f = fused.build_engine()
    lc_f = eng_f.lower_chunk(specs.abstract_data(eng_f),
                             params=specs.abstract_params())
    assert fused.fused_leaves == 1
    assert invariants.check_fusion("f", lc_f, fused.fused_leaves) == []
    # and a fused program audited as unfused trips too
    assert invariants.check_fusion("f", lc_f, expected=0)


# ----------------------------------------------------- correction dtype


def test_correction_dtype_honored_and_tripped():
    case = specs.case_by_name("sharded_mtgc_tree_bf16")
    engine = case.build_engine()
    params = specs.abstract_params()
    state = engine.abstract_state(params)
    data = specs.abstract_data(engine)
    lc = engine.lower_chunk(data, state=state)
    assert invariants.check_correction_dtype("c", lc, case.spec) == []

    # Seed the violation: a state whose z silently widened back to f32.
    wide_z = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), state.z)
    bad = lc._replace(state=state._replace(z=wide_z))
    found = invariants.check_correction_dtype("c", bad, case.spec)
    assert found and "float32" in found[0].message


# ------------------------------------------------------ f64 / host-sync


def _fake_lc(fn, *args, hlo="HloModule t"):
    closed = jax.make_jaxpr(fn)(*args)
    return types.SimpleNamespace(jaxpr=closed.jaxpr, hlo=hlo)


def test_f64_check_trips_on_hlo_and_clean_otherwise(sim_lowered):
    *_, lc = sim_lowered
    assert invariants.check_no_f64("c", lc) == []
    fake = _fake_lc(lambda x: x + 1.0, jnp.ones((2,)),
                    hlo="ENTRY %m { %x = f64[4]{0} parameter(0) }")
    found = invariants.check_no_f64("c", fake)
    assert found and "f64" in found[0].message


def test_host_sync_check_trips_inside_scan_only():
    def noisy(x):
        def body(c, _):
            jax.debug.callback(lambda v: None, c)
            return c + 1.0, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    found = invariants.check_host_sync("c", _fake_lc(noisy, 0.0))
    assert found and "loop body" in found[0].message

    def quiet(x):
        jax.debug.callback(lambda v: None, x)  # outside any loop: allowed
        def body(c, _):
            return c + 1.0, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    assert invariants.check_host_sync("c", _fake_lc(quiet, 0.0)) == []


# -------------------------------------------------------------- retrace


def test_retrace_hits_cache(sim_lowered):
    engine, state, data, _ = sim_lowered
    assert invariants.check_retrace("c", engine, state, data) == []


# -------------------------------------------------------------- budgets


def test_budget_doubled_E_trips_flops(tmp_path):
    case = specs.case_by_name("sim_mtgc_tree")
    engine = case.build_engine()
    lc = engine.lower_chunk(specs.abstract_data(engine),
                            params=specs.abstract_params())
    ref = budgets.measure(lc)
    doc = budgets.save({case.name: ref}, tmp_path / "budgets.json")
    assert budgets.check({case.name: ref}, doc, strict=True) == []

    doubled = dataclasses.replace(
        case.spec, schedule=dataclasses.replace(
            case.spec.schedule,
            group_rounds=2 * case.spec.schedule.group_rounds)).validate()
    eng2 = specs.build(doubled, specs.quad_loss)
    lc2 = eng2.lower_chunk(specs.abstract_data(eng2),
                           params=specs.abstract_params())
    drifted = budgets.measure(lc2)
    assert drifted["flops"] > 1.5 * ref["flops"]
    found = budgets.check({case.name: drifted}, doc, strict=True)
    assert any(f.check == "budget" and "flops drifted" in f.message
               for f in found)


def test_budget_env_mismatch_degrades_to_notes():
    doc = {"jax": "0.0.0", "backend": "nonesuch", "rtol": 0.2,
           "specs": {"c": {"flops": 1.0, "bytes": 1.0,
                           "collective_bytes": 0.0}}}
    found = budgets.check(
        {"c": {"flops": 100.0, "bytes": 100.0, "collective_bytes": 0.0}},
        doc)
    assert found and all(f.severity == "note" for f in found)
    # forced strict still fails
    forced = budgets.check(
        {"c": {"flops": 100.0, "bytes": 100.0, "collective_bytes": 0.0}},
        doc, strict=True)
    assert any(f.severity == "error" for f in forced)


def test_checked_in_budgets_cover_the_full_matrix():
    doc = budgets.load()
    assert doc, "analysis/budgets.json missing"
    names = {c.name for c in specs.audit_cases()}
    assert set(doc["specs"]) == names


# ------------------------------------------------------------------ CLI


def test_audit_cli_single_case_and_report(tmp_path):
    report_path = tmp_path / "report.json"
    rc = audit.main(["--cases", "sim_mtgc_tree", "-q",
                     "--report", str(report_path)])
    assert rc == 0
    report = json.loads(report_path.read_text())
    assert report["ok"] and report["cases"] == ["sim_mtgc_tree"]
    prog = report["programs"]["sim_mtgc_tree"]
    assert prog["pallas_calls"] == 0
    assert prog["aliased_params"] == list(range(prog["donated_leaves"]))
    assert prog["flops"] > 0


def test_audit_cli_update_roundtrip(tmp_path):
    path = tmp_path / "budgets.json"
    rc = audit.main(["--cases", "sim_mtgc_tree", "-q", "--update",
                     "--budget-file", str(path)])
    assert rc == 0
    rc = audit.main(["--cases", "sim_mtgc_tree", "-q", "--strict-budgets",
                     "--budget-file", str(path)])
    assert rc == 0


def test_audit_cli_list():
    assert audit.main(["--list"]) == 0
