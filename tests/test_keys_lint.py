"""The rng key-discipline linter (repro.analysis.keys).

Positive cases prove the lint *can* fire (seeded violations); negative
cases pin the blessed repo patterns (split-and-rebind, fold_in
derivation, per-iteration rebinding) as clean; and the repo-wide gate
asserts the tree the audit CLI lints lands at zero unsuppressed
findings.
"""
from pathlib import Path

import textwrap

from repro.analysis import keys


def lint(src: str):
    return keys.lint_source(textwrap.dedent(src))


def rules(findings):
    return [f.rule for f in keys.unsuppressed(findings)]


def test_straight_line_reuse_flagged():
    out = lint("""
        import jax

        def f(key):
            a = jax.random.normal(key, (2,))
            b = jax.random.uniform(key, (2,))
            return a + b
    """)
    assert rules(out) == ["key-reuse"]
    assert out[0].line == 6


def test_split_and_rebind_clean():
    out = lint("""
        import jax

        def f(rng):
            k1, rng = jax.random.split(rng)
            a = jax.random.normal(k1, (2,))
            k2, rng = jax.random.split(rng)
            return a + jax.random.normal(k2, (2,))
    """)
    assert rules(out) == []


def test_split_then_reuse_parent_flagged():
    out = lint("""
        import jax

        def f(rng):
            k1, k2 = jax.random.split(rng)
            return jax.random.normal(rng, (2,))
    """)
    assert rules(out) == ["key-reuse"]


def test_fold_in_derivation_clean():
    out = lint("""
        import jax

        def f(key):
            draws = []
            for i in range(4):
                draws.append(jax.random.normal(jax.random.fold_in(key, i), (2,)))
            return draws
    """)
    assert rules(out) == []


def test_loop_invariant_consumption_flagged():
    out = lint("""
        import jax

        def f(key):
            out = []
            for _ in range(4):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert rules(out) == ["key-reuse"]


def test_loop_rebinding_clean():
    out = lint("""
        import jax

        def f(seeds):
            out = []
            for s in seeds:
                key = jax.random.PRNGKey(s)
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert rules(out) == []


def test_comprehension_target_rebinds_clean():
    out = lint("""
        import jax

        def f(key, n):
            return [jax.random.normal(k, (2,)) for k in jax.random.split(key, n)]
    """)
    assert rules(out) == []


def test_comprehension_invariant_key_flagged():
    out = lint("""
        import jax

        def f(key, n):
            return [jax.random.normal(key, (2,)) for _ in range(n)]
    """)
    assert rules(out) == ["key-reuse"]


def test_exclusive_branches_clean_but_join_reuse_flagged():
    out = lint("""
        import jax

        def f(key, flag):
            if flag:
                x = jax.random.normal(key, (2,))
            else:
                x = jax.random.uniform(key, (2,))
            return x + jax.random.normal(key, (2,))
    """)
    assert rules(out) == ["key-reuse"]
    assert out[0].line == 9  # the post-join use, not either branch


def test_attribute_keys_tracked_and_rebinding_resets():
    out = lint("""
        import jax

        def f(state):
            mkey, rng = jax.random.split(state.rng)
            state = state._replace(rng=rng)
            k2, rng = jax.random.split(state.rng)
            return mkey, k2
    """)
    assert rules(out) == []


def test_alias_forms_resolve():
    out = lint("""
        import jax.random as jr
        from jax import random
        from jax.random import normal

        def f(key):
            a = jr.uniform(key, (2,))
            b = random.normal(key, (2,))
            c = normal(key, (2,))
            return a + b + c
    """)
    assert rules(out) == ["key-reuse", "key-reuse"]


def test_suppression_comment():
    out = lint("""
        import jax

        def f(key):
            a = jax.random.normal(key, (2,))
            b = jax.random.normal(key, (2,))  # key-ok: intentional replay
            return a + b
    """)
    assert [f.rule for f in out] == ["key-reuse"]
    assert out[0].suppressed
    assert keys.unsuppressed(out) == []


def test_host_random_inside_traced_function_flagged():
    out = lint("""
        import jax.numpy as jnp
        import numpy as np

        def loss(params, batch):
            noise = np.random.normal(size=(2,))
            return jnp.sum(params * batch) + noise.sum()
    """)
    assert rules(out) == ["host-random"]


def test_host_random_generator_and_pure_host_scope_clean():
    out = lint("""
        import jax.numpy as jnp
        import numpy as np

        def traced(params):
            rng = np.random.default_rng(0)
            return jnp.sum(params) + rng.normal()

        def host_only(n):
            return np.random.normal(size=(n,))
    """)
    assert rules(out) == []


def test_repo_tree_has_zero_unsuppressed_findings():
    """The gate the audit CLI enforces, as a plain tier-1 test: src/,
    examples/ and benchmarks/ are clean (or explicitly `# key-ok`d)."""
    root = Path(__file__).resolve().parents[1]
    roots = [root / "src" / "repro"]
    roots += [d for d in (root / "examples", root / "benchmarks")
              if d.is_dir()]
    findings = keys.unsuppressed(keys.lint_paths(roots))
    assert findings == [], "\n".join(str(f) for f in findings)
