"""Async group rounds: plan semantics, oracle parity, bit-exact sync gate.

Four layers gate the feature (core/staleness.py + the engine async paths):

1. The static plan itself (cadences, masks, force-sync bound) by hand.
2. A pure-python async oracle (tests/oracle.py::mtgc_async_run) vs the
   simulator engine for every staleness policy.
3. The superset proof: a uniform tuple + ``staleness="sync"`` must be
   *bit-exact* against the pre-existing engines across all six algorithms
   x {tree, flat} x participation modes -- the async machinery dispatches
   to the untouched legacy program (``make_plan`` returns None).
4. Cross-path parity in async mode: flat == tree, sharded == simulator,
   and the contradictory spec combos each raise a targeted ValueError.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import ALGORITHMS
from repro.core.staleness import STALENESS_POLICIES, StalenessPlan, make_plan

from oracle import mtgc_async_run
from test_api_conformance import make_data, make_spec, assert_states_equal
from test_api_conformance import G, K, E, H, T
from test_mtgc_engine import D, quad_loss, make_batches, np_grad

ASYNC_POLICIES = tuple(p for p in STALENESS_POLICIES if p != "sync")


# ----------------------------------------------------------------- plan


def test_plan_cadences_by_hand():
    plan = StalenessPlan((4, 2, 1), policy="discount")
    assert plan.e_pad == 4
    assert plan.periods == (1, 2, 4)
    assert plan.staleness == (0, 1, 3)
    assert plan.effective_rounds == (4, 4, 4)
    assert plan.fastest_group == 0
    np.testing.assert_allclose(plan.discount_weights(), [1, 0.5, 0.25])
    em = plan.iteration_mask()
    assert em.shape == (4, 3)
    np.testing.assert_array_equal(em[:, 0], [1, 1, 1, 1])
    np.testing.assert_array_equal(em[:, 1], [1, 1, 0, 0])
    np.testing.assert_array_equal(em[:, 2], [1, 0, 0, 0])
    # Report at the end of each full cycle; fresh at the start of the next.
    np.testing.assert_array_equal(plan.report_mask(0), [1, 0, 0])
    np.testing.assert_array_equal(plan.report_mask(1), [1, 1, 0])
    np.testing.assert_array_equal(plan.report_mask(3), [1, 1, 1])
    np.testing.assert_array_equal(plan.fresh_mask(0), [1, 1, 1])
    np.testing.assert_array_equal(plan.fresh_mask(1), [1, 0, 0])
    np.testing.assert_array_equal(plan.fresh_mask(2), [1, 1, 0])


def test_plan_force_sync_bound():
    unbounded = StalenessPlan((8, 1), policy="naive")
    assert unbounded.periods == (1, 8)
    bounded = StalenessPlan((8, 1), policy="naive", max_staleness=1)
    assert bounded.periods == (1, 2)
    assert bounded.effective_rounds == (8, 2)
    # "sync" reports every window regardless of the round heterogeneity.
    assert StalenessPlan((8, 1), policy="sync").periods == (1, 1)


def test_make_plan_dispatch():
    assert make_plan(3, 2) is None
    assert make_plan((3, 3), 2) is None
    plan = make_plan((3, 1), 2, policy="discount")
    assert isinstance(plan, StalenessPlan)
    assert not make_plan((3, 1), 2, policy="naive").needs_snapshots
    assert make_plan((3, 1), 2, "delay_compensated").needs_snapshots
    with pytest.raises(ValueError):
        make_plan((3, 1, 2), 2)  # one entry per group
    with pytest.raises(ValueError):
        make_plan(3, 2, max_staleness=2)  # bound without async policy


# --------------------------------------------------- oracle (simulator)


@pytest.mark.parametrize("policy", ASYNC_POLICIES)
@pytest.mark.parametrize("group_rounds,max_staleness",
                         [((4, 2, 1), None), ((4, 2, 1), 1), ((2, 1), None)])
def test_simulator_matches_async_oracle(policy, group_rounds, max_staleness):
    Go, Ko, Ho, lr, windows = len(group_rounds), 2, 2, 0.05, 4
    e_pad = max(group_rounds)
    a, b, batches = make_batches(Go, Ko, e_pad, Ho)
    spec = api.ExperimentSpec(
        levels=(Go, Ko), algorithm="mtgc", lr=lr, state_layout="tree",
        schedule=api.RoundSchedule(group_rounds=group_rounds, local_steps=Ho),
        staleness=policy, max_staleness=max_staleness)
    engine = api.build(spec, quad_loss)
    state = engine.init({"w": jnp.zeros(D)})
    round_fn = jax.jit(engine.round_fn)
    for _ in range(windows):
        state, metrics = round_fn(state, jax.tree.map(jnp.asarray, batches))
        assert np.isfinite(np.asarray(metrics.loss)).all()

    x, z, y = mtgc_async_run(
        np.zeros(D, np.float32), np_grad(a, b), Go, Ko, group_rounds, Ho,
        lr, windows, policy=policy, max_staleness=max_staleness)
    tag = f"{policy}/{group_rounds}/ms={max_staleness}"
    np.testing.assert_allclose(np.asarray(state.params["w"]), x,
                               rtol=2e-4, atol=2e-5, err_msg=tag)
    np.testing.assert_allclose(np.asarray(state.z["w"]), z,
                               rtol=2e-4, atol=2e-4, err_msg=tag)
    np.testing.assert_allclose(np.asarray(state.y["w"]), y,
                               rtol=2e-4, atol=2e-4, err_msg=tag)

    # Straggler cadence is visible in the state: after the first window
    # only cadence-1 groups have downloaded the global model.
    plan = spec.staleness_plan()
    gm = np.asarray(engine.global_model(state)["w"])
    np.testing.assert_allclose(
        gm, np.asarray(state.params["w"])[plan.fastest_group, 0])


def test_straggler_reports_late_and_y_freezes_between_reports():
    """Window-by-window structure for group_rounds=(2, 1): the E_g=1
    straggler skips the window-0 aggregation (keeps its mid-cycle model,
    y frozen) and joins at window 1 (everyone back on the global model)."""
    spec = api.ExperimentSpec(
        levels=(2, 2), algorithm="mtgc", lr=0.05, state_layout="tree",
        schedule=api.RoundSchedule(group_rounds=(2, 1), local_steps=H),
        staleness="naive")
    engine = api.build(spec, quad_loss)
    _, _, batches = make_batches(2, 2, 2, H, seed=3)
    batches = jax.tree.map(jnp.asarray, batches)
    state = engine.init({"w": jnp.zeros(D)})

    state, _ = engine.round_fn(state, batches)
    w = np.asarray(state.params["w"])
    y = np.asarray(state.y["w"])
    assert np.array_equal(w[0, 0], w[0, 1])          # replicas agree
    assert np.array_equal(w[1, 0], w[1, 1])
    assert not np.allclose(w[0, 0], w[1, 0])         # straggler lags
    # Window 0's sole reporter IS the global mean: every y stays zero.
    np.testing.assert_array_equal(y, np.zeros_like(y))

    state, _ = engine.round_fn(state, batches)
    w = np.asarray(state.params["w"])
    y = np.asarray(state.y["w"])
    np.testing.assert_array_equal(w[1], w[0])        # straggler reported
    assert np.any(y[0] != 0) and np.any(y[1] != 0)   # both merged stale-vs-
    np.testing.assert_allclose(y.sum(axis=0), 0, atol=1e-5)  # fresh reports


def test_delay_compensation_is_exact_zero_when_fresh():
    """A fresh group's compensation term (glob - snap) is exactly zero, so
    the first window of delay_compensated equals naive bit-for-bit."""
    _, _, batches = make_batches(2, 2, 2, H, seed=5)
    batches = jax.tree.map(jnp.asarray, batches)
    states = {}
    for policy in ("naive", "delay_compensated"):
        spec = api.ExperimentSpec(
            levels=(2, 2), algorithm="mtgc", lr=0.05, state_layout="tree",
            schedule=api.RoundSchedule(group_rounds=(2, 1), local_steps=H),
            staleness=policy)
        engine = api.build(spec, quad_loss)
        state, _ = engine.round_fn(engine.init({"w": jnp.zeros(D)}), batches)
        states[policy] = state
    for field in ("params", "z", "y"):
        np.testing.assert_array_equal(
            np.asarray(getattr(states["naive"], field)["w"]),
            np.asarray(getattr(states["delay_compensated"], field)["w"]),
            err_msg=field)


# ------------------------------------------- bit-exact sync gate (tier 1)


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algo", ALGORITHMS)
def test_uniform_tuple_sync_is_bit_exact_simulator(algo, layout):
    """(E, ..., E) + staleness='sync' is provably the legacy program."""
    params0 = {"w": jnp.zeros(D)}
    base = make_spec(algo, "simulator", layout)
    tup = dataclasses.replace(
        base, schedule=api.RoundSchedule(group_rounds=(E,) * G,
                                         local_steps=H),
        staleness="sync")
    assert tup.staleness_plan() is None
    s1, _ = api.fit(api.build(base, quad_loss), make_data(), T,
                    params=params0, donate=False)
    s2, _ = api.fit(api.build(tup, quad_loss), make_data(), T,
                    params=params0, donate=False)
    assert_states_equal(s2, s1, f"uniform-sync/{algo}/{layout}")


@pytest.mark.parametrize("algo", ["mtgc", "hfedavg"])
@pytest.mark.parametrize("participation",
                         [dict(),
                          dict(client_participation=0.5,
                               group_participation=0.75),
                          dict(client_participation=0.5,
                               group_participation=0.75,
                               participation_weighting="inverse_prob")])
def test_uniform_tuple_sync_is_bit_exact_sharded(algo, participation):
    params0 = {"w": jnp.zeros(D)}
    base = make_spec(algo, "sharded", "flat", **participation)
    tup = dataclasses.replace(
        base, schedule=dataclasses.replace(base.schedule,
                                           group_rounds=(E,) * G))
    rng0 = jax.random.PRNGKey(11)
    s1, _ = api.fit(api.build(base, quad_loss), make_data(microbatches=1),
                    T, params=params0, rng=rng0, donate=False)
    s2, _ = api.fit(api.build(tup, quad_loss), make_data(microbatches=1),
                    T, params=params0, rng=rng0, donate=False)
    assert_states_equal(s2, s1, f"uniform-sync/sharded/{algo}")


def test_degenerate_live_plan_matches_legacy():
    """Forcing the async machinery on with a degenerate uniform plan
    (cadence 1 everywhere) reproduces the legacy round numerically -- the
    masked/weighted async aggregation is a strict generalization."""
    from repro.core import HFLConfig, hfl_init
    from repro.core.engine import _build_global_round

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    use_flat_state=False)
    plan = StalenessPlan((E,) * G, policy="naive")
    assert plan.periods == (1,) * G
    _, _, batches = make_batches(G, K, E, H, seed=7)
    batches = jax.tree.map(jnp.asarray, batches)
    s_legacy = s_async = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf_legacy = jax.jit(_build_global_round(quad_loss, cfg))
    rf_async = jax.jit(_build_global_round(quad_loss, cfg, plan=plan))
    for _ in range(2):
        s_legacy, _ = rf_legacy(s_legacy, batches)
        s_async, _ = rf_async(s_async, batches)
    for field in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(getattr(s_async, field)["w"]),
            np.asarray(getattr(s_legacy, field)["w"]),
            rtol=1e-6, atol=1e-6, err_msg=field)


# --------------------------------------------- cross-path async parity


@pytest.mark.parametrize("policy", ASYNC_POLICIES)
def test_async_flat_matches_tree(policy):
    params0 = {"w": jnp.zeros(D)}
    _, _, batches = make_batches(G, K, 3, H, seed=9)
    batches = jax.tree.map(jnp.asarray, batches)
    finals = {}
    for layout in ("tree", "flat"):
        spec = api.ExperimentSpec(
            levels=(G, K), algorithm="mtgc", lr=0.05, state_layout=layout,
            schedule=api.RoundSchedule(group_rounds=(3, 1), local_steps=H),
            staleness=policy)
        engine = api.build(spec, quad_loss)
        state = engine.init(params0)
        for _ in range(3):
            state, _ = engine.round_fn(state, batches)
        finals[layout] = np.asarray(engine.global_model(state)["w"])
    np.testing.assert_allclose(finals["flat"], finals["tree"],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("policy", ASYNC_POLICIES)
@pytest.mark.parametrize("participation",
                         [dict(), dict(client_participation=0.5,
                                       group_participation=0.75)])
def test_async_sharded_matches_simulator(policy, participation):
    """The sharded async path (round counter, masks composed with the
    freeze/recover machinery) agrees with the simulator engine."""
    params0 = {"w": jnp.zeros(D)}
    rng0 = jax.random.PRNGKey(13)
    _, _, batches = make_batches(G, K, 3, H, seed=17)
    sim_b = jax.tree.map(jnp.asarray, batches)
    # [E, H, A=1, G, K, D]: the sharded microbatched layout of the same data.
    sh_b = jax.tree.map(lambda x: jnp.expand_dims(x, 2), sim_b)
    finals = {}
    for backend in ("simulator", "sharded"):
        spec = api.ExperimentSpec(
            levels=(G, K), algorithm="mtgc", lr=0.05, backend=backend,
            state_layout="flat",
            schedule=api.RoundSchedule(
                group_rounds=(3, 1), local_steps=H,
                microbatches=1 if backend == "sharded" else None),
            staleness=policy, **participation)
        engine = api.build(spec, quad_loss)
        state = engine.init(params0, rng0)
        rf = jax.jit(engine.round_fn)
        for _ in range(3):
            state, _ = rf(state, sh_b if backend == "sharded" else sim_b)
        finals[backend] = np.asarray(engine.global_model(state)["w"])
    np.testing.assert_allclose(finals["sharded"], finals["simulator"],
                               rtol=1e-5, atol=1e-6)


def test_async_fused_interpret_matches_unfused():
    """The fused flat path composes the iteration mask with the [G, K]
    participation mask in-register; interpret-mode kernel == unfused."""
    params0 = {"w": jnp.zeros(D)}
    _, _, batches = make_batches(G, K, 3, H, seed=21)
    batches = jax.tree.map(jnp.asarray, batches)
    finals = {}
    for fusion in ("none", "fused"):
        spec = api.ExperimentSpec(
            levels=(G, K), algorithm="mtgc", lr=0.05, state_layout="flat",
            fusion=fusion, client_participation=0.5,
            schedule=api.RoundSchedule(group_rounds=(3, 1), local_steps=H),
            staleness="discount")
        engine = api.build(spec, quad_loss)
        state = engine.init(params0, jax.random.PRNGKey(23))
        for _ in range(2):
            state, _ = engine.round_fn(state, batches)
        finals[fusion] = np.asarray(engine.global_model(state)["w"])
    np.testing.assert_allclose(finals["fused"], finals["none"],
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------- validation / shims


def test_contradictory_async_specs_raise():
    sched = api.RoundSchedule(group_rounds=(2, 1), local_steps=H)
    bad = [
        # non-uniform rounds on the multilevel backend
        dict(schedule=sched, backend="multilevel"),
        # an async policy is a no-op with uniform rounds
        dict(staleness="discount"),
        dict(staleness="naive",
             schedule=api.RoundSchedule(group_rounds=(2, 2))),
        # max_staleness without an async policy
        dict(max_staleness=2),
        dict(schedule=sched, max_staleness=2),      # staleness="sync"
        dict(schedule=sched, staleness="naive", max_staleness=0),
        dict(schedule=sched, staleness="stale_ok"),  # unknown policy
        # async needs the zero z-init and a unit server lr
        dict(schedule=sched, staleness="naive", correction_init="gradient"),
        dict(schedule=sched, staleness="naive", server_lr=0.5),
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            api.ExperimentSpec(levels=(2, 2), **kw).validate()
    # Non-uniform + "sync" is valid: heterogeneous work, zero staleness.
    api.ExperimentSpec(levels=(2, 2), schedule=sched).validate()


def test_legacy_shims_emit_deprecation_warnings():
    from repro.core import HFLConfig, make_global_round, make_multilevel_round
    from repro.launch.train import make_sharded_round

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05)
    with pytest.warns(DeprecationWarning, match="make_global_round"):
        make_global_round(quad_loss, cfg)
    with pytest.warns(DeprecationWarning, match="make_sharded_round"):
        make_sharded_round(quad_loss, E=E, H=H, lr=0.05)
    with pytest.warns(DeprecationWarning, match="make_multilevel_round"):
        make_multilevel_round(quad_loss, (G, K), (E * H, H), 0.05)
