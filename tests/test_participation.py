"""Partial-participation subsystem: masks, masked means, frozen state,
full-participation exactness, and the fused-update wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    HFLConfig,
    as_tree,
    hfl_init,
    make_global_round,
    round_masks,
    sample_hfl_masks,
)
from repro.core import multilevel as ml
from repro.core import participation as pp
from repro.core import tree as tu
from repro.data.partition import sample_round_batches

from test_mtgc_engine import D, make_batches, quad_loss


# ------------------------------------------------------ masked-mean helpers


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 4), k=st.integers(1, 5), trail=st.integers(1, 7))
def test_masked_mean_all_ones_equals_mean(g, k, trail):
    rng = np.random.default_rng(g * 31 + k * 7 + trail)
    a = {"w": jnp.asarray(rng.normal(size=(g, k, trail)), jnp.float32),
         "b": {"c": jnp.asarray(rng.normal(size=(g, k)), jnp.float32)}}
    ones = jnp.ones((g, k), jnp.float32)
    got = tu.tree_masked_mean(a, ones, axis=1)
    want = tu.tree_mean(a, axis=1)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               rtol=1e-7, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got["b"]["c"]),
                               np.asarray(want["b"]["c"]), rtol=1e-7, atol=1e-7)


def test_masked_mean_ignores_masked_entries():
    """Masked-out replicas cannot poison the aggregate -- not even with NaN."""
    x = np.ones((2, 3, 4), np.float32)
    x[:, 2] = np.nan  # frozen replica holding garbage
    mask = jnp.asarray([[1, 1, 0], [1, 0, 0]], jnp.float32)
    got = tu.tree_masked_mean({"w": jnp.asarray(x)}, mask, axis=1)["w"]
    np.testing.assert_allclose(np.asarray(got), 1.0)


def test_masked_mean_empty_slice_is_exact_zero():
    """An all-masked slice recovers exact zeros -- even when the frozen
    (masked-out) entries it would have read are non-finite. Screened
    aggregation relies on this: a group whose every contribution was
    screened must not emit NaN into the (gated, unobserved) aggregate."""
    raw = np.random.default_rng(0).normal(size=(2, 3, 4)).astype(np.float32)
    raw[0, 1] = np.nan  # garbage in the empty slice's masked-out entries
    x = jnp.asarray(raw)
    mask = jnp.asarray([[0, 0, 0], [1, 1, 0]], jnp.float32)
    got = np.asarray(tu.tree_masked_mean({"w": x}, mask, axis=1)["w"])
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got[0], np.zeros((4,), np.float32))
    np.testing.assert_allclose(got[1], raw[1, :2].mean(axis=0), rtol=1e-6)


def test_tree_select_keeps_frozen_bits():
    a = {"w": jnp.full((2, 2, 3), jnp.nan)}
    b = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(2, 2, 3)),
                          jnp.float32)}
    mask = jnp.asarray([[1, 0], [0, 1]], jnp.float32)
    out = np.asarray(tu.tree_select(mask, a, b)["w"])
    assert np.isnan(out[0, 0]).all() and np.isnan(out[1, 1]).all()
    np.testing.assert_array_equal(out[0, 1], np.asarray(b["w"])[0, 1])


# ----------------------------------------------------------- mask sampling


def test_fixed_count_is_nearest_half_up():
    """Nearest-count semantics: half-up tie-break, never banker's rounding
    (round(0.5 * 5) == 2 would under-sample), never zero."""
    assert pp.fixed_count(0.5, 5) == 3      # the banker's-rounding trap
    assert pp.fixed_count(0.5, 13) == 7
    assert pp.fixed_count(0.5, 4) == 2
    assert pp.fixed_count(0.5, 2) == 1
    assert pp.fixed_count(0.3, 5) == 2      # 1.5 rounds up
    assert pp.fixed_count(0.7, 5) == 4      # 3.5 rounds up
    assert pp.fixed_count(0.1, 5) == 1
    assert pp.fixed_count(0.01, 3) == 1     # never zero
    assert pp.fixed_count(1.0, 7) == 7
    assert pp.fixed_count(1.0 - 1e-9, 7) == 7


def test_inclusion_prob_modes():
    assert pp.inclusion_prob(0.5, 4, "uniform") == 0.5
    assert pp.inclusion_prob(1.0, 4, "uniform") == 1.0
    assert pp.inclusion_prob(0.5, 5, "fixed") == pytest.approx(3 / 5)
    assert pp.inclusion_prob(1.0, 5, "fixed") == 1.0
    with pytest.raises(ValueError):
        pp.inclusion_prob(0.5, 4, "bogus")


def test_sample_axis_mask_frac_one_vs_almost_one():
    """frac=1.0 short-circuits to ones without consuming randomness; an
    epsilon below 1.0 must still produce all-ones masks in both modes
    (fixed: fixed_count == n; uniform: the f32 threshold rounds to 1.0)."""
    key = jax.random.PRNGKey(0)
    shape = (3, 5)
    exact = pp.sample_axis_mask(key, shape, 1.0, "fixed")
    np.testing.assert_array_equal(np.asarray(exact), 1.0)
    for mode in ("uniform", "fixed"):
        almost = pp.sample_axis_mask(key, shape, 1.0 - 1e-9, mode)
        np.testing.assert_array_equal(np.asarray(almost), 1.0, err_msg=mode)


def test_host_and_engine_masks_agree_under_weighting():
    """round_masks host/device agreement survives the weighting config
    field: the host-derived mask still names exactly the frozen replicas
    of an inverse_prob uniform-sampling round."""
    G, K, E, H = 3, 4, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    client_participation=0.5, group_participation=0.75,
                    participation_mode="uniform",
                    participation_weighting="inverse_prob")
    _, _, batches = make_batches(G, K, E, H, seed=33)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        masks, _ = round_masks(state.rng, cfg)
        cm = np.asarray(masks.client)
        prev = np.asarray(as_tree(state.params)["w"])
        state, m = rf(state, jax.tree.map(jnp.asarray, batches))
        cur = np.asarray(as_tree(state.params)["w"])
        np.testing.assert_array_equal(cur[cm == 0], prev[cm == 0])
        if cm.sum():
            assert not np.allclose(cur[cm == 1], prev[cm == 1])
        np.testing.assert_allclose(float(m.participation), cm.mean(),
                                   rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), g=st.integers(1, 5), k=st.integers(1, 6),
       frac=st.sampled_from([0.25, 0.5, 0.75]))
def test_fixed_mode_counts_are_exact(seed, g, k, frac):
    masks = sample_hfl_masks(jax.random.PRNGKey(seed), g, k, frac, 1.0,
                             mode="fixed")
    counts = np.asarray(masks.client).sum(axis=1)
    np.testing.assert_array_equal(counts, pp.fixed_count(frac, k))
    assert np.asarray(masks.group).sum() == g


def test_group_mask_gates_clients():
    masks = sample_hfl_masks(jax.random.PRNGKey(7), 6, 4, 1.0, 0.5,
                             mode="fixed")
    gm = np.asarray(masks.group)
    cm = np.asarray(masks.client)
    assert (cm[gm == 0] == 0).all()
    assert (cm[gm == 1] == 1).all()
    assert gm.sum() == pp.fixed_count(0.5, 6)


def test_host_and_engine_masks_agree():
    """round_masks(state.rng, cfg) reproduces exactly the masks the jitted
    round consumes: a group frozen on the host view is frozen in the state."""
    G, K, E, H = 4, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    client_participation=0.5, group_participation=0.5,
                    participation_mode="fixed")
    _, _, batches = make_batches(G, K, E, H, seed=31)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        masks, _ = round_masks(state.rng, cfg)
        cm = np.asarray(masks.client)
        prev = np.asarray(as_tree(state.params)["w"])
        state, m = rf(state, jax.tree.map(jnp.asarray, batches))
        cur = np.asarray(as_tree(state.params)["w"])
        np.testing.assert_array_equal(cur[cm == 0], prev[cm == 0])
        assert not np.allclose(cur[cm == 1], prev[cm == 1])
        np.testing.assert_allclose(float(m.participation), cm.mean(), rtol=1e-6)


# --------------------------------------------------- engine under partial C


def test_zero_participation_group_freezes_y_and_params():
    """A group that sits out a round keeps y_j, z, and every client frozen."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    group_participation=0.5, participation_mode="fixed")
    _, _, batches = make_batches(G, K, E, H, seed=41)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(4):
        masks, _ = round_masks(state.rng, cfg)
        gm = np.asarray(masks.group)
        assert gm.sum() == 1  # fixed mode: exactly one of two groups
        off = int(np.argmin(gm))
        y0 = np.asarray(as_tree(state.y)["w"])
        z0 = np.asarray(as_tree(state.z)["w"])
        p0 = np.asarray(as_tree(state.params)["w"])
        state, _ = rf(state, jax.tree.map(jnp.asarray, batches))
        np.testing.assert_array_equal(np.asarray(as_tree(state.y)["w"])[off], y0[off])
        np.testing.assert_array_equal(np.asarray(as_tree(state.z)["w"])[off], z0[off])
        np.testing.assert_array_equal(np.asarray(as_tree(state.params)["w"])[off], p0[off])


def test_gradient_init_keeps_empty_group_y_frozen():
    """A reachable group whose Bernoulli client draws all came up empty must
    keep its y frozen even under correction_init='gradient' (round 0)."""
    from unittest import mock

    G, K, E, H = 2, 3, 1, 1
    _, _, batches = make_batches(G, K, E, H, seed=51)
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    correction_init="gradient", client_participation=0.5)

    def crafted(key, shape, frac, mode):
        if shape == (G,):
            return jnp.ones(shape, jnp.float32)          # both groups live
        return jnp.asarray([[0, 0, 0], [1, 1, 0]], jnp.float32)

    with mock.patch.object(pp, "sample_axis_mask", crafted):
        rf = jax.jit(make_global_round(quad_loss, cfg))
        state = hfl_init({"w": jnp.zeros(D)}, cfg)
        state2, _ = rf(state, jax.tree.map(jnp.asarray, batches))
    np.testing.assert_array_equal(np.asarray(as_tree(state2.y)["w"])[0],
                                  np.asarray(as_tree(state.y)["w"])[0])
    assert not np.allclose(np.asarray(as_tree(state2.params)["w"])[1, :2],
                           np.asarray(as_tree(state.params)["w"])[1, :2])


def test_partial_invariants_over_participants():
    """Sec. 3.2 invariants restricted to participants: the z increments sum
    to zero over each group's active clients, y stays zero-mean over the
    groups that have ever participated jointly... the per-round increment
    does."""
    G, K, E, H = 3, 4, 2, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.1, algorithm="mtgc",
                    client_participation=0.5)
    _, _, batches = make_batches(G, K, E, H, seed=42)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        masks, _ = round_masks(state.rng, cfg)
        cm = np.asarray(masks.client)[..., None]
        y_prev = np.asarray(as_tree(state.y)["w"])
        state, m = rf(state, jax.tree.map(jnp.asarray, batches))
        # z was re-zeroed for participants, then summed increments cancel
        zsum = (np.asarray(as_tree(state.z)["w"]) * cm).sum(axis=1)
        np.testing.assert_allclose(zsum, 0.0, atol=1e-4)
        # y increments cancel over the groups active this round
        gact = (cm.sum(1) > 0).astype(np.float32)
        dy = (np.asarray(as_tree(state.y)["w"]) - y_prev) * gact
        np.testing.assert_allclose(dy.sum(axis=0), 0.0, atol=1e-4)
        assert np.isfinite(np.asarray(m.loss)).all()


def test_full_participation_config_matches_masked_all_ones():
    """C=1.0 compiles the pre-change program; the masked path fed all-ones
    masks must agree with it to float precision on params, z and y."""
    from unittest import mock

    G, K, E, H = 2, 3, 2, 2
    _, _, batches = make_batches(G, K, E, H, seed=5)
    jb = jax.tree.map(jnp.asarray, batches)
    st0 = hfl_init({"w": jnp.zeros(D)},
                   HFLConfig(num_groups=G, clients_per_group=K))
    for algo in ("mtgc", "hfedavg", "local_corr", "group_corr", "fedprox",
                 "feddyn"):
        kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
                  group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
                  feddyn_alpha=0.1)
        rf_full = jax.jit(make_global_round(quad_loss, HFLConfig(**kw)))
        s_full, _ = rf_full(st0, jb)
        with mock.patch.object(
                pp, "sample_axis_mask",
                lambda key, shape, frac, mode: jnp.ones(shape, jnp.float32)):
            rf_ones = jax.jit(make_global_round(
                quad_loss, HFLConfig(**kw, client_participation=0.5)))
            s_ones, _ = rf_ones(st0, jb)
        for name in ("params", "z", "y", "dyn"):
            np.testing.assert_allclose(
                np.asarray(as_tree(getattr(s_full, name))["w"]),
                np.asarray(as_tree(getattr(s_ones, name))["w"]),
                rtol=1e-6, atol=1e-6, err_msg=f"{algo}.{name}")


def test_partial_mtgc_still_trains():
    G, K, E, H = 2, 4, 3, 4
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc",
                    client_participation=0.5, participation_mode="fixed")
    _, _, batches = make_batches(G, K, E, H, seed=6)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    first_step = last = None
    for _ in range(20):
        state, m = rf(state, jax.tree.map(jnp.asarray, batches))
        if first_step is None:
            first_step = float(np.asarray(m.loss)[0, 0])  # loss at x ~ 0
        last = float(np.asarray(m.loss).mean())
    # mean loss at the heterogeneous optimum is positive: check the drop
    # from the untrained model, not convergence to zero
    assert np.isfinite(last) and last < 0.6 * first_step, (first_step, last)


# ------------------------------------------------------- multilevel engine


def test_multilevel_participation_none_equals_all_ones_fractions():
    dims, periods, lr = (2, 2), (4, 2), 0.05
    _, _, b4 = make_batches(2, 2, 2, 2, seed=11)
    mb = {k: jnp.asarray(v.reshape((4,) + v.shape[2:])) for k, v in b4.items()}
    st0 = ml.multilevel_init({"w": jnp.zeros(D)}, dims)
    rf_none = jax.jit(ml.make_multilevel_round(quad_loss, dims, periods, lr))
    rf_ones = jax.jit(ml.make_multilevel_round(
        quad_loss, dims, periods, lr, participation=(1.0, 1.0)))
    s1, l1 = rf_none(st0, mb)
    s2, l2 = rf_ones(st0, mb)
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(s2.params["w"]))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_multilevel_partial_freezes_inactive_subtree():
    dims, periods, lr = (2, 2, 2), (8, 4, 2), 0.05
    rng = np.random.default_rng(12)
    a = rng.normal(size=dims + (D,)).astype(np.float32) + 2.0
    b = rng.normal(size=dims + (D,)).astype(np.float32)
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (8,) + a.shape).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (8,) + b.shape).copy()),
    }
    st = ml.multilevel_init({"w": jnp.zeros(D)}, dims)
    rf = jax.jit(ml.make_multilevel_round(
        quad_loss, dims, periods, lr, participation=(0.5, 1.0, 1.0),
        participation_mode="fixed"))
    for _ in range(3):
        # replicate the engine's level-1 mask on the host
        mkey, _ = jax.random.split(st.rng)
        keys = jax.random.split(mkey, 3)
        m1 = np.asarray(pp.sample_axis_mask(keys[0], (2,), 0.5, "fixed"))
        off = int(np.argmin(m1))
        p0 = np.asarray(st.params["w"])
        nu0 = np.asarray(st.nus[0]["w"])
        st, losses = rf(st, batches)
        np.testing.assert_array_equal(np.asarray(st.params["w"])[off], p0[off])
        np.testing.assert_array_equal(np.asarray(st.nus[0]["w"])[off], nu0[off])
        assert not np.allclose(np.asarray(st.params["w"])[1 - off], p0[1 - off])
        assert np.isfinite(np.asarray(losses)).all()


# ------------------------------------------------------- fused local update


@pytest.mark.parametrize("partial_c", [1.0, 0.5])
def test_fused_update_matches_tree_map_path(partial_c):
    G, K, E, H = 2, 3, 2, 3
    _, _, batches = make_batches(G, K, E, H, seed=8)
    jb = jax.tree.map(jnp.asarray, batches)
    outs = {}
    for fused in (False, True):
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.05, algorithm="mtgc",
                        client_participation=partial_c, use_fused_update=fused)
        state = hfl_init({"w": jnp.zeros(D)}, cfg)
        rf = jax.jit(make_global_round(quad_loss, cfg))
        for _ in range(2):
            state, _ = rf(state, jb)
        outs[fused] = state
    for name in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(as_tree(getattr(outs[False], name))["w"]),
            np.asarray(as_tree(getattr(outs[True], name))["w"]),
            rtol=1e-5, atol=1e-6, err_msg=name)


def test_fused_update_rejected_for_non_mtgc():
    # ValueError, not AssertionError: config checks must survive python -O.
    cfg = HFLConfig(algorithm="fedprox", use_fused_update=True)
    with pytest.raises(ValueError):
        make_global_round(quad_loss, cfg)


# ----------------------------------------------------------- data pipeline


def test_round_batches_skip_inactive_clients():
    rng = np.random.default_rng(0)
    data_x = rng.normal(size=(200, 5)).astype(np.float32) + 10.0  # never zero
    data_y = rng.integers(0, 3, size=(200,)).astype(np.int64)
    idx = [[np.arange(100), np.arange(100)],
           [np.arange(100, 200), np.arange(100, 200)]]
    mask = np.asarray([[1, 0], [0, 1]], np.float32)
    out = sample_round_batches(data_x, data_y, idx, rng, group_rounds=2,
                               local_steps=3, batch_size=4, client_mask=mask)
    assert (out["x"][:, :, 0, 1] == 0).all() and (out["x"][:, :, 1, 0] == 0).all()
    assert (out["x"][:, :, 0, 0] != 0).all() and (out["x"][:, :, 1, 1] != 0).all()
