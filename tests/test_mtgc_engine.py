"""Core MTGC engine vs the pure-python oracle + the paper's invariants.

These run on the default (flat-state) engine path; state internals are
read through ``as_tree``, which is the identity for pytree states. The
flat/tree equivalence itself is covered by tests/test_flat_state.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFLConfig, as_tree, global_model, hfl_init, make_global_round

from oracle import mtgc_round

D = 6


def quad_loss(params, batch):
    """0.5 * ||a * x - b||^2 with per-client (a, b) passed through the batch
    (constant across steps -> deterministic full-batch gradients)."""
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def make_batches(G, K, E, H, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(G, K, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(G, K, D)).astype(np.float32)
    batches = {
        "a": np.broadcast_to(a, (E, H, G, K, D)).copy(),
        "b": np.broadcast_to(b, (E, H, G, K, D)).copy(),
    }
    return a, b, batches


def np_grad(a, b):
    return lambda g, k, x: a[g, k] * (a[g, k] * x - b[g, k])


@pytest.mark.parametrize("G,K,E,H", [(2, 2, 2, 3), (3, 2, 4, 2), (1, 4, 1, 5)])
def test_engine_matches_oracle(G, K, E, H):
    lr = 0.05
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc")
    a, b, batches = make_batches(G, K, E, H)
    x0 = np.zeros(D, np.float32)

    state = hfl_init({"w": jnp.asarray(x0)}, cfg)
    round_fn = jax.jit(make_global_round(quad_loss, cfg))

    # two rounds: exercises carrying z/y across rounds (z re-zeroed per the
    # paper's experimental footnote; y persists)
    z = y = None
    want = x0
    for _ in range(2):
        state, _ = round_fn(state, jax.tree.map(jnp.asarray, batches))
        want, _, y = mtgc_round(want, np_grad(a, b), G, K, E, H, lr, z=None, y=y)
    got = np.asarray(global_model(state)["w"])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_correction_invariants():
    G, K, E, H = 3, 4, 2, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.1, algorithm="mtgc")
    a, b, batches = make_batches(G, K, E, H, seed=1)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    round_fn = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        state, _ = round_fn(state, jax.tree.map(jnp.asarray, batches))
        # paper Sec. 3.2: sum_i z_i = 0 per group, sum_j y_j = 0
        zsum = np.asarray(as_tree(state.z)["w"]).sum(axis=1)
        np.testing.assert_allclose(zsum, 0.0, atol=1e-4)
        ysum = np.asarray(as_tree(state.y)["w"]).sum(axis=0)
        np.testing.assert_allclose(ysum, 0.0, atol=1e-5)


def test_corrections_do_not_move_averages():
    """z/y cancel in the group/global means: with identical data order,
    MTGC and HFedAvg produce the same global model after ONE group round of
    H=1 (single step -> no drift for corrections to act on)."""
    G, K = 2, 3
    cfg_m = HFLConfig(num_groups=G, clients_per_group=K, local_steps=1,
                      group_rounds=1, lr=0.1, algorithm="mtgc")
    cfg_f = cfg_m.__class__(**{**cfg_m.__dict__, "algorithm": "hfedavg"})
    a, b, batches = make_batches(G, K, 1, 1, seed=2)
    out = {}
    for cfg in (cfg_m, cfg_f):
        state = hfl_init({"w": jnp.zeros(D)}, cfg)
        state, _ = jax.jit(make_global_round(quad_loss, cfg))(
            state, jax.tree.map(jnp.asarray, batches))
        out[cfg.algorithm] = np.asarray(global_model(state)["w"])
    np.testing.assert_allclose(out["mtgc"], out["hfedavg"], rtol=1e-6)


def test_mtgc_converges_to_global_optimum_under_heterogeneity():
    """The paper's central claim (Fig. 2): with heterogeneous clients and
    long local phases, MTGC reaches the *global* optimum; HFedAvg stalls
    with a drift-induced bias."""
    G, K, E, H, lr = 2, 2, 4, 8, 0.05
    a, b, batches = make_batches(G, K, E, H, seed=3)
    # global optimum of sum of quadratics: x* = sum(a*b) / sum(a^2)
    xstar = (a * b).sum((0, 1)) / (a * a).sum((0, 1))

    err = {}
    for algo in ("mtgc", "hfedavg"):
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=lr, algorithm=algo)
        state = hfl_init({"w": jnp.zeros(D)}, cfg)
        rf = jax.jit(make_global_round(quad_loss, cfg))
        for _ in range(60):
            state, _ = rf(state, jax.tree.map(jnp.asarray, batches))
        err[algo] = float(np.linalg.norm(np.asarray(global_model(state)["w"]) - xstar))
    # HFedAvg stalls at a drift-induced bias; MTGC keeps contracting toward
    # x* (the per-round z re-zeroing of the paper's footnote 2 makes late
    # convergence gradual, so we check the bias gap, not exact arrival).
    assert err["mtgc"] < 0.05, err
    assert err["mtgc"] < err["hfedavg"] / 5, err


@pytest.mark.parametrize("algo", ["local_corr", "group_corr", "fedprox", "feddyn"])
def test_baselines_run_and_are_finite(algo):
    G, K, E, H = 2, 2, 2, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm=algo,
                    prox_mu=0.1, feddyn_alpha=0.1)
    a, b, batches = make_batches(G, K, E, H, seed=4)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        state, m = rf(state, jax.tree.map(jnp.asarray, batches))
    assert np.isfinite(np.asarray(m.loss)).all()
    assert np.isfinite(np.asarray(global_model(state)["w"])).all()


def test_gradient_init_matches_theory_lines():
    """correction_init='gradient' (Alg. 1 lines 3-4): z starts at the
    group-mean-gradient minus own gradient."""
    G, K, E, H = 2, 2, 1, 1
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.1, algorithm="mtgc",
                    correction_init="gradient")
    a, b, batches = make_batches(G, K, E, H, seed=5)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    state2, _ = rf(state, jax.tree.map(jnp.asarray, batches))
    # after one (E=H=1) round with gradient init, all clients took the SAME
    # corrected step (gradient of the group mean) -> zero client drift
    x = np.asarray(as_tree(state2.params)["w"])
    np.testing.assert_allclose(x, np.broadcast_to(x[0, 0], x.shape), rtol=1e-6)
