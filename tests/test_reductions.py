"""Paper Sec. 3.3 / App. G: MTGC with N=1 group and E=1 IS SCAFFOLD."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HFLConfig,
    as_tree,
    global_model,
    hfl_init,
    make_global_round,
    make_scaffold_round,
    scaffold_init,
)

from test_mtgc_engine import D, make_batches, quad_loss


def test_mtgc_reduces_to_scaffold():
    K, H, lr = 4, 5, 0.05
    a, b, batches = make_batches(1, K, 1, H, seed=7)

    # MTGC, one group, E=1, theoretical (gradient) correction init
    cfg = HFLConfig(num_groups=1, clients_per_group=K, local_steps=H,
                    group_rounds=1, lr=lr, algorithm="mtgc",
                    correction_init="gradient")
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    mtgc_fn = jax.jit(make_global_round(quad_loss, cfg))

    # SCAFFOLD option I (fresh-gradient control variates)
    sc_state = scaffold_init({"w": jnp.zeros(D)}, K)
    sc_fn = jax.jit(make_scaffold_round(quad_loss, K, H, lr, option="I"))
    sc_batches = {k: jnp.asarray(v[0][:, 0]) for k, v in batches.items()}  # [H,K,...]

    for _ in range(3):
        state, _ = mtgc_fn(state, jax.tree.map(jnp.asarray, batches))
        sc_state, _ = sc_fn(sc_state, sc_batches)
        got = np.asarray(global_model(state)["w"])
        want = np.asarray(sc_state.params["w"][0])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_y_is_zero_for_single_group():
    """With N=1, the group IS the system: y_1 = 0 identically (Sec. 3.3)."""
    cfg = HFLConfig(num_groups=1, clients_per_group=3, local_steps=4,
                    group_rounds=2, lr=0.05, algorithm="mtgc")
    a, b, batches = make_batches(1, 3, 2, 4, seed=8)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    for _ in range(3):
        state, _ = rf(state, jax.tree.map(jnp.asarray, batches))
        np.testing.assert_allclose(np.asarray(as_tree(state.y)["w"]), 0.0, atol=1e-6)
