"""Checkpoint round-trips for the engines' states (repro.checkpoint).

The flat-buffer layouts (``core.packer.FlatBuffers``) and the sharded
state's participation ``rng`` must survive save -> restore *losslessly*:
a restored state driven one more round must be bit-identical to the
original state driven one more round (same batches, same masks -- the rng
words are part of the state).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint import latest_step, restore, save
from repro.core import HFLConfig, PackedBatches, hfl_init, select_round

from test_mtgc_engine import D, quad_loss

G, K, E, H = 2, 3, 2, 2


def make_data(microbatches=None, seed=0, key=1):
    rng = np.random.default_rng(seed)
    steps = H * (microbatches or 1)
    shape = (G, K, 4, steps, D)
    arrays = {
        "a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
        "b": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
    }
    return PackedBatches(arrays, jax.random.PRNGKey(key), E, H, microbatches)


def assert_states_equal(a, b, tag):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), tag
    for i, (x, y) in enumerate(zip(la, lb)):
        assert np.asarray(x).dtype == np.asarray(y).dtype, f"{tag}[leaf {i}]"
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{tag}[leaf {i}]")


def one_round(engine, state, microbatches=None):
    batches = select_round(make_data(microbatches), jax.random.PRNGKey(7))
    return engine.round_fn(state, batches)[0]


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_flat_hfl_state_roundtrip_bitexact(layout, tmp_path):
    spec = api.ExperimentSpec(
        levels=(G, K), state_layout=layout, lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        client_participation=0.5)
    engine = api.build(spec, quad_loss)
    state = engine.init({"w": jnp.ones(D)}, jax.random.PRNGKey(3))
    state = one_round(engine, state)      # populate z/y/dyn + advance rng

    save(str(tmp_path), 1, state)
    assert latest_step(str(tmp_path)) == 1
    like = engine.init({"w": jnp.zeros(D)}, jax.random.PRNGKey(0))
    restored = restore(str(tmp_path), 1, like)
    assert_states_equal(restored, state, f"{layout}/roundtrip")

    # One more round from the restored state is bit-identical -- including
    # the participation masks its rng drives.
    assert_states_equal(one_round(engine, restored),
                        one_round(engine, state), f"{layout}/one-round")


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_sharded_state_rng_roundtrip_bitexact(layout, tmp_path):
    spec = api.ExperimentSpec(
        levels=(G, K), backend="sharded", state_layout=layout, lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H,
                                   microbatches=1),
        client_participation=0.5, group_participation=0.75)
    engine = api.build(spec, quad_loss)
    state = engine.init({"w": jnp.ones(D)}, jax.random.PRNGKey(11))
    state = one_round(engine, state, microbatches=1)

    save(str(tmp_path), 5, state)
    like = engine.init({"w": jnp.zeros(D)}, jax.random.PRNGKey(0))
    restored = restore(str(tmp_path), 5, like)
    assert_states_equal(restored, state, f"sharded/{layout}")
    np.testing.assert_array_equal(np.asarray(restored.rng),
                                  np.asarray(state.rng))
    assert_states_equal(one_round(engine, restored, microbatches=1),
                        one_round(engine, state, microbatches=1),
                        f"sharded/{layout}/one-round")


def test_sharded_none_rng_survives(tmp_path):
    spec = api.ExperimentSpec(
        levels=(G, K), backend="sharded", state_layout="tree", lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H,
                                   microbatches=1))
    engine = api.build(spec, quad_loss)
    state = engine.init({"w": jnp.ones(D)})
    assert state.rng is None              # full participation: no mask rng
    save(str(tmp_path), 2, state)
    restored = restore(str(tmp_path), 2, state)
    assert restored.rng is None
    assert_states_equal(restored, state, "sharded/none-rng")


def test_restore_structure_mismatch_raises(tmp_path):
    cfg = HFLConfig(num_groups=G, clients_per_group=K)
    state = hfl_init({"w": jnp.ones(D)}, cfg)
    save(str(tmp_path), 1, state)
    other = hfl_init({"w": jnp.ones(D), "v": jnp.ones(2)}, cfg)
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, other)   # missing leaf in the checkpoint
    wide = hfl_init({"w": jnp.ones(D + 1)}, cfg)
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, wide)    # shape mismatch


def test_fit_autosave_and_resume_bitexact(tmp_path):
    """fit(checkpoint_every=, checkpoint_path=) autosaves at chunk
    boundaries; fit(resume=True) restores the latest checkpoint and runs
    only the remaining rounds -- bit-exact vs the uninterrupted run."""
    spec = api.ExperimentSpec(
        levels=(G, K), state_layout="flat", lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        client_participation=0.5)
    engine = api.build(spec, quad_loss)
    data = make_data()
    params = {"w": jnp.ones(D)}

    sA, hA = api.fit(engine, data, 6, params=params,
                     rng=jax.random.PRNGKey(3), checkpoint_every=2,
                     checkpoint_path=str(tmp_path), donate=False)
    assert latest_step(str(tmp_path)) == 6
    assert sorted(p.name for p in tmp_path.glob("*.npz")) == [
        "ckpt_00000002.npz", "ckpt_00000004.npz", "ckpt_00000006.npz"]

    # Simulate a crash after round 4: drop the final checkpoint, resume.
    for p in tmp_path.glob("*0006*"):
        p.unlink()
    sB, hB = api.fit(engine, data, 6, params=params,
                     rng=jax.random.PRNGKey(3), checkpoint_every=2,
                     checkpoint_path=str(tmp_path), resume=True,
                     donate=False)
    assert_states_equal(sA, sB, "resume")
    assert len(np.asarray(hB.metrics.loss)) == 2      # rounds 5-6 only
    assert latest_step(str(tmp_path)) == 6            # re-saved on the way

    # resume past the horizon is an explicit error, not a silent no-op.
    with pytest.raises(ValueError, match="nothing left"):
        api.fit(engine, data, 4, params=params, rng=jax.random.PRNGKey(3),
                checkpoint_every=2, checkpoint_path=str(tmp_path),
                resume=True, donate=False)


def test_fit_checkpoint_needs_path():
    spec = api.ExperimentSpec(levels=(G, K), lr=0.05,
                              schedule=api.RoundSchedule(group_rounds=E,
                                                         local_steps=H))
    engine = api.build(spec, quad_loss)
    with pytest.raises(ValueError, match="checkpoint_path"):
        api.fit(engine, make_data(), 2, params={"w": jnp.ones(D)},
                checkpoint_every=2)
