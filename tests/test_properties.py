"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import HFLConfig, as_tree, global_model, hfl_init, make_global_round
from repro.core import tree as tu

from test_mtgc_engine import D, make_batches, quad_loss


# ----------------------------------------------------------- tree algebra


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 64), s=st.floats(-3, 3))
def test_tree_axpy_linear(n, s):
    rng = np.random.default_rng(n)
    a = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
         "b": {"c": jnp.asarray(rng.normal(size=(2, n)), jnp.float32)}}
    b = jax.tree.map(jnp.ones_like, a)
    out = tu.tree_axpy(s, a, b)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               s * np.asarray(a["w"]) + 1.0, rtol=1e-5)
    dot_aa = tu.tree_dot(a, a)
    assert float(dot_aa) >= 0
    np.testing.assert_allclose(float(tu.tree_sq_norm(a)), float(dot_aa))


@settings(max_examples=15, deadline=None)
@given(g=st.integers(1, 4), k=st.integers(1, 4))
def test_tree_mean_broadcast_roundtrip(g, k):
    rng = np.random.default_rng(g * 7 + k)
    a = {"w": jnp.asarray(rng.normal(size=(g, k, 3)), jnp.float32)}
    m = tu.tree_mean(a, axis=1)
    back = tu.tree_broadcast_to_axis(m, 1, k)
    assert back["w"].shape == (g, k, 3)
    # mean is idempotent through broadcast
    np.testing.assert_allclose(np.asarray(tu.tree_mean(back, axis=1)["w"]),
                               np.asarray(m["w"]), rtol=1e-6)


# ------------------------------------------------------- engine invariants


@settings(max_examples=8, deadline=None)
@given(G=st.integers(1, 3), K=st.integers(1, 3),
       E=st.integers(1, 3), H=st.integers(1, 4))
def test_invariants_hold_for_random_topologies(G, K, E, H):
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    a, b, batches = make_batches(G, K, E, H, seed=G * 97 + K * 13 + E + H)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    state, m = jax.jit(make_global_round(quad_loss, cfg))(
        state, jax.tree.map(jnp.asarray, batches))
    np.testing.assert_allclose(np.asarray(as_tree(state.z)["w"]).sum(1), 0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(as_tree(state.y)["w"]).sum(0), 0, atol=1e-5)
    # all clients equal after dissemination
    x = np.asarray(as_tree(state.params)["w"])
    np.testing.assert_allclose(x, np.broadcast_to(x[:1, :1], x.shape),
                               atol=1e-6)
    assert np.isfinite(np.asarray(m.loss)).all()


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 100))
def test_client_permutation_equivariance(seed):
    """Permuting clients inside a group permutes z and leaves the global
    model unchanged (aggregations are symmetric means)."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    a, b, batches = make_batches(G, K, E, H, seed=seed)
    rf = jax.jit(make_global_round(quad_loss, cfg))

    st0 = hfl_init({"w": jnp.zeros(D)}, cfg)
    st1, _ = rf(st0, jax.tree.map(jnp.asarray, batches))

    perm = np.random.default_rng(seed).permutation(K)
    pb = {k: jnp.asarray(v[:, :, :, perm]) for k, v in batches.items()}
    st2, _ = rf(st0, pb)

    # float reductions over permuted operands differ in the last ulp and
    # the z update amplifies by 1/(H*lr): compare with matching slack
    np.testing.assert_allclose(np.asarray(global_model(st1)["w"]),
                               np.asarray(global_model(st2)["w"]),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(as_tree(st1.z)["w"])[:, perm],
                               np.asarray(as_tree(st2.z)["w"]), rtol=1e-3, atol=5e-4)
