"""API-surface snapshot: accidental breaks of the front door fail tier-1.

``repro.api`` is the one construction path every entry layer uses, so its
surface is a compatibility contract: ``__all__``, the
``ExperimentSpec`` / ``RoundSchedule`` field names *and defaults*, and the
declarative CLI table are snapshotted here. Deliberate surface changes
update the snapshot in the same PR -- silent drift does not pass CI.

Also smoke-covers the deliberately-standalone serving entry points
(examples/serve_decode.py, repro.launch.serve) so import rot there is
caught by the blocking job too.
"""
import dataclasses
import importlib

from repro import api

EXPECTED_ALL = [
    "ALGORITHMS",
    "BACKENDS",
    "BACKEND_ALGORITHMS",
    "CLIENT_STATES",
    "CLI_FLAGS",
    "COMPRESSION_MODES",
    "CliFlag",
    "CompressionPlan",
    "DefensePlan",
    "Engine",
    "ExperimentSpec",
    "FAULT_KINDS",
    "FUSIONS",
    "FaultPlan",
    "GuardReport",
    "GuardSpec",
    "Horizon",
    "LAYOUTS",
    "LoweredChunk",
    "MultiLevelEngine",
    "MultiLevelMetrics",
    "PackedBatches",
    "PopulationStore",
    "RoundSchedule",
    "STALENESS_POLICIES",
    "ShardedEngine",
    "SimulatorEngine",
    "add_spec_args",
    "build",
    "fit",
    "run_population_rounds",
    "spec_from_args",
]

EXPECTED_SPEC_FIELDS = {
    "levels": (2, 2),
    "schedule": api.RoundSchedule(),
    "algorithm": "mtgc",
    "lr": 0.1,
    "backend": "simulator",
    "state_layout": "flat",
    "fusion": "none",
    "fused_mode": None,
    "correction_init": "zero",
    "prox_mu": 0.0,
    "feddyn_alpha": 0.0,
    "server_lr": 1.0,
    "client_participation": 1.0,
    "group_participation": 1.0,
    "level_participation": None,
    "participation_mode": "uniform",
    "participation_weighting": "none",
    "correction_dtype": None,
    "staleness": "sync",
    "max_staleness": None,
    "population": None,
    "cohort_size": None,
    "client_state": "stateful",
    "faults": None,
    "defense": None,
    "compression": None,
}

EXPECTED_SCHEDULE_FIELDS = {
    "group_rounds": 2,
    "local_steps": 5,
    "microbatches": None,
    "periods": None,
}


def test_api_all_snapshot():
    assert sorted(api.__all__) == EXPECTED_ALL
    for name in api.__all__:
        assert hasattr(api, name), name


def test_experiment_spec_fields_and_defaults_snapshot():
    fields = {f.name: f.default for f in dataclasses.fields(api.ExperimentSpec)}
    assert fields == EXPECTED_SPEC_FIELDS


def test_round_schedule_fields_and_defaults_snapshot():
    fields = {f.name: f.default for f in dataclasses.fields(api.RoundSchedule)}
    assert fields == EXPECTED_SCHEDULE_FIELDS


def test_cli_table_covers_spec_and_round_trips():
    """Every CLI table row targets a real spec field, and a parsed command
    line reconstructs the spec it describes."""
    import argparse

    spec_fields = {f.name for f in dataclasses.fields(api.ExperimentSpec)}
    nested_fields = {
        "schedule": {f.name for f in dataclasses.fields(api.RoundSchedule)},
        "faults": {f.name for f in dataclasses.fields(api.FaultPlan)},
        "defense": {f.name for f in dataclasses.fields(api.DefensePlan)},
        "compression": {
            f.name for f in dataclasses.fields(api.CompressionPlan)},
    }
    for row in api.CLI_FLAGS:
        target, _, sub = row.field.partition(".")
        assert target in spec_fields, row.field
        if sub:
            assert sub in nested_fields[target], row.field

    ap = argparse.ArgumentParser()
    api.add_spec_args(ap)
    args = ap.parse_args([
        "--levels", "3", "4", "--E", "6", "--H", "7", "--algorithm",
        "feddyn", "--lr", "0.25", "--state-layout", "tree",
        "--client-participation", "0.5", "--weighting", "inverse_prob"])
    spec = api.spec_from_args(args)
    assert spec.levels == (3, 4)
    assert spec.schedule.group_rounds == 6
    assert spec.schedule.local_steps == 7
    assert (spec.algorithm, spec.lr) == ("feddyn", 0.25)
    assert spec.state_layout == "tree"
    assert spec.client_participation == 0.5
    assert spec.participation_weighting == "inverse_prob"
    spec.validate()

    # Optional rows are skipped while unset: --E decided group_rounds
    # above, and max_staleness kept its spec default.
    assert spec.max_staleness is None

    # Async flags round-trip; --group-rounds (a per-group tuple) wins
    # over --E.
    args_async = ap.parse_args([
        "--levels", "3", "4", "--E", "9", "--group-rounds", "4,2,1",
        "--staleness-policy", "discount", "--max-staleness", "3"])
    spec_async = api.spec_from_args(args_async)
    assert spec_async.schedule.group_rounds == (4, 2, 1)
    assert spec_async.staleness == "discount"
    assert spec_async.max_staleness == 3
    spec_async.validate()

    # Virtual-population flags round-trip; the optional rows stay unset
    # (spec defaults) when not given.
    assert (spec.population, spec.cohort_size) == (None, None)
    assert spec.client_state == "stateful"
    args_pop = ap.parse_args([
        "--levels", "2", "8", "--population", "1000", "--cohort-size", "8",
        "--client-state", "stateful"])
    spec_pop = api.spec_from_args(args_pop)
    assert spec_pop.population == 1000
    assert spec_pop.cohort_size == 8
    assert spec_pop.client_state == "stateful"
    spec_pop.validate()
    args_sl = ap.parse_args([
        "--levels", "2", "8", "--population", "64",
        "--client-state", "stateless"])
    spec_sl = api.spec_from_args(args_sl)
    assert spec_sl.client_state == "stateless"
    spec_sl.validate()

    # Fault / defense flags construct the nested plans on demand; unset
    # they stay None (the zero-fault legacy program).
    assert (spec.faults, spec.defense) == (None, None)
    args_flt = ap.parse_args([
        "--fault-crash", "0.05", "--fault-corrupt", "0.1",
        "--fault-kind", "explode", "--screen-norm", "4.0",
        "--clip-norm", "2.0"])
    spec_flt = api.spec_from_args(args_flt)
    assert spec_flt.faults == api.FaultPlan(
        crash_rate=0.05, corrupt_rate=0.1, corrupt_kind="explode")
    assert spec_flt.defense == api.DefensePlan(screen_norm=4.0, clip_norm=2.0)
    spec_flt.validate()

    # Compression flags construct the nested plan on demand; unset it
    # stays None (the uncompressed legacy program).
    assert spec.compression is None
    args_cmp = ap.parse_args([
        "--compress-client", "int8_stochastic", "--compress-group", "topk",
        "--topk-frac", "0.05", "--error-feedback", "1"])
    spec_cmp = api.spec_from_args(args_cmp)
    assert spec_cmp.compression == api.CompressionPlan(
        client_mode="int8_stochastic", group_mode="topk",
        error_feedback=1, topk_frac=0.05)
    spec_cmp.validate()

    # Overrides (entry-point pins) win over parsed values.
    pinned = api.spec_from_args(args, backend="sharded", microbatches=1,
                                algorithm="mtgc")
    assert pinned.backend == "sharded"
    assert pinned.schedule.microbatches == 1
    assert pinned.algorithm == "mtgc"

    # Excluded rows disappear from the parser.
    ap2 = argparse.ArgumentParser()
    api.add_spec_args(ap2, exclude=("backend",))
    assert "--backend" not in ap2.format_help()


def test_legacy_constructors_are_delegating_shims():
    """The three make_*_round entry points delegate to repro.api (their
    docstrings say so, and they keep working)."""
    from repro.core import make_global_round, make_multilevel_round
    from repro.launch.train import make_sharded_round

    for fn in (make_global_round, make_multilevel_round, make_sharded_round):
        assert "deprecated" in fn.__doc__
        assert "repro.api.build" in fn.__doc__


def test_standalone_serving_entry_points_import():
    """serve_decode / launch.serve are standalone from repro.api by design;
    keep them importable (and documented as such)."""
    serve_demo = importlib.import_module("examples.serve_decode")
    assert "standalone" in serve_demo.__doc__.lower()
    serve = importlib.import_module("repro.launch.serve")
    assert "standalone" in serve.__doc__.lower()
    assert callable(serve.make_serve_step)


def test_repro_api_module_reexports_core_api():
    import repro.api as front
    import repro.core.api as impl

    assert front.__all__ == impl.__all__
    assert front.build is impl.build
    assert front.ExperimentSpec is impl.ExperimentSpec
