"""Flat-buffer state (core/packer.py) vs the pytree reference path.

Three layers of evidence:

* pack/unpack round-trip property tests on ragged-leaf, mixed-dtype trees
  with arbitrary leading topology axes;
* engine parity: flat and tree states must agree (allclose, rtol 1e-5) on
  every state field *and* every metric after 3 global rounds, for all six
  algorithms and for partial participation under both sampling modes;
* multilevel + fused-kernel parity for the same 3-round protocol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ALGORITHMS,
    FlatBuffers,
    HFLConfig,
    as_tree,
    hfl_init,
    make_global_round,
    make_packer,
)
from repro.core import multilevel as ml

from test_mtgc_engine import D, make_batches, quad_loss

# ----------------------------------------------------- pack/unpack round trip


def _ragged_tree(rng, shapes, dtypes):
    leaves = [jnp.asarray(rng.normal(size=s) * 3, d) for s, d in zip(shapes, dtypes)]
    return {"a": leaves[0], "nest": {"b": leaves[1], "c": (leaves[2], leaves[3])}}


@settings(max_examples=25, deadline=None)
@given(
    s0=st.tuples(st.integers(1, 5)),
    s1=st.tuples(st.integers(1, 4), st.integers(1, 6)),
    s2=st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 4)),
    lead=st.sampled_from([(), (3,), (2, 4)]),
    mixed=st.booleans(),
)
def test_pack_unpack_roundtrip_ragged(s0, s1, s2, lead, mixed):
    rng = np.random.default_rng(sum(s0) + sum(s1) + sum(s2) + len(lead))
    dtypes = ([jnp.float32, jnp.bfloat16, jnp.float32, jnp.int32] if mixed
              else [jnp.float32] * 4)
    tpl = _ragged_tree(rng, [s0, s1, s2, ()], dtypes)
    packer = make_packer(tpl)
    tree = jax.tree.map(lambda x: jnp.broadcast_to(x, lead + x.shape), tpl)
    flat = packer.flatten(tree)
    assert flat.lead_shape == lead
    # one contiguous buffer per dtype, sizes add up
    total = sum(x.size for x in jax.tree.leaves(tpl))
    assert sum(b.shape[-1] for b in flat.bufs.values()) == total
    back = packer.unflatten(flat)
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert want.dtype == got.dtype and want.shape == got.shape
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_flat_buffers_ride_through_jit_scan_and_grad():
    tpl = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
           "b": jnp.ones((4,), jnp.float32)}
    packer = make_packer(tpl)
    fb = packer.flatten(tpl)

    doubled = jax.jit(lambda t: jax.tree.map(lambda x: 2 * x, t))(fb)
    assert isinstance(doubled, FlatBuffers) and doubled.packer == packer

    def body(c, _):
        return jax.tree.map(lambda x: x + 1, c), 0
    scanned, _ = jax.lax.scan(body, fb, jnp.arange(3))
    assert isinstance(scanned, FlatBuffers)

    g = jax.grad(lambda t: sum(jnp.sum(b ** 2) for b in t.bufs.values()))(fb)
    assert isinstance(g, FlatBuffers)
    np.testing.assert_allclose(np.asarray(g.bufs["float32"]),
                               2 * np.asarray(fb.bufs["float32"]))


def test_as_tree_is_identity_on_pytrees():
    t = {"w": jnp.zeros(3)}
    assert as_tree(t) is t


# ----------------------------------------------------------- engine parity


def _run_engine(cfg, batches, rounds=3):
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf = jax.jit(make_global_round(quad_loss, cfg))
    metrics = None
    for _ in range(rounds):
        state, metrics = rf(state, batches)
    return state, metrics


def _assert_state_parity(st_tree, st_flat, m_tree, m_flat, tag):
    for name in ("params", "z", "y", "dyn"):
        np.testing.assert_allclose(
            np.asarray(getattr(st_tree, name)["w"]),
            np.asarray(as_tree(getattr(st_flat, name))["w"]),
            rtol=1e-5, atol=1e-6, err_msg=f"{tag}.{name}")
    for name in m_tree._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(m_tree, name)),
            np.asarray(getattr(m_flat, name)),
            rtol=1e-5, atol=1e-6, err_msg=f"{tag}.metrics.{name}")


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_flat_matches_tree_all_algorithms(algo):
    G, K, E, H = 2, 3, 2, 3
    _, _, batches = make_batches(G, K, E, H, seed=61)
    jb = jax.tree.map(jnp.asarray, batches)
    kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
              group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
              feddyn_alpha=0.1)
    st_t, m_t = _run_engine(HFLConfig(use_flat_state=False, **kw), jb)
    st_f, m_f = _run_engine(HFLConfig(use_flat_state=True, **kw), jb)
    assert isinstance(st_f.params, FlatBuffers)
    assert not isinstance(st_t.params, FlatBuffers)
    _assert_state_parity(st_t, st_f, m_t, m_f, algo)


@pytest.mark.parametrize("algo", ["mtgc", "hfedavg", "feddyn"])
@pytest.mark.parametrize("mode", ["uniform", "fixed"])
def test_flat_matches_tree_partial_participation(algo, mode):
    G, K, E, H = 3, 4, 2, 3
    _, _, batches = make_batches(G, K, E, H, seed=62)
    jb = jax.tree.map(jnp.asarray, batches)
    kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
              group_rounds=E, lr=0.05, algorithm=algo, feddyn_alpha=0.1,
              client_participation=0.5, group_participation=0.75,
              participation_mode=mode)
    # identical state.rng streams -> identical masks on both paths
    st_t, m_t = _run_engine(HFLConfig(use_flat_state=False, **kw), jb)
    st_f, m_f = _run_engine(HFLConfig(use_flat_state=True, **kw), jb)
    _assert_state_parity(st_t, st_f, m_t, m_f, f"{algo}/{mode}")


def test_flat_matches_tree_gradient_init():
    G, K, E, H = 2, 3, 2, 2
    _, _, batches = make_batches(G, K, E, H, seed=63)
    jb = jax.tree.map(jnp.asarray, batches)
    kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
              group_rounds=E, lr=0.05, algorithm="mtgc",
              correction_init="gradient")
    st_t, m_t = _run_engine(HFLConfig(use_flat_state=False, **kw), jb)
    st_f, m_f = _run_engine(HFLConfig(use_flat_state=True, **kw), jb)
    _assert_state_parity(st_t, st_f, m_t, m_f, "gradient-init")


@pytest.mark.parametrize("partial_c", [1.0, 0.5])
def test_flat_fused_kernel_matches_tree(partial_c):
    """The batched Pallas call (interpret mode off-TPU) over the whole flat
    model, participation mask folded in, equals the per-leaf tree path."""
    G, K, E, H = 2, 3, 2, 2
    _, _, batches = make_batches(G, K, E, H, seed=64)
    jb = jax.tree.map(jnp.asarray, batches)
    kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
              group_rounds=E, lr=0.05, algorithm="mtgc",
              client_participation=partial_c)
    st_t, m_t = _run_engine(HFLConfig(use_flat_state=False, **kw), jb)
    st_f, m_f = _run_engine(
        HFLConfig(use_flat_state=True, use_fused_update=True, **kw), jb)
    _assert_state_parity(st_t, st_f, m_t, m_f, f"fused/{partial_c}")


# -------------------------------------------------------- multilevel parity


@pytest.mark.parametrize("participation", [None, (1.0, 0.5, 0.5)])
def test_multilevel_flat_matches_tree(participation):
    dims, periods, lr = (2, 2, 3), (12, 4, 2), 0.05
    rng = np.random.default_rng(65)
    batches = {
        "a": jnp.asarray(rng.normal(size=(periods[0],) + dims + (D,)),
                         jnp.float32) + 2.0,
        "b": jnp.asarray(rng.normal(size=(periods[0],) + dims + (D,)),
                         jnp.float32),
    }
    rf = jax.jit(ml.make_multilevel_round(quad_loss, dims, periods, lr,
                                          participation=participation))
    st_t = ml.multilevel_init({"w": jnp.zeros(D)}, dims)
    st_f = ml.multilevel_init({"w": jnp.zeros(D)}, dims, use_flat_state=True)
    for _ in range(3):
        st_t, l_t = rf(st_t, batches)
        st_f, l_f = rf(st_f, batches)
    np.testing.assert_allclose(np.asarray(as_tree(st_f.params)["w"]),
                               np.asarray(st_t.params["w"]),
                               rtol=1e-5, atol=1e-6)
    for m in range(len(dims)):
        np.testing.assert_allclose(np.asarray(as_tree(st_f.nus[m])["w"]),
                                   np.asarray(st_t.nus[m]["w"]),
                                   rtol=1e-5, atol=1e-6, err_msg=f"nu{m}")
    np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_t),
                               rtol=1e-5, atol=1e-6)
