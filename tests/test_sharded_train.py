"""The production (sharded, microbatched) round == the paper engine.

``launch.train.make_sharded_round`` is what the multi-pod dry-run lowers;
this proves it computes exactly Algorithm 1 (via the core engine, which is
itself oracle-checked), including when gradients are accumulated over A
microbatch chunks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFLConfig, global_model, hfl_init, make_global_round
from repro.launch.train import make_sharded_round, sharded_init

from test_mtgc_engine import D, make_batches, quad_loss


def quad_loss_mean(params, batch):
    """Chunked variant: mean over a leading sample axis so that grad
    accumulation with A chunks averages to the same full-batch gradient."""
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.mean(jnp.sum(r * r, axis=-1))


def test_sharded_round_equals_engine():
    G, K, E, H, lr = 2, 2, 2, 3, 0.05
    a, b, batches = make_batches(G, K, E, H, seed=21)

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc")
    st_core = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf_core = jax.jit(make_global_round(quad_loss, cfg))

    st_prod = sharded_init({"w": jnp.zeros(D)}, G, K)
    rf_prod = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=lr))
    # sharded layout: [E, H, A=1, G, K, ...]
    pbatches = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}

    for _ in range(3):
        st_core, _ = rf_core(st_core, jax.tree.map(jnp.asarray, batches))
        st_prod, m = rf_prod(st_prod, pbatches)
        got = np.asarray(st_prod.params["w"][0, 0])
        want = np.asarray(global_model(st_core)["w"])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        # invariants survive the production path too
        np.testing.assert_allclose(
            np.asarray(st_prod.z["w"]).sum(axis=1), 0.0, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(st_prod.y["w"]).sum(axis=0), 0.0, atol=1e-5)


@pytest.mark.slow
def test_grad_accumulation_is_exact():
    """A chunks of size c == one step on the full A*c batch (mean loss)."""
    G, K, E, H, lr = 2, 2, 1, 2, 0.05
    rng = np.random.default_rng(22)
    A, c = 4, 3
    a = rng.normal(size=(E, H, A, G, K, c, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(E, H, A, G, K, c, D)).astype(np.float32)

    rf = jax.jit(make_sharded_round(quad_loss_mean, E=E, H=H, lr=lr))
    st = sharded_init({"w": jnp.zeros(D)}, G, K)
    st1, _ = rf(st, {"a": jnp.asarray(a), "b": jnp.asarray(b)})

    # same samples, single chunk of A*c
    def regroup(x):
        return x.transpose(0, 1, 3, 4, 2, 5, 6).reshape(E, H, 1, G, K, A * c, D)
    st2, _ = rf(st, {"a": jnp.asarray(regroup(a)), "b": jnp.asarray(regroup(b))})
    np.testing.assert_allclose(np.asarray(st1.params["w"]),
                               np.asarray(st2.params["w"]), rtol=1e-5, atol=1e-6)


def test_hfedavg_mode_drops_corrections():
    G, K, E, H = 2, 2, 2, 2
    a, b, batches = make_batches(G, K, E, H, seed=23)
    rf = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=0.05,
                                    algorithm="hfedavg"))
    st = sharded_init({"w": jnp.zeros(D)}, G, K)
    st, _ = rf(st, {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()})
    np.testing.assert_allclose(np.asarray(st.z["w"]), 0.0)
    np.testing.assert_allclose(np.asarray(st.y["w"]), 0.0)
