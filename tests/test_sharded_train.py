"""The production (sharded, microbatched) round == the paper engine.

``launch.train.make_sharded_round`` is what the multi-pod dry-run lowers;
this proves it computes exactly Algorithm 1 (via the core engine, which is
itself oracle-checked), including when gradients are accumulated over A
microbatch chunks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFLConfig, as_tree, global_model, hfl_init, make_global_round
from repro.launch.train import make_sharded_round, sharded_init

from test_mtgc_engine import D, make_batches, quad_loss


def quad_loss_mean(params, batch):
    """Chunked variant: mean over a leading sample axis so that grad
    accumulation with A chunks averages to the same full-batch gradient."""
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.mean(jnp.sum(r * r, axis=-1))


def test_sharded_round_equals_engine():
    G, K, E, H, lr = 2, 2, 2, 3, 0.05
    a, b, batches = make_batches(G, K, E, H, seed=21)

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc")
    st_core = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf_core = jax.jit(make_global_round(quad_loss, cfg))

    st_prod = sharded_init({"w": jnp.zeros(D)}, G, K)
    rf_prod = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=lr))
    # sharded layout: [E, H, A=1, G, K, ...]
    pbatches = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}

    for _ in range(3):
        st_core, _ = rf_core(st_core, jax.tree.map(jnp.asarray, batches))
        st_prod, m = rf_prod(st_prod, pbatches)
        got = np.asarray(st_prod.params["w"][0, 0])
        want = np.asarray(global_model(st_core)["w"])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        # invariants survive the production path too
        np.testing.assert_allclose(
            np.asarray(st_prod.z["w"]).sum(axis=1), 0.0, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(st_prod.y["w"]).sum(axis=0), 0.0, atol=1e-5)


@pytest.mark.slow
def test_grad_accumulation_is_exact():
    """A chunks of size c == one step on the full A*c batch (mean loss)."""
    G, K, E, H, lr = 2, 2, 1, 2, 0.05
    rng = np.random.default_rng(22)
    A, c = 4, 3
    a = rng.normal(size=(E, H, A, G, K, c, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(E, H, A, G, K, c, D)).astype(np.float32)

    rf = jax.jit(make_sharded_round(quad_loss_mean, E=E, H=H, lr=lr))
    st = sharded_init({"w": jnp.zeros(D)}, G, K)
    st1, _ = rf(st, {"a": jnp.asarray(a), "b": jnp.asarray(b)})

    # same samples, single chunk of A*c
    def regroup(x):
        return x.transpose(0, 1, 3, 4, 2, 5, 6).reshape(E, H, 1, G, K, A * c, D)
    st2, _ = rf(st, {"a": jnp.asarray(regroup(a)), "b": jnp.asarray(regroup(b))})
    np.testing.assert_allclose(np.asarray(st1.params["w"]),
                               np.asarray(st2.params["w"]), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_flat", [False, True])
def test_fused_sharded_round_matches_unfused(use_flat):
    """The fused Pallas kernel (interpret mode off-TPU) behind
    ``use_fused_update`` computes exactly the unfused sharded round --
    including the folded microbatch mean g/A -- on both state layouts."""
    G, K, E, H, lr, A = 2, 2, 2, 3, 0.05, 2
    rng = np.random.default_rng(24)
    a = rng.normal(size=(E, H, A, G, K, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(E, H, A, G, K, D)).astype(np.float32)
    batches = {"a": jnp.asarray(a), "b": jnp.asarray(b)}

    rf_ref = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=lr))
    rf_fused = jax.jit(make_sharded_round(
        quad_loss, E=E, H=H, lr=lr, use_fused_update=True,
        fused_mode="interpret"))
    st_ref = sharded_init({"w": jnp.zeros(D)}, G, K)
    st_fused = sharded_init({"w": jnp.zeros(D)}, G, K, use_flat_state=use_flat)
    for _ in range(3):
        st_ref, m_ref = rf_ref(st_ref, batches)
        st_fused, m_fused = rf_fused(st_fused, batches)
    for name in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(as_tree(getattr(st_fused, name))["w"]),
            np.asarray(getattr(st_ref, name)["w"]),
            rtol=1e-5, atol=1e-6, err_msg=name)
    np.testing.assert_allclose(np.asarray(m_fused.loss),
                               np.asarray(m_ref.loss), rtol=1e-5)


@pytest.mark.parametrize("algorithm", ["mtgc", "hfedavg"])
def test_flat_sharded_round_matches_tree(algorithm):
    G, K, E, H, lr = 2, 3, 2, 2, 0.05
    a, b, batches = make_batches(G, K, E, H, seed=25)
    pb = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}
    rf = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=lr,
                                    algorithm=algorithm))
    st_t = sharded_init({"w": jnp.zeros(D)}, G, K)
    st_f = sharded_init({"w": jnp.zeros(D)}, G, K, use_flat_state=True)
    for _ in range(3):
        st_t, m_t = rf(st_t, pb)
        st_f, m_f = rf(st_f, pb)
    for name in ("params", "z", "y"):
        np.testing.assert_allclose(
            np.asarray(as_tree(getattr(st_f, name))["w"]),
            np.asarray(getattr(st_t, name)["w"]),
            rtol=1e-5, atol=1e-6, err_msg=name)
    np.testing.assert_allclose(np.asarray(m_f.loss), np.asarray(m_t.loss),
                               rtol=1e-5)


def test_correction_dtype_is_stored_narrow_and_rejected_for_flat():
    """bf16 z/y storage survives the round (update math in f32) and is
    incompatible with the flat layout (one buffer per dtype)."""
    G, K, E, H = 2, 2, 1, 2
    a, b, batches = make_batches(G, K, E, H, seed=26)
    pb = {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()}
    st = sharded_init({"w": jnp.zeros(D)}, G, K, correction_dtype=jnp.bfloat16)
    assert st.z["w"].dtype == jnp.bfloat16 and st.y["w"].dtype == jnp.bfloat16
    rf = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=0.05))
    st, m = rf(st, pb)
    assert st.z["w"].dtype == jnp.bfloat16 and st.y["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(m.loss)).all()
    with pytest.raises(ValueError):
        sharded_init({"w": jnp.zeros(D)}, G, K, use_flat_state=True,
                     correction_dtype=jnp.bfloat16)


def test_fused_sharded_rejected_for_hfedavg():
    # ValueError, not AssertionError: config checks must survive python -O.
    with pytest.raises(ValueError):
        make_sharded_round(quad_loss, E=1, H=1, lr=0.1, algorithm="hfedavg",
                           use_fused_update=True)


def test_hfedavg_mode_drops_corrections():
    G, K, E, H = 2, 2, 2, 2
    a, b, batches = make_batches(G, K, E, H, seed=23)
    rf = jax.jit(make_sharded_round(quad_loss, E=E, H=H, lr=0.05,
                                    algorithm="hfedavg"))
    st = sharded_init({"w": jnp.zeros(D)}, G, K)
    st, _ = rf(st, {k: jnp.asarray(v[:, :, None]) for k, v in batches.items()})
    np.testing.assert_allclose(np.asarray(st.z["w"]), 0.0)
    np.testing.assert_allclose(np.asarray(st.y["w"]), 0.0)
