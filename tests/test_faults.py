"""Fault injection, screened aggregation and the self-healing horizon.

Three layers of gates:

* ``core.faults`` primitives: deterministic mask draws off the carried rng
  stream, zero-rate plans producing exact zeros.
* Engine semantics vs the pure-python oracle (``oracle.mtgc_faulty_run``)
  per fault kind, replaying the engine's own ``fault_masks`` realization
  -- and the hard bit-exactness contract: a disabled plan traces the
  legacy program untouched (states bitwise equal), across layouts and
  participation.
* The guarded driver: rollback + retry on divergence, bounded retries,
  and ``repro.api.fit`` end-to-end (defended runs stay finite and
  converge; checkpointed guard composes).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import HFLConfig, as_tree, hfl_init
from repro.core import driver as drv
from repro.core import engine as eng
from repro.core.faults import (
    DefensePlan,
    FaultPlan,
    all_finite_mask,
    fault_masks,
    screen_and_clip,
)

from oracle import mtgc_faulty_run

D = 5


def quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def make_batches(G, K, E, H, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(G, K, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(G, K, D)).astype(np.float32)
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (E, H, G, K, D)).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (E, H, G, K, D)).copy()),
    }
    return a, b, batches


def np_grad(a, b):
    return lambda g, k, x: a[g, k] * (a[g, k] * x - b[g, k])


def replay_masks(rng, plan, G, K, rounds):
    """The exact fault realization the engine will draw, as numpy masks."""
    crash, timeout, corrupt = [], [], []
    for _ in range(rounds):
        fm, rng = fault_masks(rng, plan, G, K)
        crash.append(np.asarray(fm.crash))
        timeout.append(np.asarray(fm.timeout))
        corrupt.append(np.asarray(fm.corrupt))
    return np.stack(crash), np.stack(timeout), np.stack(corrupt)


def leaves_equal(s1, s2):
    return all(np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
               for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)))


# --------------------------------------------------------- primitives


def test_fault_masks_deterministic_and_key_discipline():
    plan = FaultPlan(crash_rate=0.3, timeout_rate=0.2, corrupt_rate=0.1)
    rng = jax.random.PRNGKey(7)
    m1, r1 = fault_masks(rng, plan, 3, 4)
    m2, r2 = fault_masks(rng, plan, 3, 4)
    assert leaves_equal(m1, m2) and np.array_equal(r1, r2)
    assert m1.crash.shape == (3, 4)
    assert m1.timeout.shape == (3,)
    assert m1.corrupt.shape == (3, 4)
    # The carried stream is split exactly once regardless of which rates
    # are active: the downstream trajectory does not depend on the mix.
    _, r3 = fault_masks(rng, FaultPlan(crash_rate=0.9), 3, 4)
    assert np.array_equal(r1, r3)


def test_zero_rate_masks_are_exact_zeros():
    m, _ = fault_masks(jax.random.PRNGKey(0), FaultPlan(corrupt_rate=0.5),
                       2, 3)
    assert np.all(np.asarray(m.crash) == 0)
    assert np.all(np.asarray(m.timeout) == 0)


def test_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(crash_rate=1.0).validate()
    with pytest.raises(ValueError):
        FaultPlan(corrupt_kind="zeroed").validate()
    with pytest.raises(ValueError):
        DefensePlan(screen_norm=-1.0).validate()
    with pytest.raises(ValueError):
        DefensePlan(retry_widen=1.5).validate()
    assert not FaultPlan().enabled
    assert FaultPlan(timeout_rate=0.1).enabled


def test_screen_and_clip_primitives():
    x0 = {"w": jnp.zeros((1, 3, 4))}
    delta = np.zeros((1, 3, 4), np.float32)
    delta[0, 0] = 1.0                     # norm 2, fine
    delta[0, 1] = np.nan                  # non-finite
    delta[0, 2] = 100.0                   # norm 200, over any threshold
    x_up = {"w": jnp.asarray(delta)}
    scr, ok = screen_and_clip(x0, x_up, DefensePlan(screen_norm=10.0))
    np.testing.assert_array_equal(np.asarray(ok), [[1.0, 0.0, 0.0]])
    # Clean entries keep their exact bits.
    np.testing.assert_array_equal(np.asarray(scr["w"])[0, 0], delta[0, 0])
    # Clipping rescales the over-norm delta onto the ball.
    clipped, ok2 = screen_and_clip(x0, x_up, DefensePlan(clip_norm=1.0))
    assert np.asarray(ok2)[0, 1] == 0.0   # nonfinite screen still on
    assert np.asarray(ok2)[0, 2] == 1.0   # over-norm is clipped, not screened
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["w"])[0, 2]), 1.0, rtol=1e-5)
    assert np.asarray(all_finite_mask(x_up, 2)).tolist() == [[1.0, 0.0, 1.0]]


# ------------------------------------------- zero-fault bit-exactness


@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("cp", [1.0, 0.5])
def test_disabled_plan_is_bit_exact(layout, cp):
    """faults=FaultPlan() (all rates zero) must trace the legacy program
    untouched: states bitwise equal after multiple rounds."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, client_participation=cp,
                    use_flat_state=layout == "flat")
    _, _, batches = make_batches(G, K, E, H)
    rng = jax.random.PRNGKey(3) if cp < 1.0 else None
    plain = eng._build_global_round(quad_loss, cfg)
    gated = eng._build_global_round(quad_loss, cfg, faults=FaultPlan())
    s1 = hfl_init({"w": jnp.zeros(D)}, cfg, rng)
    s2 = hfl_init({"w": jnp.zeros(D)}, cfg, rng)
    for _ in range(3):
        s1, m1 = jax.jit(plain)(s1, batches)
        s2, m2 = jax.jit(gated)(s2, batches)
    assert leaves_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(m1.loss), np.asarray(m2.loss))
    assert float(m2.screened) == 0.0


# ------------------------------------------------ oracle, per fault kind


def run_engine(cfg, plan, defense, batches, rounds, rng_seed=11):
    round_fn = jax.jit(eng._build_global_round(quad_loss, cfg, faults=plan,
                                               defense=defense))
    state = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(rng_seed))
    rng0 = state.rng
    scr = 0.0
    for _ in range(rounds):
        state, metrics = round_fn(state, batches)
        scr += float(metrics.screened)
    return state, scr, rng0


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_crash_faults_match_oracle(layout):
    G, K, E, H, lr, T = 2, 3, 2, 2, 0.05, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, use_flat_state=layout == "flat")
    a, b, batches = make_batches(G, K, E, H)
    plan = FaultPlan(crash_rate=0.4)
    state, _, rng0 = run_engine(cfg, plan, None, batches, T)
    crash, _, _ = replay_masks(rng0, plan, G, K, T)
    x, z, y, _ = mtgc_faulty_run(np.zeros(D), np_grad(a, b), G, K, E, H, lr,
                                 T, crash=crash)
    np.testing.assert_allclose(np.asarray(as_tree(state.params)["w"]), x,
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(as_tree(state.z)["w"]), z,
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(as_tree(state.y)["w"]), y,
                               rtol=2e-3, atol=2e-4)


def test_timeout_faults_match_oracle():
    G, K, E, H, lr, T = 3, 2, 2, 2, 0.05, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, use_flat_state=False)
    a, b, batches = make_batches(G, K, E, H, seed=4)
    plan = FaultPlan(timeout_rate=0.4)
    state, _, rng0 = run_engine(cfg, plan, None, batches, T)
    _, timeout, _ = replay_masks(rng0, plan, G, K, T)
    assert timeout.sum() > 0, "seed produced no timeouts; pick another"
    x, z, y, _ = mtgc_faulty_run(np.zeros(D), np_grad(a, b), G, K, E, H, lr,
                                 T, timeout=timeout)
    np.testing.assert_allclose(np.asarray(as_tree(state.params)["w"]), x,
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(as_tree(state.y)["w"]), y,
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("kind", ["explode", "nan"])
def test_corrupt_faults_match_oracle_defended(kind):
    """Corrupted uploads + the screen: engine states and the screened
    count match the oracle exactly (per kind)."""
    G, K, E, H, lr, T = 2, 3, 2, 2, 0.05, 3
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, use_flat_state=False)
    a, b, batches = make_batches(G, K, E, H, seed=5)
    plan = FaultPlan(corrupt_rate=0.3, corrupt_kind=kind)
    defense = DefensePlan(screen_norm=50.0 if kind == "explode" else None)
    state, scr, rng0 = run_engine(cfg, plan, defense, batches, T)
    _, _, corrupt = replay_masks(rng0, plan, G, K, T)
    assert corrupt.sum() > 0, "seed produced no corruptions; pick another"
    x, z, y, scr_want = mtgc_faulty_run(
        np.zeros(D), np_grad(a, b), G, K, E, H, lr, T, corrupt=corrupt,
        corrupt_kind=kind, screen_nonfinite=True,
        screen_norm=defense.screen_norm)
    assert scr == scr_want and scr > 0
    np.testing.assert_allclose(np.asarray(as_tree(state.params)["w"]), x,
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(as_tree(state.z)["w"]), z,
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(as_tree(state.y)["w"]), y,
                               rtol=2e-3, atol=2e-4)


def test_undefended_nan_corruption_poisons_undefended_only():
    """The failure the defense exists for: NaN uploads poison the global
    model without the screen, and never reach z/y/aggregates with it."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=False)
    _, _, batches = make_batches(G, K, E, H, seed=6)
    plan = FaultPlan(corrupt_rate=0.3, corrupt_kind="nan")
    bad_state, _, _ = run_engine(cfg, plan, None, batches, 2)
    assert not np.isfinite(np.asarray(as_tree(bad_state.params)["w"])).all()
    good_state, scr, _ = run_engine(cfg, plan, DefensePlan(), batches, 2)
    assert scr > 0
    for leaf in (good_state.z, good_state.y):
        assert np.isfinite(np.asarray(as_tree(leaf)["w"])).all()


def test_screened_client_correction_stays_frozen():
    """A screened contribution never integrates: the corrupted client's z
    stays at its reset value (zero) for the faulted round."""
    G, K, E, H = 1, 3, 1, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=False)
    _, _, batches = make_batches(G, K, E, H, seed=7)
    plan = FaultPlan(corrupt_rate=0.45, corrupt_kind="nan")
    round_fn = jax.jit(eng._build_global_round(quad_loss, cfg, faults=plan,
                                               defense=DefensePlan()))
    state = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(1))
    fm, _ = fault_masks(state.rng, plan, G, K)
    corrupt = np.asarray(fm.corrupt)
    assert corrupt.sum() > 0, "seed produced no corruptions; pick another"
    state, _ = round_fn(state, batches)
    z = np.asarray(as_tree(state.z)["w"])
    for g in range(G):
        for k in range(K):
            if corrupt[g, k]:
                np.testing.assert_array_equal(z[g, k], 0.0)
            else:
                assert np.abs(z[g, k]).sum() > 0


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_fully_screened_group_reverts_not_poisons(layout):
    """When every upload in a group is screened, its clients revert to the
    group-round start model -- a screened upload must never survive in a
    replica, or the global recovery mean would integrate it. With all
    clients corrupted everywhere, the whole run is a frozen no-op: params
    stay exactly x0, z and y stay exactly zero, losses stay finite."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=layout == "flat")
    _, _, batches = make_batches(G, K, E, H, seed=9)
    plan = FaultPlan(corrupt_rate=0.999, corrupt_kind="nan")
    state, scr, _ = run_engine(cfg, plan, DefensePlan(), batches, 2)
    # The realization must actually corrupt everyone for the claim below.
    assert scr == 2 * E * G * K, "seed missed a corrupt draw; pick another"
    np.testing.assert_array_equal(np.asarray(as_tree(state.params)["w"]),
                                  np.zeros((G, K, D)))
    np.testing.assert_array_equal(np.asarray(as_tree(state.z)["w"]),
                                  np.zeros((G, K, D)))
    np.testing.assert_array_equal(np.asarray(as_tree(state.y)["w"]),
                                  np.zeros((G, D)))


# ------------------------------------------------------ async timeouts


def test_async_timeout_carries_realized_downloads():
    """Under an async schedule, timeouts clear the report mask and the
    realized-download carry (state.dl) replaces the static fresh cadence."""
    from repro.core.staleness import make_plan

    G, K, E_g, H = 3, 2, (2, 1, 1), 2
    plan = make_plan(E_g, G, "discount", None)
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=max(E_g), lr=0.05, use_flat_state=False)
    _, _, batches = make_batches(G, K, max(E_g), H, seed=8)
    fplan = FaultPlan(timeout_rate=0.5)
    round_fn = jax.jit(eng._build_global_round(quad_loss, cfg, plan=plan,
                                               faults=fplan))
    state = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(2),
                     fault_download=True)
    assert np.array_equal(np.asarray(state.dl), np.ones(G))
    rng = state.rng
    for t in range(3):
        fm, rng = fault_masks(rng, fplan, G, K)
        rep_expect = (np.asarray(plan.report_mask(t))
                      * (1.0 - np.asarray(fm.timeout)))
        state, _ = round_fn(state, batches)
        want_dl = rep_expect if rep_expect.sum() > 0 else np.zeros(G)
        np.testing.assert_array_equal(np.asarray(state.dl), want_dl)
    assert np.isfinite(np.asarray(as_tree(state.params)["w"])).all()


def test_async_timeout_without_dl_carry_raises():
    from repro.core.staleness import make_plan

    G, K = 2, 2
    plan = make_plan((2, 1), G, "naive", None)
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=2,
                    group_rounds=2, lr=0.05, use_flat_state=False)
    _, _, batches = make_batches(G, K, 2, 2)
    round_fn = eng._build_global_round(quad_loss, cfg, plan=plan,
                                       faults=FaultPlan(timeout_rate=0.3))
    state = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fault_download"):
        round_fn(state, batches)


# ------------------------------------------------------- guarded driver


def _toy_data(G, K, E, H, seed=0):
    rng = np.random.default_rng(seed)
    S = 4
    a = rng.normal(size=(G, K, S, H, D)).astype(np.float32) + 2.0
    b = rng.normal(size=(G, K, S, H, D)).astype(np.float32)
    return drv.PackedBatches({"a": a, "b": b}, jax.random.PRNGKey(9), E, H)


def test_guard_zero_fault_is_bit_exact_with_empty_report():
    G, K, E, H = 2, 2, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=False)
    rf = eng._build_global_round(quad_loss, cfg)
    data = _toy_data(G, K, E, H)
    s0 = hfl_init({"w": jnp.zeros(D)}, cfg)
    s1, _, h1 = drv.run_rounds(rf, s0, data, 4, chunk=2, donate=False)
    s0 = hfl_init({"w": jnp.zeros(D)}, cfg)
    s2, _, h2 = drv.run_rounds(rf, s0, data, 4, chunk=2, donate=False,
                               guard=drv.GuardSpec())
    assert leaves_equal(s1, s2)
    assert h1.guard is None
    assert h2.guard == drv.GuardReport(rollbacks=0, retries=0)


def test_guard_rolls_back_and_exhausts():
    """An always-NaN round diverges every attempt: the guard retries
    max_retries times, then raises."""
    G, K, E, H = 2, 2, 1, 1
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=False)
    plan = FaultPlan(corrupt_rate=0.999, corrupt_kind="nan")
    rf = eng._build_global_round(quad_loss, cfg, faults=plan)
    data = _toy_data(G, K, E, H)
    s0 = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError, match="exhausted"):
        drv.run_rounds(rf, s0, data, 2, chunk=2, donate=False,
                       guard=drv.GuardSpec(max_retries=2))


def test_guard_recovers_via_resplit_rng():
    """At a moderate fault rate the re-split rng eventually draws a clean
    chunk: the run completes finite with rollbacks recorded."""
    G, K, E, H = 2, 3, 2, 2
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, use_flat_state=False)
    plan = FaultPlan(corrupt_rate=0.05, corrupt_kind="nan")
    rf = eng._build_global_round(quad_loss, cfg, faults=plan)
    data = _toy_data(G, K, E, H, seed=1)
    s0 = hfl_init({"w": jnp.zeros(D)}, cfg, jax.random.PRNGKey(1))
    state, _, hz = drv.run_rounds(rf, s0, data, 10, chunk=2, donate=True,
                                  guard=drv.GuardSpec(max_retries=6))
    assert np.isfinite(np.asarray(hz.metrics.loss)).all()
    assert np.isfinite(np.asarray(as_tree(state.params)["w"])).all()
    assert hz.guard.rollbacks > 0


# ------------------------------------------------------------- api layer


def _api_fixture(faults=None, defense=None, backend="simulator",
                 layout="tree"):
    G, K = 2, 3
    spec = api.ExperimentSpec(
        levels=(G, K), lr=0.02, backend=backend, state_layout=layout,
        schedule=api.RoundSchedule(group_rounds=2, local_steps=2,
                                   microbatches=1 if backend == "sharded"
                                   else None),
        faults=faults, defense=defense)
    engine = api.build(spec, quad_loss)
    rng = np.random.default_rng(0)
    X = {"a": rng.normal(size=(G * K * 64, D)).astype(np.float32) + 2.0,
         "b": rng.normal(size=(G * K * 64, D)).astype(np.float32)}
    idx = [[np.arange((g * K + k) * 64, (g * K + k + 1) * 64)
            for k in range(K)] for g in range(G)]
    data = engine.pack_arrays(X, idx, batch_size=8,
                              rng=np.random.default_rng(1),
                              key=jax.random.PRNGKey(2))
    return engine, data


def test_api_validation_rejects_contradictions():
    bad = [
        dict(backend="multilevel", levels=(2, 2, 2),
             schedule=api.RoundSchedule(periods=(4, 2, 1), local_steps=1),
             faults=FaultPlan(crash_rate=0.1)),
        dict(population=8, levels=(2, 4), faults=FaultPlan(crash_rate=0.1)),
        dict(correction_init="gradient", faults=FaultPlan(crash_rate=0.1)),
        dict(server_lr=0.5, faults=FaultPlan(crash_rate=0.1)),
        dict(faults=FaultPlan(crash_rate=2.0)),
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            api.ExperimentSpec(**kw).validate()
    # A disabled plan is not fault mode: the combos above become legal.
    api.ExperimentSpec(server_lr=0.5, faults=FaultPlan()).validate()


@pytest.mark.parametrize("backend,layout", [("simulator", "flat"),
                                            ("sharded", "tree")])
def test_api_defended_fit_survives_faults(backend, layout):
    engine, data = _api_fixture(
        faults=FaultPlan(corrupt_rate=0.3, corrupt_kind="explode"),
        defense=DefensePlan(screen_norm=5.0), backend=backend, layout=layout)
    state, hz = api.fit(engine, data, 6, params={"w": jnp.zeros(D)},
                        chunk=2, guard=True, donate=False)
    loss = np.asarray(hz.metrics.loss)
    scr = float(np.sum(np.asarray(hz.metrics.screened)))
    assert np.isfinite(loss).all()
    assert scr > 0
    assert np.mean(loss[-1]) < np.mean(loss[0])
    model = engine.global_model(state)
    assert np.isfinite(np.asarray(model["w"])).all()


def test_api_retry_round_fn_tightens_screen():
    engine, _ = _api_fixture(
        faults=FaultPlan(corrupt_rate=0.2, corrupt_kind="explode"),
        defense=DefensePlan(screen_norm=8.0))
    rf0 = engine.retry_round_fn(0)
    rf1 = engine.retry_round_fn(1)
    rf1b = engine.retry_round_fn(1)
    assert rf0 is engine.round_fn
    assert rf1 is not rf0
    assert rf1 is rf1b          # cached: the driver's runner cache holds
    # Without a norm screen there is nothing to tighten.
    engine2, _ = _api_fixture(faults=FaultPlan(corrupt_rate=0.2),
                              defense=DefensePlan())
    assert engine2.retry_round_fn(1) is engine2.round_fn


def test_sharded_zero_fault_bit_exact_via_api():
    """The sharded engine behind build() with a disabled plan matches the
    plain build bitwise over a short horizon."""
    engine_a, data_a = _api_fixture(backend="sharded")
    engine_b, data_b = _api_fixture(faults=FaultPlan(), backend="sharded")
    sa, _ = api.fit(engine_a, data_a, 3, params={"w": jnp.zeros(D)},
                    donate=False)
    sb, _ = api.fit(engine_b, data_b, 3, params={"w": jnp.zeros(D)},
                    donate=False)
    assert leaves_equal(sa, sb)


@pytest.mark.slow
def test_bench_faults_claims():
    """Full claim gate (undefended corruption breaks training, screened +
    guarded recovers on the same fault realization, guard overhead < 10%)
    at benchmark scale; runs in the non-blocking CI job."""
    from benchmarks.bench_faults import bench

    out = bench(G=2, K=8, n=20_000, T=8, chunk=2, reps=5, corrupt_rate=0.2)
    assert out["all_claims_ok"], out["claims"]
