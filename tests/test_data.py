"""Data pipeline: Dirichlet partitioning (paper Sec. 5.1 protocol),
synthetic dataset learnability, LM batching."""
import numpy as np
import pytest

from repro.data.lm import lm_batches, make_lm_tokens
from repro.data.partition import partition, sample_round_batches
from repro.data.synthetic import make_classification, make_language, train_test_split


@pytest.mark.parametrize("mode", ["group_iid", "client_iid", "both_noniid",
                                  "label_shift"])
def test_partition_modes(mode):
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=4000, num_classes=10, dim=16)
    idx = partition(ds.y, num_groups=4, clients_per_group=5, mode=mode,
                    alpha=0.1, seed=0)
    assert len(idx) == 4 and all(len(g) == 5 for g in idx)
    flat = np.concatenate([c for g in idx for c in g])
    if mode != "label_shift":  # label shift intentionally subsamples
        # disjoint and (mostly) covering
        assert len(flat) == len(np.unique(flat))
        assert len(flat) >= 0.97 * len(ds.y)
    for g in idx:
        for c in g:
            assert len(c) >= 8


def test_dirichlet_alpha_controls_heterogeneity():
    """smaller alpha -> more label skew per client (the paper's knob)."""
    rng = np.random.default_rng(1)
    ds = make_classification(rng, num_samples=8000, num_classes=10, dim=16)

    def skew(alpha):
        idx = partition(ds.y, 2, 5, mode="both_noniid", alpha=alpha, seed=3)
        tvs = []
        for g in idx:
            for c in g:
                p = np.bincount(ds.y[c], minlength=10) / len(c)
                tvs.append(0.5 * np.abs(p - 0.1).sum())
        return np.mean(tvs)

    assert skew(0.1) > skew(100.0) + 0.2


def test_round_batch_shapes():
    rng = np.random.default_rng(2)
    ds = make_classification(rng, num_samples=2000, num_classes=10, dim=16)
    idx = partition(ds.y, 2, 3, mode="group_iid", alpha=0.5, seed=1)
    b = sample_round_batches(ds.x, ds.y, idx, rng, group_rounds=2,
                             local_steps=3, batch_size=8)
    assert b["x"].shape == (2, 3, 2, 3, 8, 16)
    assert b["y"].shape == (2, 3, 2, 3, 8)


def test_classification_is_learnable():
    """MLP + SGD separates the Gaussian mixture (stands in for EMNIST)."""
    import jax
    import jax.numpy as jnp
    from repro.models.small import accuracy, make_loss, mlp

    rng = np.random.default_rng(3)
    ds = make_classification(rng, num_samples=3000, num_classes=5, dim=16,
                             noise=0.5)
    tr, te = train_test_split(ds, rng)
    init, apply = mlp(5, 16, hidden=32)
    params = init(jax.random.PRNGKey(0))
    loss = make_loss(apply)
    step = jax.jit(lambda p, b: jax.tree.map(
        lambda pi, gi: pi - 0.3 * gi, p, jax.grad(loss)(p, b)))
    for i in range(60):
        sel = rng.integers(0, len(tr.x), 64)
        params = step(params, {"x": jnp.asarray(tr.x[sel]),
                               "y": jnp.asarray(tr.y[sel])})
    acc = accuracy(apply, params, jnp.asarray(te.x), np.asarray(te.y))
    assert acc > 0.8, acc


def test_language_styles_are_distinct():
    rng = np.random.default_rng(4)
    ds, styles = make_language(rng, num_styles=3, vocab=16,
                               samples_per_style=20, seq_len=40)
    assert ds.x.shape == (60, 40) and set(np.unique(styles)) == {0, 1, 2}
    # next-token targets are the shifted stream
    np.testing.assert_array_equal(ds.y[:, :-1], ds.x[:, 1:])


def test_lm_batches():
    rng = np.random.default_rng(5)
    toks, doms = make_lm_tokens(rng, vocab=64, num_tokens=10_000)
    assert toks.min() >= 0 and toks.max() < 64
    b = lm_batches(toks, rng, (2, 3), seq_len=32)
    assert b["tokens"].shape == (2, 3, 32)
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["targets"][..., :-1])
