"""Appendix E (Algorithm 2): M-level MTGC.

* M=2 must reproduce the two-level engine (Algorithm 1) exactly.
* M=3 runs, keeps subtree correction sums at zero, and converges to the
  global optimum under 3-level heterogeneity (paper Fig. 11 setting).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HFLConfig,
    global_model,
    hfl_init,
    make_global_round,
    make_multilevel_round,
    multilevel_global_model,
    multilevel_init,
)

from test_mtgc_engine import D, make_batches, quad_loss


def test_two_level_equivalence():
    G, K, E, H, lr = 2, 3, 2, 2, 0.05
    a, b, batches = make_batches(G, K, E, H, seed=11)

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=lr, algorithm="mtgc")
    st2 = hfl_init({"w": jnp.zeros(D)}, cfg)
    rf2 = jax.jit(make_global_round(quad_loss, cfg))

    stM = multilevel_init({"w": jnp.zeros(D)}, (G, K))
    rfM = jax.jit(make_multilevel_round(quad_loss, (G, K), (E * H, H), lr))
    # multilevel consumes [P_1, G, K, ...]; engine consumes [E, H, G, K, ...]
    mbatches = {k: jnp.asarray(v.reshape((E * H,) + v.shape[2:]))
                for k, v in batches.items()}

    for _ in range(2):
        st2, _ = rf2(st2, jax.tree.map(jnp.asarray, batches))
        stM, _ = rfM(stM, mbatches)
        got = np.asarray(multilevel_global_model(stM)["w"])
        want = np.asarray(global_model(st2)["w"])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_three_level_invariants_and_convergence():
    dims, periods, lr = (2, 2, 2), (8, 4, 2), 0.05
    N = int(np.prod(dims))
    rng = np.random.default_rng(12)
    a = rng.normal(size=dims + (D,)).astype(np.float32) + 2.0
    b = rng.normal(size=dims + (D,)).astype(np.float32)
    xstar = (a * b).sum((0, 1, 2)) / (a * a).sum((0, 1, 2))
    P1 = periods[0]
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (P1,) + a.shape).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (P1,) + b.shape).copy()),
    }
    st = multilevel_init({"w": jnp.zeros(D)}, dims)
    rf = jax.jit(make_multilevel_round(quad_loss, dims, periods, lr))
    for _ in range(50):
        st, losses = rf(st, batches)
    # invariants: each level's corrections sum to zero over its siblings
    for m, nu in enumerate(st.nus):
        w = np.asarray(nu["w"])
        np.testing.assert_allclose(w.sum(axis=m), 0.0, atol=1e-3)
    x = np.asarray(multilevel_global_model(st)["w"])
    # per-round correction re-initialization (Alg. 2 line 11) makes late
    # convergence gradual; the drift bias itself is gone (vs ~0.3 for FedAvg)
    assert np.linalg.norm(x - xstar) < 3e-2, np.linalg.norm(x - xstar)
