"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
variant of each family runs one forward/train step on CPU with correct
shapes and no NaNs; serve paths (prefill + decode) are consistent with the
full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models.transformer import build_model


def _batch(c, B, T, rng, with_targets=True):
    t_text = T - (c.vision_tokens if c.arch_type == "vlm" else 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, c.vocab_size, (B, t_text)), jnp.int32)}
    if with_targets:
        batch["targets"] = jnp.asarray(
            rng.integers(0, c.vocab_size, (B, t_text)), jnp.int32)
    if c.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, c.vision_tokens, c.vision_dim)), jnp.float32)
    if c.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, c.encoder_frames, c.d_model)), jnp.float32)
    return batch, t_text


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    c = get_arch(arch).reduced()
    assert c.num_layers == 2 and c.d_model <= 512
    bundle = build_model(c)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, T = 2, 32
    batch, t_text = _batch(c, B, T, rng)

    logits = bundle.forward(params, batch)
    assert logits.shape[:2] == (B, T if c.arch_type == "vlm" else t_text)
    assert logits.shape[-1] == c.vocab_padded
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
    assert np.isfinite(float(loss))
    # one SGD step decreases nothing catastrophic / produces finite params
    new = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = bundle.loss(new, batch)
    assert np.isfinite(float(loss2))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    c = get_arch(arch).reduced()
    bundle = build_model(c)
    params = bundle.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, T = 2, 16
    batch, t_text = _batch(c, B, T, rng, with_targets=False)
    toks = batch["tokens"]

    full = bundle.forward(params, batch)[:, -1]

    cache = bundle.init_cache(B, T)
    pre = dict(batch, tokens=toks[:, :-1])
    _, cache = bundle.prefill(params, pre, cache)
    extra = {k: batch[k] for k in ("frames",) if k in batch}
    idx = t_text - 1 + (c.vision_tokens if c.arch_type == "vlm" else 0)
    lg, cache = bundle.decode_step(
        params, {"token": toks[:, -1:], "index": jnp.asarray(idx, jnp.int32),
                 **extra}, cache)
    err = float(jnp.max(jnp.abs(full.astype(jnp.float32) - lg.astype(jnp.float32))))
    assert err < 5e-4, err


def test_sliding_window_limits_attention():
    """gemma3-style local layers: tokens beyond the window cannot influence
    the output (causal sliding-window masking is actually applied)."""
    c = get_arch("mixtral-8x22b").reduced(sliding_window=4, num_layers=1)
    bundle = build_model(c)
    params = bundle.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, c.vocab_size, (1, 24))
    b1 = {"tokens": jnp.asarray(toks, jnp.int32)}
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % c.vocab_size  # mutate far-past token
    b2 = {"tokens": jnp.asarray(toks2, jnp.int32)}
    l1 = bundle.forward(params, b1)[:, -1]
    l2 = bundle.forward(params, b2)[:, -1]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_gemma3_layer_pattern():
    from repro.models.transformer import _layer_windows
    c = get_arch("gemma3-27b")
    w = _layer_windows(c)
    assert len(w) == 62
    assert (w == 0).sum() == 10          # every 6th layer is global
    assert (w[:5] == 1024).all() and w[5] == 0


def test_moe_router_load_balance_aux():
    c = get_arch("granite-moe-1b-a400m").reduced()
    bundle = build_model(c)
    params = bundle.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    batch, _ = _batch(c, 2, 32, rng)
    loss = bundle.loss(params, batch)
    assert np.isfinite(float(loss))
