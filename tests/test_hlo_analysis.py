"""The trip-count-aware HLO walker that powers the roofline analysis."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def test_loop_free_matches_xla_cost_analysis():
    def f(a, b):
        return (a @ b).sum() + jnp.exp(a).sum()

    a = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    res = H.analyze(c.as_text())
    xla = H.xla_cost_dict(c)
    # dominated by the dot: 2*128*64*256
    assert abs(res.flops - xla["flops"]) / xla["flops"] < 0.05
    assert res.flops >= 2 * 128 * 64 * 256


@pytest.mark.parametrize("n", [1, 3, 8])
def test_scan_trip_count_multiplies(n):
    def g(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        c, _ = jax.lax.scan(body, x, None, length=n)
        return c

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(g).lower(x).compile()
    res = H.analyze(c.as_text())
    exact = n * 2 * 128 ** 3
    assert 0.95 < res.flops / exact < 1.10, (n, res.flops, exact)


def test_nested_scans_multiply():
    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(nested).lower(x).compile()
    res = H.analyze(c.as_text())
    exact = 12 * 2 * 128 ** 3
    assert 0.95 < res.flops / exact < 1.10


def test_collectives_counted_with_trip_counts():
    """psum inside a scan on a 1-device 'mesh' lowers to all-reduce ops
    that the walker must multiply by the trip count."""
    hlo = """
HloModule test

%body (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ar = f32[64,128] all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[64,128]) tuple(%ni, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,128]) tuple(%zero, %x)
  %w = (s32[], f32[64,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,128] get-tuple-element(%w), index=1
}
"""
    res = H.analyze(hlo)
    assert res.per_collective["all-reduce"] == 5 * 64 * 128 * 4
    assert res.collective_bytes == 5 * 64 * 128 * 4


def test_dus_counts_slice_not_buffer():
    hlo = """
HloModule t

ENTRY %main (buf: f32[1024,128], upd: f32[1,128]) -> f32[1024,128] {
  %buf = f32[1024,128] parameter(0)
  %upd = f32[1,128] parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[1024,128] dynamic-update-slice(%buf, %upd, %z, %z)
}
"""
    res = H.analyze(hlo)
    # in-place: ~2x the update slice, NOT 2x the megabyte buffer
    assert res.bytes <= 4 * 1 * 128 * 4 + 16


def test_async_collective_start_done_counted_once():
    """Async all-reduce-start/-done pairs are one transfer, not two."""
    hlo = """
HloModule t

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128] parameter(0)
  %ars = f32[64,128] all-reduce-start(%x), replica_groups={}, to_apply=%sum
  ROOT %ard = f32[64,128] all-reduce-done(%ars)
}
"""
    res = H.analyze(hlo)
    assert res.per_collective["all-reduce"] == 64 * 128 * 4
    assert res.collective_bytes == 64 * 128 * 4


def test_collective_elided_operand_falls_back_to_result_shape():
    """Operands printed as bare %names that resolve nowhere (e.g. a
    module sliced out of context) must fall back to the result shape
    instead of counting zero bytes."""
    hlo = """
HloModule t

ENTRY %main (x: f32[8]) -> f32[32,32] {
  %x = f32[8] parameter(0)
  ROOT %ar = f32[32,32] all-reduce(%ghost), replica_groups={}
}
"""
    res = H.analyze(hlo)
    assert res.per_collective["all-reduce"] == 32 * 32 * 4


def test_unknown_dtype_bytes_fall_back_conservatively():
    """A dtype token missing from the byte table (new narrow-float
    formats) costs the 4-byte fallback, not zero."""
    hlo = """
HloModule t

ENTRY %main (x: f8e8m0fnu[64]) -> f8e8m0fnu[64] {
  %x = f8e8m0fnu[64] parameter(0)
  ROOT %ar = f8e8m0fnu[64] all-reduce(f8e8m0fnu[64] %x), replica_groups={}
}
"""
    res = H.analyze(hlo)
    assert res.per_collective["all-reduce"] == 64 * H._DT_FALLBACK_BYTES


def test_nested_while_trip_counts_multiply():
    """Hand-written nested whiles: inner body runs outer*inner times."""
    hlo = """
HloModule t

%inner_body (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %nx = f32[16] add(%x, %x)
  ROOT %t = (s32[], f32[16]) tuple(%ni, %nx)
}

%inner_cond (p: (s32[], f32[16])) -> pred[] {
  %p = (s32[], f32[16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%outer_body (q: (s32[], f32[16])) -> (s32[], f32[16]) {
  %q = (s32[], f32[16]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  %y = f32[16] get-tuple-element(%q), index=1
  %one = s32[] constant(1)
  %nj = s32[] add(%j, %one)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16]) tuple(%zero, %y)
  %w = (s32[], f32[16]) while(%init), condition=%inner_cond, body=%inner_body
  %ny = f32[16] get-tuple-element(%w), index=1
  ROOT %t = (s32[], f32[16]) tuple(%nj, %ny)
}

%outer_cond (q: (s32[], f32[16])) -> pred[] {
  %q = (s32[], f32[16]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  %m = s32[] constant(3)
  ROOT %lt = pred[] compare(%j, %m), direction=LT
}

ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16]) tuple(%zero, %x)
  %w = (s32[], f32[16]) while(%init), condition=%outer_cond, body=%outer_body
  ROOT %out = f32[16] get-tuple-element(%w), index=1
}
"""
    res = H.analyze(hlo)
    # inner f32[16] add executes 3 * 4 = 12 times; the counter adds and
    # loop compares contribute 1 flop per execution on top.
    inner_adds = 3 * 4 * 16
    scalar_ops = 12 + 12 + 3 + 3  # inner iv add + inner cmp + outer iv + cmp
    assert res.flops == inner_adds + scalar_ops
