"""Compressed hierarchical uploads (core/compression.py, kernels/quantize.py).

Four layers of gates:

* Kernel contracts: the interpreted Pallas quantize/top-k kernels are
  bit-exact vs the jnp oracles over shape sweeps (odd lengths, lane
  padding), and stochastic int8 rounding is unbiased in expectation.
* Link semantics vs a pure-python error-feedback oracle: the simulator
  engine's client-link top-k + EF path replayed step-for-step in numpy.
* The hard bit-exactness contract: a disabled plan (and the ``none``
  modes) traces the legacy program untouched, across backends, layouts
  and participation -- and the sim/sharded engines stay in lockstep
  under active plans.
* Composition: compression x faults (the defense screens the
  *dequantized* upload; a screened client's residual stays untouched),
  ``comm_bytes`` accounting vs the analytic wire model, checkpoint
  round-trips of the residual state, and spec-level rejections.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import compression as cmp
from repro.core.faults import DefensePlan, FaultPlan, fault_masks
from repro.kernels import ops as kops
from repro.kernels import quantize as qz
from repro.kernels import ref as kref

D = 5


def quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def make_problem(G, K, E, H, d=D, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(G, K, d)).astype(np.float32) + 2.0
    b = rng.normal(size=(G, K, d)).astype(np.float32)
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (E, H, G, K, d)).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (E, H, G, K, d)).copy()),
    }
    params = {"w": jnp.zeros((d,), jnp.float32)}
    return a, b, batches, params


def sharded_batches(batches):
    """Simulator layout [E,H,G,K,...] -> sharded layout [E,H,A=1,G,K,...]."""
    return jax.tree.map(lambda x: x[:, :, None], batches)


def leaves_equal(s1, s2):
    return all(np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
               for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)))


def spec_for(backend, layout, plan, G=2, K=3, E=2, H=2, lr=0.05, **kw):
    return api.ExperimentSpec(
        levels=(G, K),
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        lr=lr, backend=backend, state_layout=layout, compression=plan,
        **kw)


# ------------------------------------------------------------- kernels


@pytest.mark.parametrize("R,n", [(1, 1), (3, 7), (2, 128), (4, 1000),
                                 (1, 8192 + 3)])
def test_int8_kernel_matches_ref_bitexact(R, n):
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (R, n), jnp.float32) * 3.0
    amax = jnp.max(jnp.abs(u), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    noise = jax.random.uniform(jax.random.PRNGKey(1), (R, n), jnp.float32)
    want = kref.int8_roundtrip_ref(u, scale, noise)
    got = qz.int8_roundtrip(u, scale, noise, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == u.dtype


@pytest.mark.parametrize("R,n", [(1, 1), (3, 7), (2, 128), (4, 1000)])
def test_topk_kernel_matches_ref_bitexact(R, n):
    u = jax.random.normal(jax.random.PRNGKey(2), (R, n), jnp.float32)
    k = max(1, n // 10)
    thresh = jax.lax.top_k(jnp.abs(u), k)[0][:, -1]
    want = kref.topk_mask_ref(u, thresh)
    got = qz.topk_mask(u, thresh, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # kept entries are the k largest magnitudes (modulo ties: >= k kept)
    assert int(jnp.sum(got != 0)) >= k * R or int(jnp.sum(u != 0)) < k * R


def test_int8_zero_rows_and_padding_are_safe():
    """A zero row survives (scale-1 fallback), and lane padding never
    leaks into real entries."""
    u = jnp.zeros((2, 130), jnp.float32).at[1, 3].set(5.0)
    amax = jnp.max(jnp.abs(u), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    noise = jnp.full(u.shape, 0.999, jnp.float32)
    got = qz.int8_roundtrip(u, scale, noise, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.zeros(130))
    assert float(got[1, 3]) == pytest.approx(5.0, rel=1e-6)


def test_int8_stochastic_rounding_is_unbiased():
    u = jax.random.normal(jax.random.PRNGKey(3), (1, 64), jnp.float32)
    amax = jnp.max(jnp.abs(u), axis=1)
    scale = amax / 127.0
    keys = jax.random.split(jax.random.PRNGKey(4), 2048)
    noise = jax.vmap(lambda k: jax.random.uniform(k, u.shape))(keys)
    deqs = jax.vmap(lambda nz: kref.int8_roundtrip_ref(u, scale, nz))(noise)
    err = jnp.mean(deqs, axis=0) - u
    assert float(jnp.max(jnp.abs(err))) < 2e-2 * float(amax[0])


def test_ops_dispatch_ref_equals_interpret():
    u = jax.random.normal(jax.random.PRNGKey(5), (4, 300), jnp.float32)
    amax = jnp.max(jnp.abs(u), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    noise = jax.random.uniform(jax.random.PRNGKey(6), u.shape)
    a = kops.int8_roundtrip(u, scale, noise, mode="ref")
    b = kops.int8_roundtrip(u, scale, noise, mode="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    thresh = jax.lax.top_k(jnp.abs(u), 30)[0][:, -1]
    a = kops.topk_mask(u, thresh, mode="ref")
    b = kops.topk_mask(u, thresh, mode="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------- pure-python EF oracle


def np_topk_roundtrip(u, frac):
    """Numpy mirror of roundtrip(mode='topk') for a [rows, n] matrix."""
    n = u.shape[1]
    k = max(1, min(n, int(np.ceil(frac * n))))
    thresh = np.sort(np.abs(u), axis=1)[:, n - k]
    return np.where(np.abs(u) >= thresh[:, None], u, 0.0)


def mtgc_topk_ef_oracle(x0, a, b, G, K, E, H, lr, rounds, frac):
    """The simulator engine's client-link topk+EF semantics in numpy
    (full participation, sync, mtgc with zero-init z)."""
    x = np.broadcast_to(x0, (G, K) + x0.shape).astype(np.float64).copy()
    z = np.zeros_like(x)
    y = np.zeros((G,) + x0.shape)
    ef = np.zeros_like(x)
    a = a.astype(np.float64)
    b = b.astype(np.float64)
    for _ in range(rounds):
        z[:] = 0.0
        for _e in range(E):
            x_start = x.copy()
            for _h in range(H):
                g = a * (a * x - b)
                x = x - lr * (g + z + y[:, None])
            u = (x - x_start) + ef
            deq = np_topk_roundtrip(u.reshape(G * K, -1),
                                    frac).reshape(u.shape)
            x_up = x_start + deq
            ef = u - deq
            xbar = x_up.mean(axis=1)
            # z is client-side state: it integrates the client's own
            # local model (x), never the wire view carrying the residual.
            z = z + (x - xbar[:, None]) / (H * lr)
            x = np.broadcast_to(xbar[:, None], x.shape).copy()
        xbar_j = x[:, 0]
        xg = xbar_j.mean(axis=0)
        y = y + (xbar_j - xg[None]) / (H * E * lr)
        x = np.broadcast_to(xg, x.shape).copy()
    return x, z, y, ef


@pytest.mark.parametrize("backend", ["simulator", "sharded"])
def test_engine_matches_topk_ef_oracle(backend):
    G, K, E, H, rounds, frac = 2, 3, 2, 2, 3, 0.4
    a, b, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode="topk", topk_frac=frac)
    eng = api.build(spec_for(backend, "tree", plan, G=G, K=K, E=E, H=H),
                    quad_loss)
    state = eng.init(params)
    data = batches if backend == "simulator" else sharded_batches(batches)
    rf = jax.jit(eng.round_fn)
    for _ in range(rounds):
        state, m = rf(state, data)
    ox, oz, oy, oef = mtgc_topk_ef_oracle(
        np.zeros((D,)), a, b, G, K, E, H, 0.05, rounds, frac)
    np.testing.assert_allclose(np.asarray(state.params["w"]), ox,
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.efc["w"]), oef,
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.z["w"]), oz,
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.y["w"]), oy,
                               rtol=2e-4, atol=1e-5)
    # EF actually carries error: with 40% density the residual is live.
    assert float(np.abs(oef).max()) > 0


# ------------------------------------------------- bit-exact contracts


@pytest.mark.parametrize("backend", ["simulator", "sharded"])
@pytest.mark.parametrize("layout", ["flat", "tree"])
@pytest.mark.parametrize("participation", [1.0, 0.6])
def test_disabled_plan_is_bitexact(backend, layout, participation):
    """CompressionPlan() (both links 'none') adds no state leaves and
    traces the legacy program bit for bit."""
    G, K, E, H = 2, 3, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    data = batches if backend == "simulator" else sharded_batches(batches)
    states = []
    for plan in (None, api.CompressionPlan()):
        eng = api.build(spec_for(backend, layout, plan, G=G, K=K, E=E, H=H,
                                 client_participation=participation),
                        quad_loss)
        state = eng.init(params, rng=jax.random.PRNGKey(3))
        rf = jax.jit(eng.round_fn)
        for _ in range(2):
            state, m = rf(state, data)
        states.append(state)
        assert state.efc is None and state.efg is None
    assert leaves_equal(states[0], states[1])
    assert len(jax.tree.leaves(states[0])) == len(jax.tree.leaves(states[1]))


@pytest.mark.parametrize("layout", ["flat", "tree"])
@pytest.mark.parametrize("cm,gm", [("int8_stochastic", "none"),
                                   ("topk", "bf16"),
                                   ("int8_stochastic", "int8_stochastic")])
def test_sim_and_sharded_engines_in_lockstep(layout, cm, gm):
    """Both two-level engines realize identical compressed rounds (same
    rng schedule, same seam ordering)."""
    G, K, E, H = 2, 3, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode=cm, group_mode=gm)
    finals = []
    for backend, data in (("simulator", batches),
                          ("sharded", sharded_batches(batches))):
        eng = api.build(spec_for(backend, layout, plan, G=G, K=K, E=E, H=H),
                        quad_loss)
        state = eng.init(params, rng=jax.random.PRNGKey(3))
        for _ in range(2):
            state, m = jax.jit(eng.round_fn)(state, data)
        finals.append((state, m))
    s0, m0 = finals[0]
    s1, m1 = finals[1]
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(s0.params)[0]),
                               np.asarray(jax.tree.leaves(s1.params)[0]),
                               rtol=1e-6, atol=1e-7)
    assert float(m0.comm_bytes) == float(m1.comm_bytes)


# ------------------------------------------------ compression x faults


def test_screened_clients_leave_ef_residual_untouched():
    """nan-corrupted uploads are screened *after* dequantization, and the
    screened client's error-feedback row stays exactly zero while served
    clients' residuals move."""
    G, K, E, H = 2, 4, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode="int8_stochastic")
    faults = FaultPlan(corrupt_rate=0.5, corrupt_kind="nan")
    defense = DefensePlan(screen_nonfinite=True)
    eng = api.build(spec_for("simulator", "tree", plan, G=G, K=K, E=E, H=H,
                             faults=faults, defense=defense),
                    quad_loss)
    rng = jax.random.PRNGKey(11)
    state = eng.init(params, rng=rng)
    state, m = jax.jit(eng.round_fn)(state, batches)

    # Replay the engine's own fault realization for round 1.
    fm, _ = fault_masks(rng, faults, G, K)
    corrupt = np.asarray(fm.corrupt)  # [G, K], 1 = corrupted every e
    assert corrupt.sum() > 0 and corrupt.sum() < G * K
    assert float(m.screened) >= E * corrupt.sum()
    efc = np.asarray(state.efc["w"])  # [G, K, D]
    assert np.isfinite(np.asarray(jax.tree.leaves(state.params)[0])).all()
    for g in range(G):
        for k in range(K):
            row = efc[g, k]
            if corrupt[g, k]:
                np.testing.assert_array_equal(row, np.zeros(D))
            else:
                assert np.abs(row).sum() > 0


def test_defense_screens_dequantized_upload_norm():
    """The norm screen sees post-dequantization bytes: a topk-compressed
    honest upload whose *compressed* delta passes the screen survives
    even when EF inflation would not change that; the run stays finite
    and every survivor's bits entered the aggregate."""
    G, K, E, H = 2, 3, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode="topk", topk_frac=0.5)
    defense = DefensePlan(screen_norm=1e6, screen_nonfinite=True)
    eng = api.build(spec_for("simulator", "tree", plan, G=G, K=K, E=E, H=H,
                             defense=defense), quad_loss)
    state = eng.init(params, rng=jax.random.PRNGKey(5))
    state, m = jax.jit(eng.round_fn)(state, batches)
    assert float(m.screened) == 0.0
    assert np.isfinite(np.asarray(jax.tree.leaves(state.params)[0])).all()


# --------------------------------------------------- bytes accounting


def test_comm_bytes_matches_wire_model():
    G, K, E, H, d = 2, 3, 2, 2, 256
    _, _, batches, params = make_problem(G, K, E, H, d=d)
    sizes = cmp.model_leaf_sizes(
        jax.tree.map(lambda x: jnp.broadcast_to(x, (G, K) + x.shape), params))
    assert sizes == ((d, "float32"),)

    def measured(plan):
        eng = api.build(spec_for("simulator", "tree", plan,
                                 G=G, K=K, E=E, H=H), quad_loss)
        state = eng.init(params, rng=jax.random.PRNGKey(0))
        _, m = jax.jit(eng.round_fn)(state, batches)
        return float(m.comm_bytes)

    base = measured(None)
    assert base == 4 * d * (E * G * K + G)

    plan = api.CompressionPlan(client_mode="int8_stochastic",
                               group_mode="int8_stochastic")
    got = measured(plan)
    want = (cmp.upload_bytes(sizes, "int8_stochastic") * (E * G * K + G))
    assert got == want
    assert base / got >= 3.5   # the acceptance-criteria compression ratio

    sparse = measured(api.CompressionPlan(client_mode="topk",
                                          group_mode="topk",
                                          topk_frac=0.01))
    k = max(1, int(np.ceil(0.01 * d)))
    assert sparse == 8 * k * (E * G * K + G)


def test_comm_bytes_counts_only_sent_uploads():
    """Crashed clients upload nothing; sampled-out clients upload
    nothing; screened uploads still count (they were transmitted)."""
    G, K, E, H = 2, 4, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    faults = FaultPlan(crash_rate=0.5)
    eng = api.build(spec_for("simulator", "tree", None, G=G, K=K, E=E, H=H,
                             faults=faults), quad_loss)
    rng = jax.random.PRNGKey(7)
    state = eng.init(params, rng=rng)
    _, m = jax.jit(eng.round_fn)(state, batches)
    fm, _ = fault_masks(rng, faults, G, K)
    crash = np.asarray(fm.crash)
    alive = G * K - int(crash.sum())
    gact = int(((1.0 - crash).sum(axis=1) > 0).sum())
    assert float(m.comm_bytes) == 4 * D * (E * alive + gact)


# ----------------------------------------------- state plumbing gates


def test_checkpoint_roundtrip_carries_ef_residuals(tmp_path):
    from repro.checkpoint import restore, save

    G, K, E, H = 2, 3, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode="int8_stochastic",
                               group_mode="topk")
    eng = api.build(spec_for("simulator", "tree", plan, G=G, K=K, E=E, H=H),
                    quad_loss)
    state = eng.init(params, rng=jax.random.PRNGKey(3))
    rf = jax.jit(eng.round_fn)
    state, _ = rf(state, batches)
    assert state.efc is not None and state.efg is not None
    save(str(tmp_path), 1, state)
    restored = restore(str(tmp_path), 1, jax.tree.map(jnp.zeros_like, state))
    assert leaves_equal(state, restored)
    # A restored state continues bit-identically (rng words included).
    s_a, _ = rf(state, batches)
    s_b, _ = rf(restored, batches)
    assert leaves_equal(s_a, s_b)


def test_ef_requires_state_built_with_residuals():
    from repro.core import engine as eng_mod

    G, K, E, H = 2, 3, 2, 2
    _, _, batches, params = make_problem(G, K, E, H)
    plan = api.CompressionPlan(client_mode="int8_stochastic")
    eng = api.build(spec_for("simulator", "tree", plan, G=G, K=K, E=E, H=H),
                    quad_loss)
    bad = eng.init(params, rng=jax.random.PRNGKey(0))._replace(efc=None)
    with pytest.raises(ValueError, match="ef_client=True"):
        eng.round_fn(bad, batches)


# ------------------------------------------------------ spec plumbing


def test_spec_rejections():
    plan = api.CompressionPlan(client_mode="int8_stochastic")
    with pytest.raises(ValueError, match="two-level"):
        api.ExperimentSpec(levels=(2, 2, 2), backend="multilevel",
                           compression=plan).validate()
    with pytest.raises(ValueError, match="async"):
        api.ExperimentSpec(
            schedule=api.RoundSchedule(group_rounds=(2, 1)),
            staleness="discount", compression=plan).validate()
    with pytest.raises(ValueError, match="stateless"):
        api.ExperimentSpec(levels=(2, 4), population=4,
                           client_state="stateless",
                           compression=plan).validate()
    with pytest.raises(ValueError, match="error feedback"):
        api.ExperimentSpec(levels=(2, 4), population=16,
                           compression=plan).validate()
    with pytest.raises(ValueError, match="server_lr"):
        api.ExperimentSpec(server_lr=0.5, compression=plan).validate()
    with pytest.raises(ValueError, match="correction_init"):
        api.ExperimentSpec(correction_init="gradient",
                           compression=plan).validate()
    with pytest.raises(ValueError, match="unknown client_mode"):
        api.CompressionPlan(client_mode="fp4").validate()
    with pytest.raises(ValueError, match="topk_frac"):
        api.CompressionPlan(topk_frac=0.0).validate()
    # A disabled plan composes with anything -- e.g. async schedules.
    api.ExperimentSpec(schedule=api.RoundSchedule(group_rounds=(2, 1)),
                       staleness="discount",
                       compression=api.CompressionPlan()).validate()


def test_int8_ef_smoke_fit():
    """int8+EF on both links trains the quadratic: loss falls, bytes
    shrink ~4x vs uncompressed -- the fast tier-1 smoke of the
    end-to-end compressed path."""
    G, K, E, H, d = 2, 4, 2, 4, 64
    rng = np.random.default_rng(1)
    a = rng.normal(size=(G, K, d)).astype(np.float32) + 2.0
    wstar = rng.normal(size=(d,)).astype(np.float32)
    b = a * wstar   # shared optimum: the consensus loss floor is zero
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (E, H, G, K, d)).copy()),
        "b": jnp.asarray(np.broadcast_to(b, (E, H, G, K, d)).copy()),
    }
    params = {"w": jnp.zeros((d,), jnp.float32)}
    plan = api.CompressionPlan(client_mode="int8_stochastic",
                               group_mode="int8_stochastic")
    losses = {}
    for name, p in (("plain", None), ("int8+ef", plan)):
        eng = api.build(spec_for("simulator", "flat", p, G=G, K=K, E=E, H=H,
                                 lr=0.02), quad_loss)
        state = eng.init(params, rng=jax.random.PRNGKey(0))
        rf = jax.jit(eng.round_fn)
        hist = []
        for _ in range(8):
            state, m = rf(state, batches)
            hist.append(float(m.loss[-1, -1] if m.loss.ndim else m.loss))
        losses[name] = hist
        bytes_ = float(m.comm_bytes)
        if p is None:
            base_bytes = bytes_
        else:
            assert base_bytes / bytes_ >= 3.5
    assert losses["int8+ef"][-1] < 0.1 * losses["int8+ef"][0]
    assert (losses["int8+ef"][-1]
            <= max(1.05 * losses["plain"][-1], losses["plain"][-1] + 1e-3))
