"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mtgc_update import mtgc_update, mtgc_update_flat
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(0)


# ------------------------------------------------------------ mtgc_update


@pytest.mark.parametrize("shape", [(5,), (128,), (1000,), (33, 129), (2, 3, 130)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6), (jnp.bfloat16, 1e-2)])
def test_mtgc_update_sweep(shape, dtype, tol):
    xs = [jnp.asarray(RNG.normal(size=shape), dtype) for _ in range(4)]
    got = mtgc_update(*xs, lr=0.1, interpret=True, block_rows=8)
    want = ref.mtgc_update_ref(*xs, 0.1)
    assert got.dtype == xs[0].dtype and got.shape == xs[0].shape
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    assert err < tol, (shape, dtype, err)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4000),
       lr=st.floats(1e-4, 1.0),
       blk=st.sampled_from([8, 16, 64]))
def test_mtgc_update_property(n, lr, blk):
    rng = np.random.default_rng(n)
    xs = [jnp.asarray(rng.normal(size=(n,)), jnp.float32) for _ in range(4)]
    got = mtgc_update(*xs, lr=lr, interpret=True, block_rows=blk)
    want = ref.mtgc_update_ref(*xs, lr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("G,K,N", [(2, 2, 300), (3, 1, 1), (1, 4, 128 * 9 + 5),
                                   (2, 3, 4096)])
@pytest.mark.parametrize("masked", [False, True])
def test_mtgc_update_flat_sweep(G, K, N, masked):
    """Whole-model batched kernel: y broadcast by the index map, optional
    participation mask folded in, g_scale folding the microbatch mean."""
    rng = np.random.default_rng(G * 100 + K * 10 + N + masked)
    x, g, z = (jnp.asarray(rng.normal(size=(G, K, N)), jnp.float32)
               for _ in range(3))
    y = jnp.asarray(rng.normal(size=(G, N)), jnp.float32)
    mask = (jnp.asarray(rng.integers(0, 2, size=(G, K)), jnp.float32)
            if masked else None)
    got = mtgc_update_flat(x, g, z, y, mask, lr=0.07, g_scale=0.5,
                           interpret=True, block_rows=16)
    want = ref.mtgc_update_flat_ref(x, g, z, y, mask, 0.07, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    if masked:
        # frozen replicas keep their exact bits
        np.testing.assert_array_equal(np.asarray(got)[np.asarray(mask) == 0],
                                      np.asarray(x)[np.asarray(mask) == 0])


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 3000), k=st.integers(1, 4),
       lr=st.floats(1e-4, 1.0), blk=st.sampled_from([8, 64, 1024]))
def test_mtgc_update_flat_property(n, k, lr, blk):
    rng = np.random.default_rng(n * 7 + k)
    G = 2
    x, g, z = (jnp.asarray(rng.normal(size=(G, k, n)), jnp.float32)
               for _ in range(3))
    y = jnp.asarray(rng.normal(size=(G, n)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=(G, k)), jnp.float32)
    got = mtgc_update_flat(x, g, z, y, mask, lr=lr, interpret=True,
                           block_rows=blk)
    want = ref.mtgc_update_flat_ref(x, g, z, y, mask, lr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("B,T,S,H,Kv,Dh,causal,win", [
    (1, 128, 128, 4, 4, 64, True, 0),
    (2, 128, 128, 4, 2, 64, True, 0),       # GQA
    (1, 256, 256, 2, 1, 32, True, 64),      # MQA + sliding window
    (1, 128, 256, 4, 4, 64, False, 0),      # cross/bidirectional
    (2, 256, 256, 8, 2, 128, True, 100),    # window not block-aligned
    (1, 64, 64, 25, 5, 32, True, 16),       # hymba's 25/5 heads
])
def test_flash_attention_sweep(B, T, S, H, Kv, Dh, causal, win):
    q = jnp.asarray(RNG.normal(size=(B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Kv, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Kv, Dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=win,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 5e-5, err


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    assert got.dtype == jnp.bfloat16 and err < 3e-2, err


@settings(max_examples=8, deadline=None)
@given(tb=st.sampled_from([(64, 64), (128, 64), (192, 64)]),
       hkv=st.sampled_from([(4, 4), (4, 2), (6, 3)]),
       causal=st.booleans(),
       win=st.sampled_from([0, 32, 77]))
def test_flash_attention_property(tb, hkv, causal, win):
    T, blk = tb
    H, Kv = hkv
    rng = np.random.default_rng(T * H + win)
    q = jnp.asarray(rng.normal(size=(1, T, H, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, T, Kv, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, T, Kv, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=win,
                          block_q=blk, block_k=blk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=5e-5)


# -------------------------------------------------------------- rwkv scan


@pytest.mark.parametrize("B,H,T,Dh,C", [
    (1, 2, 32, 16, 8), (2, 3, 64, 32, 16), (1, 1, 128, 64, 64),
    (2, 2, 64, 64, 32),
])
def test_rwkv6_scan_sweep(B, H, T, Dh, C):
    r, k, v = (jnp.asarray(RNG.normal(size=(B, H, T, Dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.abs(jnp.asarray(RNG.normal(size=(B, H, T, Dh)), jnp.float32))
    u = jnp.asarray(RNG.normal(size=(H, Dh)), jnp.float32)
    S0 = jnp.asarray(RNG.normal(size=(B, H, Dh, Dh)), jnp.float32)
    want_o, want_s = ref.rwkv6_scan_ref(r, k, v, logw, u, S0)

    flat = lambda a: a.reshape(B * H, T, Dh)
    u_b = jnp.broadcast_to(u[None], (B, H, Dh)).reshape(B * H, Dh)
    got_o, got_s = rwkv6_scan(flat(r), flat(k), flat(v), flat(logw), u_b,
                              S0.reshape(B * H, Dh, Dh), chunk=C, interpret=True)
    np.testing.assert_allclose(got_o.reshape(B, H, T, Dh), want_o,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_s.reshape(B, H, Dh, Dh), want_s,
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_state_carry_composes():
    """scan(T) == scan(T/2) then scan(T/2) with the carried state."""
    B, H, T, Dh, C = 1, 2, 64, 16, 8
    r, k, v = (jnp.asarray(RNG.normal(size=(B * H, T, Dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.abs(jnp.asarray(RNG.normal(size=(B * H, T, Dh)), jnp.float32))
    u = jnp.asarray(RNG.normal(size=(B * H, Dh)), jnp.float32)
    S0 = jnp.zeros((B * H, Dh, Dh))
    o_full, s_full = rwkv6_scan(r, k, v, logw, u, S0, chunk=C, interpret=True)
    h = T // 2
    o1, s1 = rwkv6_scan(r[:, :h], k[:, :h], v[:, :h], logw[:, :h], u, S0,
                        chunk=C, interpret=True)
    o2, s2 = rwkv6_scan(r[:, h:], k[:, h:], v[:, h:], logw[:, h:], u, s1,
                        chunk=C, interpret=True)
    np.testing.assert_allclose(np.concatenate([o1, o2], 1), o_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)


def test_model_rwkv_path_matches_kernel():
    """rwkv6_chunked (the model's jnp path) and the Pallas kernel agree."""
    import jax.random as jr
    from repro.models.rwkv6 import init_rwkv6, rwkv6_chunked, _proj
    D, Hn = 64, 4
    p = init_rwkv6(jr.PRNGKey(0), D, Hn, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 32, D)), jnp.float32)
    xp = jnp.zeros((2, D))
    st = jnp.zeros((2, Hn, D // Hn, D // Hn))
    out_model, _, st_model = rwkv6_chunked(p, x, xp, st, n_heads=Hn, chunk=8)

    r, k, v, logw, g = _proj(p, x, xp, Hn)
    tr = lambda a: a.transpose(0, 2, 1, 3).reshape(2 * Hn, 32, D // Hn)
    u_b = jnp.broadcast_to(p["u"][None], (2, Hn, D // Hn)).reshape(2 * Hn, -1)
    o_kern, s_kern = rwkv6_scan(
        tr(r).astype(jnp.float32), tr(k).astype(jnp.float32),
        tr(v).astype(jnp.float32), tr(logw), u_b,
        st.reshape(2 * Hn, D // Hn, D // Hn), chunk=8, interpret=True)
    np.testing.assert_allclose(
        s_kern.reshape(2, Hn, D // Hn, D // Hn), st_model, rtol=1e-4, atol=1e-4)


def test_mtgc_update_flat_nonfinite_row_isolation():
    """Fault-injection contract: the participation/crash mask is a
    where-select in-register, so a masked-out replica keeps its exact bits
    even when its g/z operands carry NaN/Inf -- and a poisoned ACTIVE row
    contaminates only itself (no cross-row leak through the block layout).
    """
    G, K, N = 2, 3, 300
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(G, K, N)), jnp.float32)
    g = np.asarray(rng.normal(size=(G, K, N)), np.float32)
    z = np.asarray(rng.normal(size=(G, K, N)), np.float32)
    y = jnp.asarray(rng.normal(size=(G, N)), jnp.float32)
    # Poison one masked-out replica and one active replica.
    g[0, 1] = np.nan
    z[0, 1] = np.inf
    g[1, 2] = np.nan
    mask = np.ones((G, K), np.float32)
    mask[0, 1] = 0.0
    got = np.asarray(mtgc_update_flat(x, jnp.asarray(g), jnp.asarray(z), y,
                                      jnp.asarray(mask), lr=0.07,
                                      interpret=True, block_rows=16))
    # Masked-out poisoned row: exact input bits, no NaN leak.
    np.testing.assert_array_equal(got[0, 1], np.asarray(x)[0, 1])
    # Active poisoned row: documented propagation -- NaN stays in-row.
    assert not np.isfinite(got[1, 2]).any()
    # Every other row is the clean reference update.
    want = np.asarray(ref.mtgc_update_flat_ref(
        x, jnp.asarray(g), jnp.asarray(z), y, jnp.asarray(mask), 0.07, 1.0))
    for gi in range(G):
        for ki in range(K):
            if (gi, ki) in ((0, 1), (1, 2)):
                continue
            np.testing.assert_allclose(got[gi, ki], want[gi, ki],
                                       rtol=1e-6, atol=1e-6)


def test_mtgc_update_tree_nonfinite_propagates():
    """The unmasked single-leaf kernel has no gate: non-finite operands
    propagate into the output (callers gate with masks-as-data upstream --
    that is the engines' job, not the kernel's)."""
    rng = np.random.default_rng(1)
    x, z, y = (jnp.asarray(rng.normal(size=(40,)), jnp.float32)
               for _ in range(3))
    g = np.asarray(rng.normal(size=(40,)), np.float32)
    g[7] = np.nan
    got = np.asarray(mtgc_update(x, jnp.asarray(g), z, y, lr=0.05,
                                 interpret=True, block_rows=8))
    assert np.isnan(got[7])
    assert np.isfinite(np.delete(got, 7)).all()
