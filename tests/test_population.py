"""Virtual client population (core.population): bit-exactness + claims.

Gates, per the subsystem's contract:

- **Degenerate bit-exactness**: ``population == cohort_size == levels[1]``
  reproduces the materialized engines state-for-state (and metric-for-
  metric) across algorithms x layouts x participation modes x backends --
  the cohort path is a pure refactor until the population actually
  exceeds the cohort.
- **Oracle persistence**: a pure-python replay of the cohort-draw key
  discipline plus a python-dict store must match ``run_population_rounds``
  bit-exactly across non-contiguous cohort draws, including a client that
  is sampled early, sits out, and returns with its correction intact.
- **Overlap == sequential**: the double-buffered path is bit-exact
  against the strictly sequential gather/scatter ordering even when
  consecutive cohorts share clients (the ``refresh`` patch path).
- **Stateless contract**, **validation**, **checkpoint round-trip**, the
  **Packer edge cases** (scalar / zero-size / mixed-dtype leaves) through
  gather/scatter, and the BENCH_population memory claim re-derived from
  the segment table at small scale (the wall-time claims are gated by the
  slow-marked benchmark run, CI's non-blocking job).
"""
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint import restore, save
from repro.core import PackedBatches, run_rounds
from repro.core.packer import FlatBuffers, is_flat, make_packer
from repro.core.population import (
    PopulationStore,
    draw_cohort,
    population_fields,
    run_population_rounds,
)

from test_mtgc_engine import D, quad_loss

G, K, E, H = 2, 3, 2, 2


def make_data(microbatches=None, seed=0, key=1):
    rng = np.random.default_rng(seed)
    steps = H * (microbatches or 1)
    shape = (G, K, 4, steps, D)
    arrays = {
        "a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
        "b": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
    }
    return PackedBatches(arrays, jax.random.PRNGKey(key), E, H, microbatches)


def build_engine(population=None, *, algorithm="mtgc", layout="flat",
                 backend="simulator", client_state="stateful", **kw):
    spec = api.ExperimentSpec(
        levels=(G, K), algorithm=algorithm, lr=0.05,
        schedule=api.RoundSchedule(
            group_rounds=E, local_steps=H,
            microbatches=1 if backend == "sharded" else None),
        state_layout=layout, backend=backend,
        population=population, client_state=client_state, **kw)
    return api.build(spec, quad_loss)


def assert_trees_equal(a, b, tag):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), tag
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{tag}[leaf {i}]")


# ---------------------------------------------------------------- degenerate


@pytest.mark.parametrize("backend", ["simulator", "sharded"])
@pytest.mark.parametrize("layout", ["tree", "flat"])
@pytest.mark.parametrize("algorithm", ["mtgc", "hfedavg", "feddyn"])
def test_degenerate_bitexact_vs_materialized(algorithm, layout, backend):
    """population == cohort == K: same states, same metrics, rng untouched."""
    if backend == "sharded" and algorithm == "feddyn":
        pytest.skip("feddyn is simulator-only")
    params = {"w": jnp.ones(D)}
    mb = 1 if backend == "sharded" else None
    base = build_engine(algorithm=algorithm, layout=layout, backend=backend)
    virt = build_engine(K, cohort_size=K, algorithm=algorithm, layout=layout,
                        backend=backend)
    s0, hz0 = api.fit(base, make_data(mb), 4, params=params,
                      rng=jax.random.PRNGKey(3), chunk=2)
    s1, hz1 = api.fit(virt, make_data(mb), 4, params=params,
                      rng=jax.random.PRNGKey(3), chunk=2)
    assert hz0.population is None
    assert isinstance(hz1.population, PopulationStore)
    assert_trees_equal(s0, s1, f"{algorithm}/{layout}/{backend} state")
    assert_trees_equal(hz0.metrics, hz1.metrics,
                       f"{algorithm}/{layout}/{backend} metrics")
    # The store holds exactly the final corrections, identity-mapped.
    for f in virt.population_fields:
        value = getattr(s1, f, None)
        if value is None:
            continue
        flat = value if is_flat(value) else \
            hz1.population.packers[f].flatten(value)
        for key, buf in flat.bufs.items():
            np.testing.assert_array_equal(
                hz1.population.data[f][key], np.asarray(buf),
                err_msg=f"store[{f}][{key}]")


@pytest.mark.parametrize("participation",
                         [{"client_participation": 0.5},
                          {"group_participation": 0.5},
                          {"client_participation": 0.5,
                           "group_participation": 0.5}])
def test_degenerate_bitexact_partial_participation(participation):
    """Partial in-round participation is legal at P == K and stays exact."""
    params = {"w": jnp.ones(D)}
    base = build_engine(**participation)
    virt = build_engine(K, **participation)
    s0, _ = api.fit(base, make_data(), 4, params=params,
                    rng=jax.random.PRNGKey(3), chunk=2)
    s1, _ = api.fit(virt, make_data(), 4, params=params,
                    rng=jax.random.PRNGKey(3), chunk=2)
    assert_trees_equal(s0, s1, f"partial {participation} state")


# -------------------------------------------------------------------- oracle


def oracle_draws(rng, num_draws, P):
    """Replay the cohort-draw key discipline in pure python/numpy."""
    out = []
    for _ in range(num_draws):
        ckey, rng = jax.random.split(rng)
        keys = jax.random.split(ckey, G)
        out.append(np.stack([
            np.asarray(jax.random.choice(k, P, (K,), replace=False))
            for k in keys
        ]))
    return out, rng


@pytest.mark.parametrize("overlap", [True, False])
def test_oracle_store_persistence(overlap):
    """Python-dict store + chunked materialized runs == the virtual path.

    P=7 over K=3 slots, 3 chunks -> non-contiguous draws; a client sampled
    in one chunk and skipped in the next must come back with its earlier
    correction bit-intact.
    """
    P, T, chunk = 7, 6, 2
    engine = build_engine(P)
    state0 = engine.init({"w": jnp.ones(D)}, jax.random.PRNGKey(11))
    store = engine.init_population(state0)
    out, _, hz = run_population_rounds(
        engine.round_fn, state0, store, make_data(), T, chunk=chunk,
        overlap=overlap)

    # --- oracle: same draws, python-side store, materialized chunks.
    engine2 = build_engine(P)
    state = engine2.init({"w": jnp.ones(D)}, jax.random.PRNGKey(11))
    packer = store.packers["z"]
    zstore = {key: np.zeros((G, P, n), np.dtype(key))
              for key, n in packer.buffer_sizes}
    draws, rng_end = oracle_draws(state.rng, T // chunk, P)
    rows = np.arange(G)[:, None]
    data = make_data()
    snapshots = []
    for idx in draws:
        z = FlatBuffers({key: jnp.asarray(buf[rows, idx])
                         for key, buf in zstore.items()}, packer)
        state = state._replace(z=z)
        state, data, _ = run_rounds(engine2.round_fn, state, data, chunk,
                                    chunk=chunk)
        for key, buf in zstore.items():
            buf[rows, idx] = np.asarray(state.z.bufs[key])
        snapshots.append({key: buf.copy() for key, buf in zstore.items()})
    state = state._replace(rng=rng_end)

    assert_trees_equal(state, out, f"oracle state overlap={overlap}")
    for key, buf in zstore.items():
        np.testing.assert_array_equal(store.data["z"][key], buf,
                                      err_msg=f"oracle store [{key}]")

    # Persistence across absence: some client of chunk 0 sits out chunk 1
    # (7 ids, 2 x 3 slots -> guaranteed by pigeonhole); its row must be
    # byte-identical from the chunk-0 scatter until it is drawn again.
    idx0, idx1 = draws[0], draws[1]
    checked = 0
    for g in range(G):
        for c in idx0[g]:
            if c in idx1[g]:
                continue
            for key in zstore:
                np.testing.assert_array_equal(
                    snapshots[1][key][g, c], snapshots[0][key][g, c],
                    err_msg=f"client ({g},{c}) lost its correction")
            checked += 1
    assert checked > 0


def test_overlap_matches_sequential_with_shared_clients():
    """P=5 over K=4, chunk=1: consecutive cohorts must share clients, so
    the overlapped pre-gather goes stale and ``refresh`` must patch it."""
    P, T = 5, 6
    runs = {}
    for overlap in (True, False):
        spec = api.ExperimentSpec(
            levels=(G, 4), algorithm="mtgc", lr=0.05,
            schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
            state_layout="flat", population=P)
        e = api.build(spec, quad_loss)
        rng = np.random.default_rng(0)
        shape = (G, 4, 4, E * H, D)
        data = PackedBatches(
            {"a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
             "b": jnp.asarray(rng.normal(size=shape).astype(np.float32))},
            jax.random.PRNGKey(1), E, H, None)
        state = e.init({"w": jnp.ones(D)}, jax.random.PRNGKey(11))
        st = e.init_population(state)
        out, _, _ = run_population_rounds(e.round_fn, state, st, data, T,
                                          chunk=1, overlap=overlap)
        runs[overlap] = (out, st)
    assert_trees_equal(runs[True][0], runs[False][0], "overlap state")
    for key in runs[True][1].data["z"]:
        np.testing.assert_array_equal(runs[True][1].data["z"][key],
                                      runs[False][1].data["z"][key],
                                      err_msg=f"overlap store [{key}]")


# ----------------------------------------------------------------- stateless


def test_stateless_zeroes_corrections_each_round():
    """client_state='stateless' == zeroing z before every round by hand."""
    from repro.core import select_round

    base = build_engine()
    stateless = build_engine(K, client_state="stateless")
    params = {"w": jnp.ones(D)}
    s_base = base.init(params)
    s_less = stateless.init(params)
    for r in range(3):
        batches = select_round(make_data(), jax.random.PRNGKey(100 + r))
        zeroed = s_base._replace(
            z=jax.tree.map(jnp.zeros_like, s_base.z),
            **({"dyn": jax.tree.map(jnp.zeros_like, s_base.dyn)}
               if getattr(s_base, "dyn", None) is not None else {}))
        s_base = base.round_fn(zeroed, batches)[0]
        s_less = stateless.round_fn(s_less, batches)[0]
        assert_trees_equal(s_base, s_less, f"stateless round {r}")


def test_stateless_fit_has_no_store():
    engine = build_engine(K, client_state="stateless")
    _, hz = api.fit(engine, make_data(), 3, params={"w": jnp.ones(D)})
    assert hz.population is None
    with pytest.raises(ValueError, match="no store"):
        engine.init_population(engine.init({"w": jnp.ones(D)}))


# ---------------------------------------------------------------- validation


@pytest.mark.parametrize("kw, match", [
    (dict(client_state="ephemeral"), "unknown client_state"),
    (dict(cohort_size=K), "set population too"),
    (dict(client_state="stateless"), "virtual-population contract"),
    (dict(population=0), "must be >= 1"),
    (dict(population=2 * K, levels=(G, K, 2), backend="multilevel"),
     "two-level"),
    (dict(population=2 * K, backend="multilevel"), "multilevel backend"),
    (dict(population=2 * K, cohort_size=K + 1), "must equal levels"),
    (dict(population=K - 1), "sampled without replacement"),
    (dict(population=2 * K, client_participation=0.5),
     "participation mechanism"),
    (dict(population=2 * K, group_participation=0.5),
     "participation mechanism"),
])
def test_validate_rejects_contradictions(kw, match):
    base = dict(
        levels=(G, K), algorithm="mtgc", lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H))
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        api.ExperimentSpec(**base).validate()


def test_validate_accepts_virtual_combinations():
    for kw in (dict(population=100), dict(population=K),
               dict(population=100, cohort_size=K),
               dict(population=100, client_state="stateless")):
        spec = api.ExperimentSpec(
            levels=(G, K), algorithm="mtgc", lr=0.05,
            schedule=api.RoundSchedule(group_rounds=E, local_steps=H), **kw)
        spec.validate()
        assert spec.virtual_population == (kw["population"] > K)


# ---------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip_and_continuation(tmp_path):
    """{"state", "population"} survives save -> restore bit-exactly, and a
    restored pair continues a horizon identically to the original."""
    P, T1, T2 = 7, 2, 4
    engine = build_engine(P)
    params = {"w": jnp.ones(D)}
    state = engine.init(params, jax.random.PRNGKey(11))
    store = engine.init_population(state)
    state, data, _ = run_population_rounds(
        engine.round_fn, state, store, make_data(), T1, chunk=1)

    save(str(tmp_path), T1, {"state": state, "population": store})
    like_state = engine.init(params, jax.random.PRNGKey(0))
    like = {"state": like_state, "population": engine.init_population(like_state)}
    restored = restore(str(tmp_path), T1, like)

    assert_trees_equal(restored["state"], state, "restored state")
    rs = restored["population"]
    assert isinstance(rs, PopulationStore)
    for key, buf in store.data["z"].items():
        got = rs.data["z"][key]
        assert isinstance(got, np.ndarray)          # in-place scatter works
        np.testing.assert_array_equal(got, buf, err_msg=f"store [{key}]")

    out_a, _, _ = run_population_rounds(
        engine.round_fn, state, store, data, T2, chunk=2)
    out_b, _, _ = run_population_rounds(
        engine.round_fn, restored["state"], rs, data, T2, chunk=2)
    assert_trees_equal(out_a, out_b, "continuation")
    for key in store.data["z"]:
        np.testing.assert_array_equal(store.data["z"][key],
                                      rs.data["z"][key],
                                      err_msg=f"continued store [{key}]")


# --------------------------------------------------------- packer edge cases


class FakeState(NamedTuple):
    z: Any
    rng: Any = None


EDGE_TEMPLATE = {
    "scalar": jnp.zeros((), jnp.float32),
    "empty": jnp.zeros((0,), jnp.float32),
    "ints": jnp.zeros((3,), jnp.int32),
    "half": jnp.zeros((2, 2), jnp.bfloat16),
    "w": jnp.zeros((4,), jnp.float32),
}


def _edge_flat(seed=0):
    packer = make_packer(EDGE_TEMPLATE)
    rng = np.random.default_rng(seed)
    bufs = {}
    for key, n in packer.buffer_sizes:
        raw = rng.normal(size=(G, K, n)) * 10
        bufs[key] = jnp.asarray(raw.astype(np.dtype(key)))
    return FlatBuffers(bufs, packer)


@pytest.mark.parametrize("layout", ["flat", "tree"])
def test_store_edge_case_leaves_roundtrip(layout):
    """Scalar, zero-size, and mixed-dtype leaves gather/scatter bit-exactly
    in both state layouts, and untouched population rows never move."""
    P = 9
    flat = _edge_flat()
    value = flat if layout == "flat" else flat.to_tree()
    state = FakeState(z=value)
    store = PopulationStore.from_state(state, P, ("z", "dyn"))
    assert store.fields == ("z",)                  # absent dyn dropped
    assert store.state_bytes() == sum(
        buf.nbytes for buf in store.data["z"].values())
    assert store.device_bytes(K) == sum(
        np.asarray(buf).nbytes for buf in flat.bufs.values())

    before = {key: buf.copy() for key, buf in store.data["z"].items()}
    idx = np.stack([np.array([8, 3, 5]), np.array([0, 7, 4])])
    staged = store.gather(idx)
    installed = store.install(state, staged)
    # Tree states rebuild through the segment table: structure + dtypes of
    # every edge-case leaf survive.
    assert_trees_equal(jax.tree.map(jnp.zeros_like, installed.z),
                       jax.tree.map(jnp.zeros_like, value), "structure")

    host = store.extract(installed)
    perturbed = {f: {key: arr + np.ones_like(arr) for key, arr in bufs.items()}
                 for f, bufs in host.items()}
    store.scatter(idx, perturbed)
    rows = np.arange(G)[:, None]
    mask = np.zeros((G, P), bool)
    mask[rows, idx] = True
    for key, buf in store.data["z"].items():
        np.testing.assert_array_equal(buf[rows, idx], perturbed["z"][key],
                                      err_msg=f"scattered rows [{key}]")
        np.testing.assert_array_equal(buf[~mask], before[key][~mask],
                                      err_msg=f"untouched rows [{key}]")

    # Round-trip back through install: the scattered rows come back bit-
    # exact through gather -> install -> extract.
    back = store.extract(store.install(state, store.gather(idx)))
    for key in back["z"]:
        np.testing.assert_array_equal(back["z"][key], perturbed["z"][key],
                                      err_msg=f"roundtrip [{key}]")


def test_draw_cohort_shape_and_distinctness():
    idx = draw_cohort(jax.random.PRNGKey(0), G, 50, K)
    assert idx.shape == (G, K)
    for g in range(G):
        assert len(set(idx[g].tolist())) == K
        assert idx[g].min() >= 0 and idx[g].max() < 50
    # Same key -> same cohort; different key -> (overwhelmingly) different.
    again = draw_cohort(jax.random.PRNGKey(0), G, 50, K)
    np.testing.assert_array_equal(idx, again)


def test_population_fields_per_algorithm():
    assert population_fields("feddyn") == ("z", "dyn")
    for algo in ("mtgc", "hfedavg", "local_corr", "group_corr", "fedprox"):
        assert population_fields(algo) == ("z",)


# ----------------------------------------------------- memory claim (small)


def test_memory_claim_from_segment_table():
    """Claim (i) of BENCH_population at small scale: device bytes constant
    in P (and equal to the real cohort buffers), host bytes exactly linear."""
    engine = build_engine(K)
    state = engine.init({"w": jnp.ones(D)})
    populations = (K, 10 * K, 100 * K)
    stores = [PopulationStore.from_state(state, P) for P in populations]
    device = [s.device_bytes(K) for s in stores]
    assert len(set(device)) == 1
    assert device[0] == sum(np.asarray(b).nbytes for b in state.z.bufs.values())
    host = [s.state_bytes() for s in stores]
    slopes = {(host[i + 1] - host[i]) / (populations[i + 1] - populations[i])
              for i in range(len(host) - 1)}
    assert len(slopes) == 1 and slopes.pop() > 0
    for s, P in zip(stores, populations):
        assert s.state_bytes() == sum(
            buf.nbytes for bufs in s.data.values() for buf in bufs.values())
        report = s.size_report(K)
        assert report["host_bytes"] == s.state_bytes()
        assert report["device_bytes"] == device[0]


@pytest.mark.slow
def test_bench_population_claims():
    """Full claim gate (memory + wall-time independence + overlap overhead)
    at benchmark scale; runs in the non-blocking CI job."""
    from benchmarks.bench_population import bench

    out = bench(G=2, K=8, n=30_000, T=8, chunk=4, reps=3,
                populations=(8, 80, 800))
    assert out["all_claims_ok"], out["claims"]


# ------------------------------------------------------------- fit routing


def test_fit_virtual_tree_layout_end_to_end():
    """Virtual mode with the tree state layout: fit auto-creates the store,
    rides it on Horizon.population, and a second fit continues from it."""
    P = 12
    spec = api.ExperimentSpec(
        levels=(G, K), algorithm="mtgc", lr=0.05,
        schedule=api.RoundSchedule(group_rounds=E, local_steps=H),
        state_layout="tree", population=P, cohort_size=K)
    engine = api.build(spec, quad_loss)
    state, hz = api.fit(engine, make_data(), 4, params={"w": jnp.ones(D)},
                        chunk=2)
    store = hz.population
    assert isinstance(store, PopulationStore)
    assert store.population == P and not store.flat["z"]
    touched = {key: np.any(buf != 0, axis=-1).sum()
               for key, buf in store.data["z"].items()}
    assert all(v > 0 for v in touched.values())
    state2, hz2 = api.fit(engine, hz.data, 4, state=state,
                          population_store=store, chunk=2)
    assert hz2.population is store
