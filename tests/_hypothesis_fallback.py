"""Minimal deterministic stand-in for `hypothesis` (offline containers).

The property-test modules import ``given/settings/strategies`` from
``hypothesis``; CI installs the real thing via the ``test`` extra, but the
paper-repro container has no network. ``conftest.py`` registers this module
under the ``hypothesis`` name when the import fails, so collection succeeds
and the property tests still run a fixed, seeded batch of examples instead
of being skipped wholesale.

Only the surface this suite uses is implemented: ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=..., derandomize=...)``,
and the ``integers`` / ``floats`` / ``booleans`` / ``sampled_from`` /
``tuples`` strategies. No shrinking, no database -- failures report the drawn example
in the assertion context instead.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import sys
import types

import numpy as np

# Keep the fallback cheap: real hypothesis explores more, this is a smoke net.
_MAX_EXAMPLES_CAP = 10
_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw_fn, desc: str):
        self._draw_fn = draw_fn
        self.desc = desc

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)

    def __repr__(self):
        return f"<fallback strategy {self.desc}>"


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)),
                     f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: float(r.uniform(min_value, max_value)),
                     f"floats({min_value}, {max_value})")


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.integers(0, 2)), "booleans()")


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: elements[int(r.integers(0, len(elements)))],
                     f"sampled_from({elements!r})")


def tuples(*strategies) -> _Strategy:
    return _Strategy(lambda r: tuple(s.draw(r) for s in strategies),
                     f"tuples({', '.join(s.desc for s in strategies)})")


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
             derandomize: bool = False, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_fallback_max_examples",
                            _DEFAULT_EXAMPLES), _MAX_EXAMPLES_CAP)
            # Deterministic per-test stream: stable across runs and machines.
            seed = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big")
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {drawn}") from e

        # Hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis does the same); __wrapped__ would leak the original
        # signature through inspect.signature.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies
        ])
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "tuples"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
