"""Compiled horizon driver (core/driver.py) vs the per-round loop.

The driver's contract is *bit-exact* equivalence: scanning T rounds of
(on-device batch selection + round function) inside one donated jit must
reproduce exactly what T single-round dispatches produce from the same
state and the same packed dataset. Gated here for all six algorithms x
{tree, flat} state x {full, uniform} participation, for chunked dispatch
(including the T % chunk remainder), and for the sharded production round.
Donation itself is asserted by checking the input buffers are invalidated.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGORITHMS,
    HFLConfig,
    PackedBatches,
    as_tree,
    hfl_init,
    make_global_round,
    make_round_step,
    pack_client_shards,
    run_rounds,
    select_round,
)

from test_mtgc_engine import D, quad_loss

G, K, E, H, T = 2, 3, 2, 2, 5


def _donation_supported() -> bool:
    f = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    x = jnp.ones((8,))
    f(x)
    return x.is_deleted()


needs_donation = pytest.mark.skipif(
    not _donation_supported(),
    reason="buffer donation unsupported on this backend")


def make_data(S=4, seed=0, key=1, microbatches=None):
    """Packed quadratic data: per-(client, shard, step) (a, b) pairs."""
    rng = np.random.default_rng(seed)
    steps = H * (microbatches or 1)
    shape = (G, K, S, steps, D)
    arrays = {
        "a": jnp.asarray(rng.normal(size=shape).astype(np.float32) + 2.0),
        "b": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
    }
    return PackedBatches(arrays, jax.random.PRNGKey(key), E, H, microbatches)


def _loop(round_fn, state, data, rounds=T):
    step = make_round_step(round_fn, donate=False)
    mets = []
    for _ in range(rounds):
        state, data, m = step(state, data)
        mets.append(m)
    stacked = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                           *mets)
    return state, data, stacked


def _assert_bitexact(state_a, state_b, metrics_a, metrics_b, fields, tag):
    for name in fields:
        np.testing.assert_array_equal(
            np.asarray(as_tree(getattr(state_a, name))["w"]),
            np.asarray(as_tree(getattr(state_b, name))["w"]),
            err_msg=f"{tag}.{name}")
    for name in metrics_a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(metrics_a, name)),
            np.asarray(getattr(metrics_b, name)),
            err_msg=f"{tag}.metrics.{name}")


# ----------------------------------------------- driver vs per-round loop


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("flat", [False, True], ids=["tree", "flat"])
@pytest.mark.parametrize("participation", ["full", "uniform"])
def test_driver_matches_loop(algo, flat, participation):
    kw = dict(num_groups=G, clients_per_group=K, local_steps=H,
              group_rounds=E, lr=0.05, algorithm=algo, prox_mu=0.1,
              feddyn_alpha=0.1, use_flat_state=flat)
    if participation == "uniform":
        kw.update(client_participation=0.5, group_participation=0.75,
                  participation_mode="uniform")
    cfg = HFLConfig(**kw)
    rf = make_global_round(quad_loss, cfg)

    state_l, data_l, metrics_l = _loop(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data())
    state_d, data_d, hz = run_rounds(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), T, donate=False)

    tag = f"{algo}/{'flat' if flat else 'tree'}/{participation}"
    _assert_bitexact(state_l, state_d, metrics_l, hz.metrics,
                     ("params", "z", "y", "dyn"), tag)
    # Both rng streams advanced identically (participation + selection).
    np.testing.assert_array_equal(np.asarray(state_l.rng),
                                  np.asarray(state_d.rng))
    np.testing.assert_array_equal(np.asarray(data_l.rng),
                                  np.asarray(data_d.rng))


def test_chunked_matches_unchunked():
    """chunk=2 over T=5 (chunks of 2, 2, and a remainder of 1) is bit-exact
    against the single whole-horizon dispatch."""
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    rf = make_global_round(quad_loss, cfg)
    state_u, _, hz_u = run_rounds(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), T, donate=False)
    state_c, _, hz_c = run_rounds(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), T, chunk=2,
        donate=False)
    _assert_bitexact(state_u, state_c, hz_u.metrics, hz_c.metrics,
                     ("params", "z", "y"), "chunked")
    assert np.asarray(hz_c.metrics.loss).shape[0] == T
    # Oversized / zero chunk both mean "whole horizon".
    state_o, _, _ = run_rounds(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), T, chunk=99,
        donate=False)
    np.testing.assert_array_equal(
        np.asarray(as_tree(state_o.params)["w"]),
        np.asarray(as_tree(state_u.params)["w"]))


def test_eval_fn_cadence_and_values():
    """eval_fn fires at eval_every multiples plus the final round, inside the
    compiled scan, and sees the post-round state."""
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    rf = make_global_round(quad_loss, cfg)

    def eval_fn(prev, state):
        return {"pmean": jnp.mean(as_tree(state.params)["w"]),
                "round": state.round}

    state, data, hz = run_rounds(
        rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), T, chunk=2,
        eval_every=2, eval_fn=eval_fn, donate=False)
    np.testing.assert_array_equal(hz.eval_rounds, [2, 4, 5])
    np.testing.assert_array_equal(np.asarray(hz.evals["round"]), [2, 4, 5])

    # Cross-check values against the per-round loop.
    state_l, data_l = hfl_init({"w": jnp.zeros(D)}, cfg), make_data()
    step = make_round_step(rf, donate=False)
    want = []
    for t in range(T):
        state_l, data_l, _ = step(state_l, data_l)
        if (t + 1) % 2 == 0 or t == T - 1:
            want.append(float(jnp.mean(as_tree(state_l.params)["w"])))
    np.testing.assert_array_equal(np.asarray(hz.evals["pmean"]),
                                  np.asarray(want, np.float32))


# ------------------------------------------------------------- donation


@needs_donation
def test_run_rounds_donates_state_buffers():
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    rf = make_global_round(quad_loss, cfg)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    bufs = [leaf for f in ("params", "z", "y", "dyn")
            for leaf in jax.tree.leaves(getattr(state, f))]
    state2, _, _ = run_rounds(rf, state, make_data(), 2)
    assert all(b.is_deleted() for b in bufs)
    assert not any(b.is_deleted() for b in jax.tree.leaves(state2.params))


@needs_donation
def test_round_step_donates_state_buffers():
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    rf = make_global_round(quad_loss, cfg)
    state = hfl_init({"w": jnp.zeros(D)}, cfg)
    buf = jax.tree.leaves(state.params)[0]
    step = make_round_step(rf)
    state, _, _ = step(state, make_data())
    assert buf.is_deleted()


# ------------------------------------------- packed data + selection layout


def test_select_round_gathers_whole_client_shards():
    """Every [e, :, g, k] block of the selected batches is one of client
    (g, k)'s own packed shards, taken whole."""
    S = 5
    base = (np.arange(G)[:, None, None, None] * 1000
            + np.arange(K)[None, :, None, None] * 100
            + np.arange(S)[None, None, :, None] * 10
            + np.arange(H)[None, None, None, :])
    data = PackedBatches({"v": jnp.asarray(base, jnp.float32)},
                         jax.random.PRNGKey(3), E, H, None)
    out = np.asarray(select_round(data, jax.random.PRNGKey(7))["v"])
    assert out.shape == (E, H, G, K)
    for e in range(E):
        for g in range(G):
            for k in range(K):
                block = out[e, :, g, k]
                s = (block[0] - g * 1000 - k * 100) / 10
                assert s == int(s) and 0 <= s < S, block
                np.testing.assert_array_equal(
                    block, base[g, k, int(s)].astype(np.float32))


def test_select_round_microbatched_layout():
    A, S = 2, 3
    steps = H * A
    arrays = {"x": jnp.arange(G * K * S * steps * 4, dtype=jnp.float32)
              .reshape(G, K, S, steps, 4)}
    data = PackedBatches(arrays, jax.random.PRNGKey(0), E, H, A)
    out = select_round(data, jax.random.PRNGKey(1))["x"]
    assert out.shape == (E, H, A, G, K, 4)
    # [H, A] must be the steps axis split in order: microbatch a of step h
    # is packed step h * A + a.
    flat = np.asarray(out).reshape(E, steps, G, K, 4)
    back = PackedBatches(arrays, jax.random.PRNGKey(0), E, steps, None)
    np.testing.assert_array_equal(
        flat, np.asarray(select_round(back, jax.random.PRNGKey(1))["x"]))


def test_pack_client_shards_draws_from_client_pools():
    rng = np.random.default_rng(0)
    n = 64
    idx = [[np.arange(g * K * 8 + k * 8, g * K * 8 + k * 8 + 8)
            for k in range(K)] for g in range(G)]
    x = np.arange(n, dtype=np.float32)    # feature == global sample index
    y = np.arange(n, dtype=np.int32)
    data = pack_client_shards({"x": x, "y": y}, idx, group_rounds=E,
                              local_steps=H, batch_size=3, shards=4, rng=rng,
                              key=jax.random.PRNGKey(0))
    assert data.num_shards == 4
    xs = np.asarray(data.arrays["x"])
    assert xs.shape == (G, K, 4, H, 3)
    np.testing.assert_array_equal(xs, np.asarray(data.arrays["y"]))
    for g in range(G):
        for k in range(K):
            assert set(xs[g, k].ravel().astype(int)) <= set(idx[g][k])


def test_packed_batches_is_a_pytree():
    data = make_data()
    leaves = jax.tree.leaves(data)
    assert len(leaves) == 3      # a, b, rng
    mapped = jax.tree.map(lambda x: x, data)
    assert isinstance(mapped, PackedBatches)
    assert (mapped.group_rounds, mapped.local_steps, mapped.microbatches) == \
        (E, H, None)


# --------------------------------------------------- chunk-runner caching


def test_chunk_runner_cached_per_round_fn_and_collectable():
    """Repeated run_rounds with the same round function reuse one compiled
    runner (no retrace); dropping the round function releases the runner
    (the old identity-keyed lru_cache kept dead executables pinned)."""
    import gc
    import weakref

    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    base = make_global_round(quad_loss, cfg)
    traces = []

    def rf(state, batches):
        traces.append(1)
        return base(state, batches)

    run_rounds(rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), 2,
               donate=False)
    assert len(traces) == 1 and len(rf.__chunk_runners__) == 1
    runner = rf.__chunk_runners__[(None, False)]
    run_rounds(rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), 2,
               donate=False)
    # Cache hit: same runner object, no second trace.
    assert len(traces) == 1
    assert rf.__chunk_runners__[(None, False)] is runner

    ref = weakref.ref(runner)
    del runner, rf
    gc.collect()
    assert ref() is None, "dead round fn still pins its compiled runner"


def test_chunk_runner_distinct_eval_fns_get_distinct_runners():
    cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                    group_rounds=E, lr=0.05, algorithm="mtgc")
    rf = make_global_round(quad_loss, cfg)

    def ev1(prev, state):
        return {"v": state.round}

    def ev2(prev, state):
        return {"v": state.round + 1}

    for ev in (ev1, ev2, ev1):
        run_rounds(rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), 2,
                   eval_fn=ev, donate=False)
    assert len(rf.__chunk_runners__) == 2

    # Fresh eval closures per call must not grow the cache without bound.
    from repro.core.driver import _RUNNERS_PER_FN
    evs = []  # keep ids alive so every closure is a distinct live key
    for _ in range(_RUNNERS_PER_FN + 3):
        evs.append(lambda prev, state: {"v": state.round})
        run_rounds(rf, hfl_init({"w": jnp.zeros(D)}, cfg), make_data(), 2,
                   eval_fn=evs[-1], donate=False)
    assert len(rf.__chunk_runners__) <= _RUNNERS_PER_FN


# --------------------------------------------------- sharded round parity


def test_driver_matches_loop_sharded_round():
    """The production round (launch.train) under the driver's microbatched
    layout: loop vs compiled horizon, bit-exact."""
    from repro.launch.train import make_sharded_round, sharded_init

    A = 2
    rf = make_sharded_round(quad_loss, E=E, H=H, lr=0.05)
    rounds = 3

    state_l, data_l, metrics_l = _loop(
        rf, sharded_init({"w": jnp.zeros(D)}, G, K),
        make_data(microbatches=A), rounds=rounds)
    state_d, data_d, hz = run_rounds(
        rf, sharded_init({"w": jnp.zeros(D)}, G, K),
        make_data(microbatches=A), rounds, chunk=2, donate=False)

    _assert_bitexact(state_l, state_d, metrics_l, hz.metrics,
                     ("params", "z", "y"), "sharded")
    np.testing.assert_array_equal(np.asarray(data_l.rng),
                                  np.asarray(data_d.rng))
