"""Suite-wide config.

The property-test modules need ``hypothesis``; CI installs it via the
``test`` extra, but the offline repro container cannot. Register the
deterministic fallback (tests/_hypothesis_fallback.py) before those modules
import, so collection never dies on ModuleNotFoundError.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
