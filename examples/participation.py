"""Partial-participation demo: half the clients sit out every round.

Same hierarchy and non-i.i.d. data as the quickstart, but each global round
samples 50% of every group's clients ('fixed' mode: exactly half). The host
asks the engine's RNG who participates (`round_masks`) *before* packing
batches, so inactive clients cost no host sampling and no host->device
bytes; the jitted round derives the identical masks internally and freezes
everyone who sat out. MTGC's corrections keep helping under sampling --
compare against hierarchical FedAvg on the same mask/batch stream.

    PYTHONPATH=src python examples/participation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HFLConfig, as_tree, hfl_init, make_global_round, round_masks
from repro.data.partition import partition, sample_round_batches
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp


def main():
    G, K, E, H, rounds = 4, 5, 4, 5, 15
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)

    for algo in ("mtgc", "hfedavg"):
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.1, algorithm=algo,
                        client_participation=0.5, participation_mode="fixed")
        state = hfl_init(init(jax.random.PRNGKey(0)), cfg)
        step = jax.jit(make_global_round(loss_fn, cfg))
        data_rng = np.random.default_rng(1)  # same stream for both algos
        print(f"\n== {algo} @ 50% client participation ==")
        for t in range(rounds):
            masks, _ = round_masks(state.rng, cfg)   # who trains this round?
            cmask = np.asarray(masks.client)
            batches = sample_round_batches(train.x, train.y, idx, data_rng,
                                           E, H, batch_size=32,
                                           client_mask=cmask)
            state, m = step(state, jax.tree.map(jnp.asarray, batches))
            if (t + 1) % 5 == 0:
                # Evaluate a replica that received the last dissemination.
                g_a, k_a = np.argwhere(cmask > 0)[0]
                params = as_tree(jax.tree.map(lambda x: x[g_a, k_a], state.params))
                acc = accuracy(apply, params, jnp.asarray(test.x), test.y)
                print(f"round {t+1:3d}  active {int(cmask.sum()):2d}/{G*K}  "
                      f"loss {float(np.mean(m.loss)):.4f}  test acc {acc:.4f}  "
                      f"||z||^2 {float(m.z_norm):.3e}  "
                      f"||y||^2 {float(m.y_norm):.3e}")


if __name__ == "__main__":
    main()
