"""Partial-participation demo: half the clients sit out every round.

Same hierarchy and non-i.i.d. data as the quickstart, but each global round
samples 50% of every group's clients ('fixed' mode: exactly half). The
experiment is declared once as an ``ExperimentSpec`` and the whole run is
one compiled scan (``repro.api.fit`` over core/driver.py): participation
masks are drawn from the engine state's PRNG *inside* the program, batches
come from on-device selection out of the once-uploaded packed dataset, and
evaluation picks an active replica each eval round by re-deriving the
round's mask from the pre-round rng (``engine.participation_masks``), all
under the same jit. MTGC's corrections keep helping under sampling -- compare against
hierarchical FedAvg on the same mask/batch stream.

    PYTHONPATH=src python examples/participation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.core import as_tree
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp


def main():
    G, K, E, H, rounds = 4, 5, 4, 5, 15
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    for algo in ("mtgc", "hfedavg"):
        spec = ExperimentSpec(
            levels=(G, K),
            schedule=RoundSchedule(group_rounds=E, local_steps=H),
            algorithm=algo, lr=0.1,
            client_participation=0.5, participation_mode="fixed")
        engine = build(spec, loss_fn)

        def eval_fn(prev, state, engine=engine):
            # Frozen replicas hold stale params: evaluate a client that
            # received this round's dissemination. The round's mask is
            # re-derived from the pre-round rng -- exactly the draw the
            # engine used inside the round.
            cmask = engine.participation_masks(prev.rng)[0].client
            i = jnp.argmax(cmask.reshape(-1))
            params = as_tree(jax.tree.map(lambda v: v[i // K, i % K],
                                          state.params))
            return {"acc": acc_of(params)}

        data = engine.pack_arrays({"x": train.x, "y": train.y}, idx,
                                  batch_size=32, shards=8,
                                  rng=np.random.default_rng(1),
                                  key=jax.random.PRNGKey(1))
        state, hz = fit(engine, data, rounds,
                        params=init(jax.random.PRNGKey(0)),
                        eval_every=5, eval_fn=eval_fn)
        print(f"\n== {algo} @ 50% client participation ==")
        for i, r in enumerate(hz.eval_rounds):
            active = int(round(float(hz.metrics.participation[r-1]) * G * K))
            print(f"round {r:3d}  active {active:2d}/{G*K}  "
                  f"loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
                  f"test acc {float(hz.evals['acc'][i]):.4f}  "
                  f"||z||^2 {float(hz.metrics.z_norm[r-1]):.3e}  "
                  f"||y||^2 {float(hz.metrics.y_norm[r-1]):.3e}")

    # Bernoulli availability: under 'uniform' sampling the realized count
    # fluctuates round to round, and spec.participation_weighting picks the
    # aggregation estimator -- 'none' renormalizes by whoever showed up,
    # 'inverse_prob' divides by the expected count (Horvitz-Thompson) so the
    # aggregates MTGC's z/y corrections track stay unbiased (under 'fixed'
    # sampling, above, the two coincide). The price is variance: the
    # disseminated aggregate is scaled by (realized / expected) count, so
    # the unbiased estimator wants enough clients per group (here K=10 at
    # 80% availability; at K=5 / 50% the multiplicative noise can blow up a
    # nonlinear model). See benchmarks/fig_participation --bias-bench for
    # the bias/variance numbers on the quadratic objective.
    Gw, Kw, Ew = 4, 10, 2
    rng_w = np.random.default_rng(2)
    ds_w = make_classification(rng_w, num_samples=12000, num_classes=10,
                               dim=32)
    train_w, test_w = train_test_split(ds_w, rng_w)
    idx_w = partition(train_w.y, Gw, Kw, mode="both_noniid", alpha=0.3,
                      seed=2)
    acc_w = jit_accuracy(apply, jnp.asarray(test_w.x), jnp.asarray(test_w.y))
    for weighting in ("none", "inverse_prob"):
        spec = ExperimentSpec(
            levels=(Gw, Kw),
            schedule=RoundSchedule(group_rounds=Ew, local_steps=H),
            algorithm="mtgc", lr=0.1,
            client_participation=0.8,
            participation_mode="uniform",
            participation_weighting=weighting)
        engine = build(spec, loss_fn)

        def eval_fn(prev, state, engine=engine):
            cmask = engine.participation_masks(prev.rng)[0].client
            i = jnp.argmax(cmask.reshape(-1))
            params = as_tree(jax.tree.map(lambda v: v[i // Kw, i % Kw],
                                          state.params))
            return {"acc": acc_w(params)}

        data = engine.pack_arrays({"x": train_w.x, "y": train_w.y}, idx_w,
                                  batch_size=32, shards=8,
                                  rng=np.random.default_rng(3),
                                  key=jax.random.PRNGKey(3))
        state, hz = fit(engine, data, rounds,
                        params=init(jax.random.PRNGKey(0)),
                        eval_every=5, eval_fn=eval_fn)
        print(f"\n== mtgc @ Bernoulli 80%, weighting={weighting} ==")
        for i, r in enumerate(hz.eval_rounds):
            active = int(round(float(hz.metrics.participation[r-1]) * Gw * Kw))
            print(f"round {r:3d}  active {active:2d}/{Gw*Kw}  "
                  f"loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
                  f"test acc {float(hz.evals['acc'][i]):.4f}")


if __name__ == "__main__":
    main()
