"""Paper Appendix E: MTGC on a three-level hierarchy (Algorithm 2).

cloud -> 2 regions -> 2 edges/region -> 3 clients/edge, with aggregation
periods (P1, P2, P3) = (8, 4, 2) local steps and non-i.i.d. data at every
level. Declared through the same front door as the two-level experiments:
``ExperimentSpec(levels=(2, 2, 3), backend="multilevel",
schedule=RoundSchedule(periods=...))`` -- and driven by the same compiled
horizon (``fit``), with the three-level batch blocks packed once and
gathered on device (the driver's packing generalizes to any topology
depth).

    PYTHONPATH=src python examples/three_level.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.core import as_tree
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp


def main():
    dims, periods = (2, 2, 3), (8, 4, 2)
    rounds = 20
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    flat_idx = partition(train.y, dims[0], dims[1] * dims[2],
                         mode="both_noniid", alpha=0.1, seed=0)
    # Re-nest the per-client pools to the tree shape: [region][edge][client].
    idx = [[[flat_idx[k1][k2 * dims[2] + k3] for k3 in range(dims[2])]
            for k2 in range(dims[1])] for k1 in range(dims[0])]

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    spec = ExperimentSpec(levels=dims, backend="multilevel", lr=0.1,
                          schedule=RoundSchedule(periods=periods))
    engine = build(spec, loss_fn)

    def eval_fn(prev, state):
        return {"acc": acc_of(engine.global_model(state))}

    data = engine.pack_arrays({"x": train.x, "y": train.y}, idx,
                              batch_size=32, shards=8,
                              rng=np.random.default_rng(1),
                              key=jax.random.PRNGKey(1))
    st, hz = fit(engine, data, rounds, params=init(jax.random.PRNGKey(0)),
                 eval_every=5, eval_fn=eval_fn)
    for i, r in enumerate(hz.eval_rounds):
        print(f"round {r:3d}  loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
              f"acc {float(hz.evals['acc'][i]):.4f}")
    # Paper Sec. 3.2 invariant, generalized: each level's corrections sum
    # to zero over the children of any aggregator.
    nu1 = as_tree(st.nus[0])
    print("correction-sum invariant (level 1):",
          "%.2e" % max(float(jnp.abs(jnp.asarray(leaf).sum(0)).max())
                       for leaf in jax.tree.leaves(nu1)),
          "(see tests for full checks)")


if __name__ == "__main__":
    main()
