"""Paper Appendix E: MTGC on a three-level hierarchy (Algorithm 2).

cloud -> 2 regions -> 2 edges/region -> 3 clients/edge, with aggregation
periods (P1, P2, P3) = (8, 4, 2) local steps and non-i.i.d. data at every
level.

    PYTHONPATH=src python examples/three_level.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_multilevel_round, multilevel_global_model, multilevel_init
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp


def main():
    dims, periods = (2, 2, 3), (8, 4, 2)
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, dims[0], dims[1] * dims[2],
                    mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    st = multilevel_init(init(jax.random.PRNGKey(0)), dims)
    rf = jax.jit(make_multilevel_round(loss_fn, dims, periods, 0.1))

    P1, B = periods[0], 32
    for t in range(20):
        sel = np.stack([
            np.stack([rng.choice(idx[k1][k2 * dims[2] + k3], size=(P1, B))
                      for k2 in range(dims[1]) for k3 in range(dims[2])]
                     ).reshape(dims[1], dims[2], P1, B)
            for k1 in range(dims[0])])
        batches = {"x": jnp.asarray(train.x[sel].transpose(3, 0, 1, 2, 4, 5)),
                   "y": jnp.asarray(train.y[sel].transpose(3, 0, 1, 2, 4))}
        st, losses = rf(st, batches)
        if (t + 1) % 5 == 0:
            acc = accuracy(apply, multilevel_global_model(st),
                           jnp.asarray(test.x), test.y)
            print(f"round {t+1:3d}  loss {float(losses.mean()):.4f}  acc {acc:.4f}")
    print("correction-sum invariants:",
          ["%.2e" % float(jnp.abs(jnp.asarray(nu['l1']['w']).sum(m)).max())
           if isinstance(nu, dict) and 'l1' in nu else "ok"
           for m, nu in enumerate(st.nus)][:1], "(see tests for full checks)")


if __name__ == "__main__":
    main()
