"""Batched serving demo: prefill a batch of prompts, then greedy-decode.

Runs three architecture families (dense GQA, attention-free RWKV6, hybrid
Hymba) through the same prefill/decode_step API the dry-run lowers at
32k/524k context on the production mesh.

Standalone by design: serving is not federated, so this demo deliberately
does not go through ``repro.api`` (the HFL experiment front door) -- it
exercises only the model bundles' prefill/decode surface. Training
examples all construct via ``repro.api.build``/``fit``.

    PYTHONPATH=src python examples/serve_decode.py --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.transformer import build_model


def serve(arch: str, B: int, T: int, gen: int):
    cfg = get_arch(arch).reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    cache = bundle.init_cache(B, T + gen)
    prefill = jax.jit(bundle.prefill)
    decode = jax.jit(bundle.decode_step)

    t0 = time.time()
    lg, cache = prefill(params, {"tokens": toks}, cache)
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        lg, cache = decode(params, {"token": tok,
                                    "index": jnp.asarray(T + i, jnp.int32)}, cache)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seq = np.asarray(jnp.concatenate(out, 1))
    print(f"{arch:14s} batch={B} prompt={T} generated={gen} "
          f"in {dt:.2f}s ({B*gen/dt:.0f} tok/s)  sample: {seq[0][:10]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    for arch in ("qwen3-14b", "rwkv6-1.6b", "hymba-1.5b"):
        serve(arch, args.batch, args.prompt, args.gen)


if __name__ == "__main__":
    main()
