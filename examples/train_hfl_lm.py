"""End-to-end driver: hierarchical MTGC *language-model* training with the
production round (microbatched, shardable), domain-skewed token shards per
client, periodic eval + checkpointing.

Defaults train a ~7M-param glm4-family model for 50 global rounds x E2 x H2
(=200 local steps) on CPU in a few minutes; crank --layers/--d-model up to
the 100M regime on real hardware (the same script is what the dry-run
lowers at 26B scale on the production mesh).

    PYTHONPATH=src python examples/train_hfl_lm.py --rounds 50
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_arch
from repro.data.lm import make_lm_tokens
from repro.launch.train import make_sharded_round, sharded_init
from repro.models.transformer import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--E", type=int, default=2)
    ap.add_argument("--H", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/mtgc_lm_ckpt")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced(
        num_layers=args.layers, d_model=args.d_model,
        d_ff=4 * args.d_model, vocab_size=2048, num_heads=8,
        d_head=args.d_model // 8)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced) params={n/1e6:.2f}M  "
          f"topology G{args.groups}xK{args.clients}, E{args.E} H{args.H}")

    # domain-skewed shards: each (group, client) samples its own domains
    rng = np.random.default_rng(0)
    toks, doms = make_lm_tokens(rng, cfg.vocab_size, 400_000, num_domains=8)
    G, K = args.groups, args.clients
    shard_tokens = []
    for g in range(G):
        row = []
        for k in range(K):
            dsel = (doms % (G * K)) == (g * K + k)   # crude domain skew
            row.append(toks[dsel])
        shard_tokens.append(row)

    state = sharded_init(params, G, K)
    step = jax.jit(make_sharded_round(bundle.loss, E=args.E, H=args.H,
                                      lr=args.lr))
    t0 = time.time()
    for t in range(args.rounds):
        b = np.zeros((args.E, args.H, 1, G, K, args.batch, args.seq), np.int32)
        y = np.zeros_like(b)
        for g in range(G):
            for k in range(K):
                sh = shard_tokens[g][k]
                st = rng.integers(0, len(sh) - args.seq - 1,
                                  (args.E, args.H, 1, args.batch))
                for e in range(args.E):
                    for h in range(args.H):
                        for i in range(args.batch):
                            s = st[e, h, 0, i]
                            b[e, h, 0, g, k, i] = sh[s:s + args.seq]
                            y[e, h, 0, g, k, i] = sh[s + 1:s + args.seq + 1]
        state, m = step(state, {"tokens": jnp.asarray(b), "targets": jnp.asarray(y)})
        if (t + 1) % 10 == 0 or t == 0:
            print(f"round {t+1:4d}  loss {float(m.loss.mean()):.4f}  "
                  f"||z||^2 {float(m.z_norm):.2e}  ||y||^2 {float(m.y_norm):.2e}  "
                  f"({time.time()-t0:.1f}s)")
        if (t + 1) % 25 == 0:
            save(args.ckpt, t + 1, state._asdict())
            print(f"  checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
