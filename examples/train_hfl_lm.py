"""End-to-end driver: hierarchical MTGC *language-model* training with the
production round (microbatched, shardable), domain-skewed token shards per
client, periodic eval + checkpointing.

Defaults train a ~7M-param glm4-family model for 50 global rounds x E2 x H2
(=200 local steps) on CPU in a few minutes; crank --layers/--d-model up to
the 100M regime on real hardware (the same script is what the dry-run
lowers at 26B scale on the production mesh).

The experiment is declared once through ``repro.api`` (backend="sharded")
and trained in checkpoint-sized segments of ``fit``: each segment is a
compiled donated horizon over a freshly packed set of per-client
domain-skewed shard blocks, and the state (params + corrections + rng)
carries across segments and into ``repro.checkpoint``.

    PYTHONPATH=src python examples/train_hfl_lm.py --rounds 50
"""
import argparse
import time

import jax
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.checkpoint import save
from repro.configs import get_arch
from repro.data.lm import make_lm_tokens
from repro.models.transformer import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--E", type=int, default=2)
    ap.add_argument("--H", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/mtgc_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25,
                    help="rounds per fit segment / checkpoint cadence")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced(
        num_layers=args.layers, d_model=args.d_model,
        d_ff=4 * args.d_model, vocab_size=2048, num_heads=8,
        d_head=args.d_model // 8)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced) params={n/1e6:.2f}M  "
          f"topology G{args.groups}xK{args.clients}, E{args.E} H{args.H}")

    # domain-skewed shards: each (group, client) samples its own domains
    rng = np.random.default_rng(0)
    toks, doms = make_lm_tokens(rng, cfg.vocab_size, 400_000, num_domains=8)
    G, K = args.groups, args.clients
    shard_tokens = [
        [toks[(doms % (G * K)) == (g * K + k)] for k in range(K)]  # crude skew
        for g in range(G)
    ]

    spec = ExperimentSpec(
        levels=(G, K),
        schedule=RoundSchedule(group_rounds=args.E, local_steps=args.H,
                               microbatches=1),
        algorithm="mtgc", lr=args.lr, backend="sharded", state_layout="tree")
    engine = build(spec, bundle.loss)
    state = engine.init(params)

    t0 = time.time()
    done = 0
    while done < args.rounds:
        seg = min(args.ckpt_every, args.rounds - done)
        # Fresh shard blocks per segment (the np rng advances), one upload.
        data = engine.pack_tokens(shard_tokens, batch_size=args.batch,
                                  seq_len=args.seq, rng=rng,
                                  key=jax.random.PRNGKey(done + 1))
        state, hz = fit(engine, data, seg, state=state)
        for t in range(seg):
            r = done + t + 1
            if r % 10 == 0 or r == 1:
                print(f"round {r:4d}  loss {float(hz.metrics.loss[t].mean()):.4f}  "
                      f"||z||^2 {float(hz.metrics.z_norm[t]):.2e}  "
                      f"||y||^2 {float(hz.metrics.y_norm[t]):.2e}  "
                      f"({time.time()-t0:.1f}s)")
        done += seg
        save(args.ckpt, done, state._asdict())
        print(f"  checkpoint @ round {done} -> {args.ckpt}")


if __name__ == "__main__":
    main()
