"""Async group rounds: a straggler edge that reports late, three ways.

Three groups of heterogeneous quadratic clients; group 2 is a straggler
that only manages E_g = 1 group round per global window while the others
run E = 4. Declaring ``group_rounds=(4, 4, 1)`` with an async staleness
policy lets the fast groups aggregate every window while the straggler
keeps working and reports every 4th window, 3 aggregations stale
(``core/staleness.py``). Everything lands through the PR 5 front door --
the spec below is the *entire* configuration surface:

    spec = ExperimentSpec(
        levels=(3, 8), algorithm="mtgc", lr=0.05,
        schedule=RoundSchedule(group_rounds=(4, 4, 1), local_steps=2),
        staleness="discount")          # or "naive" / "delay_compensated"

The script tracks the global model's distance to the exact joint optimum
under each stale-merge policy against the zero-staleness ``"sync"``
baseline (the straggler reports its single round every window). Naive
full-weight merging keeps dragging the global model back toward the
stale anchor; the discounted merge recovers most of the sync
trajectory, and first-order delay compensation recovers it almost
entirely. The MC version of this readout (R instances, claim gates) is
benchmarks/bench_async.py.

    PYTHONPATH=src python examples/async_rounds.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build

G, K, D, H, E = 3, 8, 6, 2, 4
GROUP_ROUNDS = (E,) * (G - 1) + (1,)     # group 2 is the straggler
WINDOWS = 24
POLICIES = ("sync", "naive", "discount", "delay_compensated")


def quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def make_problem(seed=0):
    """Heterogeneous per-client quadratics with equal group-level optima
    (the straggler's lag, not its data, is what the policies differ on),
    plus the exact joint optimum and the [E, H, G, K, D] batch block."""
    rng = np.random.default_rng(seed)
    curv = rng.normal(size=(G, K, D)) ** 2 * 0.5 + 0.3
    targ = rng.normal(size=(G, K, D))
    gmean = (curv * targ).sum(axis=1, keepdims=True) / curv.sum(
        axis=1, keepdims=True)
    targ = targ - gmean + rng.normal(size=(1, 1, D)) * 2.0
    a = np.sqrt(curv).astype(np.float32)
    b = (a * targ).astype(np.float32)
    w_opt = (curv * targ).sum(axis=(0, 1)) / curv.sum(axis=(0, 1))
    batches = {
        "a": jnp.asarray(np.broadcast_to(a, (E, H, G, K, D))),
        "b": jnp.asarray(np.broadcast_to(b, (E, H, G, K, D))),
    }
    return batches, w_opt.astype(np.float32)


def run_policy(policy, batches, w_opt):
    spec = ExperimentSpec(
        levels=(G, K), algorithm="mtgc", lr=0.05,
        schedule=RoundSchedule(group_rounds=GROUP_ROUNDS, local_steps=H),
        staleness=policy)
    engine = build(spec, quad_loss)
    state = engine.init({"w": jnp.zeros(D)})
    round_fn = jax.jit(engine.round_fn)
    dists = []
    for _ in range(WINDOWS):
        state, _ = round_fn(state, batches)
        # global_model reads a cadence-1 group's replica: under an async
        # plan only those groups are guaranteed the fresh global model.
        glob = np.asarray(engine.global_model(state)["w"])
        dists.append(float(np.linalg.norm(glob - w_opt)))
    return dists


def main():
    batches, w_opt = make_problem()
    dists = {p: run_policy(p, batches, w_opt) for p in POLICIES}

    print(f"straggler cadence: reports every {E} windows, "
          f"{E - 1} aggregations stale\n")
    print("window  " + "".join(f"{p:>18s}" for p in POLICIES))
    for t in range(3, WINDOWS, 4):
        print(f"  {t + 1:4d}  " + "".join(
            f"{dists[p][t]:18.4f}" for p in POLICIES))

    final = {p: dists[p][-1] for p in POLICIES}
    print("\ndistance to the joint optimum after "
          f"{WINDOWS} windows (sync = zero-staleness baseline):")
    for p in POLICIES:
        gap = final[p] - final["sync"]
        print(f"  {p:18s} {final[p]:.4f}  (gap to sync {gap:+.4f})")
    rec = (final["naive"] - final["discount"]) / max(
        final["naive"] - final["sync"], 1e-12)
    print(f"\ndiscounted merging recovers {100 * rec:.0f}% of the sync gap "
          "the naive stale merge leaves open")


if __name__ == "__main__":
    main()
