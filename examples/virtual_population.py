"""Virtual client population: 100k clients, 64 on the device at a time.

Declares ``population=25_000`` virtual clients per group over a 4-group x
16-client materialized hierarchy -- 100k clients total whose per-client
MTGC corrections live in a host-side ``PopulationStore`` (numpy, packed by
the same segment table as the device buffers), while the device only ever
holds the sampled cohort of 64. Each chunk of rounds the driver draws a
fresh cohort per group from the state rng, gathers its corrections into
the flat ``[G, K, N]`` buffers, runs the unchanged fused rounds, and
scatters the updated corrections back -- overlapped against the compiled
scan, so the round program is byte-identical to the materialized one.

Device correction memory is O(cohort), independent of the 100k population;
scale ``population`` 10x and only the host store grows.

    PYTHONPATH=src python examples/virtual_population.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp


def main():
    G, K, population, E, H, rounds = 4, 16, 25_000, 2, 5, 20
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=16000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.3, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    spec = ExperimentSpec(
        levels=(G, K),
        schedule=RoundSchedule(group_rounds=E, local_steps=H),
        algorithm="mtgc", lr=0.1,
        population=population, cohort_size=K, client_state="stateful")
    engine = build(spec, loss_fn)

    def eval_fn(prev, state):
        return {"acc": acc_of(engine.global_model(state))}

    data = engine.pack_arrays({"x": train.x, "y": train.y}, idx,
                              batch_size=32, shards=8,
                              rng=np.random.default_rng(1),
                              key=jax.random.PRNGKey(1))
    state = engine.init(init(jax.random.PRNGKey(0)))
    store = engine.init_population(state)
    report = store.size_report(K)
    print(f"population: {G} groups x {population} virtual clients "
          f"= {G * population} total, cohort {G}x{K}")
    print(f"host store:   {report['host_bytes'] / 1e6:8.1f} MB "
          f"({'/'.join(store.fields)} corrections, numpy)")
    print(f"device cohort:{report['device_bytes'] / 1e6:8.1f} MB "
          f"(constant in population)")

    # chunk=2: a fresh cohort is drawn (and its corrections swapped in)
    # every 2 rounds -- the chunk is the cohort-rotation granularity.
    state, hz = fit(engine, data, rounds, state=state, chunk=2,
                    population_store=store, eval_every=5, eval_fn=eval_fn)

    for i, r in enumerate(hz.eval_rounds):
        print(f"round {r:3d}  loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
              f"test acc {float(hz.evals['acc'][i]):.4f}")

    # Rows whose correction ever left zero == clients sampled so far.
    z = next(iter(hz.population.data["z"].values()))
    touched = int(np.sum(np.any(z != 0.0, axis=-1)))
    print(f"clients with live corrections: {touched} / {G * population} "
          f"(<= {rounds // 2} cohort draws x {G * K} slots)")


if __name__ == "__main__":
    main()
