"""Fault-tolerant HFL: injected faults break training, the defense heals it.

A small two-level MTGC run (CPU, seconds) under deterministic fault
injection (``core/faults.py``): every round, clients crash, groups time
out of the global exchange, and some uploads come back corrupted
(exploded deltas here -- try ``corrupt_kind="nan"`` too). The whole
configuration is the PR 8 front door -- faults and the defense are spec
fields, the self-healing horizon is a ``fit`` flag:

    spec = ExperimentSpec(
        levels=(G, K), algorithm="mtgc", lr=0.05,
        faults=FaultPlan(crash_rate=0.05, timeout_rate=0.05,
                         corrupt_rate=0.15, corrupt_kind="explode"),
        defense=DefensePlan(screen_norm=...))
    state, hz = fit(engine, data, T, params=..., guard=True)

Three runs on the *same fault realization* (the fault masks are drawn
from the state rng, which the defense never touches):

1. clean      -- zero faults: the convergence reference.
2. undefended -- corrupted uploads enter the group means and the z/y
                 corrections; a single exploded delta multiplies through
                 the hierarchy and the loss blows up.
3. defended   -- non-finite + norm screening drops the bad uploads
                 before any mean or correction, crashes fold into the
                 participation masks, and the guarded horizon snapshots
                 every chunk so a diverged chunk is rolled back and
                 retried with a fresh fault draw and a tighter screen.

    PYTHONPATH=src python examples/faults.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    DefensePlan,
    ExperimentSpec,
    FaultPlan,
    PackedBatches,
    RoundSchedule,
    build,
    fit,
)

G, K, D, E, H, T = 3, 8, 20, 2, 4, 12
FAULTS = FaultPlan(crash_rate=0.05, timeout_rate=0.05,
                   corrupt_rate=0.15, corrupt_kind="explode")
DEFENSE = DefensePlan(screen_norm=25.0)          # clean deltas are ~O(1)


def quad_loss(params, batch):
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def make_problem(seed=0):
    """Heterogeneous per-client quadratics sharing one optimum: b = a w*
    + noise, so the clean run converges to a small noise floor."""
    rng = np.random.default_rng(seed)
    # [G, K, shards, steps, D]: one shard, E*H local batches per round.
    a = (rng.normal(size=(G, K, 1, E * H, D)) * 0.3 + 1.0).astype(np.float32)
    w_true = rng.normal(size=(D,)).astype(np.float32)
    b = (a * w_true + 0.02 * rng.normal(size=a.shape)).astype(np.float32)
    return {"a": jnp.asarray(a), "b": jnp.asarray(b)}


def run(name, batches, faults=None, defense=None, guard=False):
    spec = ExperimentSpec(
        levels=(G, K), algorithm="mtgc", lr=0.05,
        schedule=RoundSchedule(group_rounds=E, local_steps=H),
        faults=faults, defense=defense)
    engine = build(spec, quad_loss)
    data = PackedBatches(batches, jax.random.PRNGKey(1), E, H, None)
    state, hz = fit(engine, data, T, params={"w": jnp.zeros(D)},
                    rng=jax.random.PRNGKey(7), chunk=4,
                    guard=guard or None, donate=False)
    loss = np.asarray(hz.metrics.loss, dtype=np.float64)
    per_round = [float(np.mean(l)) for l in loss]
    screened = getattr(hz.metrics, "screened", None)
    return {
        "name": name,
        "loss": per_round,
        "screened": float(np.sum(np.asarray(screened)))
        if screened is not None else 0.0,
        "guard": hz.guard,
        "model": np.asarray(engine.global_model(state)["w"]),
    }


def main():
    batches = make_problem()
    runs = [
        run("clean", batches),
        run("undefended", batches, faults=FAULTS),
        run("defended", batches, faults=FAULTS, defense=DEFENSE, guard=True),
    ]

    print(f"faults: {FAULTS}\ndefense: {DEFENSE}\n")
    print("round   " + "".join(f"{r['name']:>16s}" for r in runs))
    for t in range(0, T, 2):
        print(f"  {t + 1:3d}  " + "".join(
            f"{r['loss'][t]:16.3e}" for r in runs))

    print("\nfinal loss:")
    for r in runs:
        extra = f"  screened {r['screened']:.0f} contributions"
        if r["guard"] is not None:
            extra += (f", guard rollbacks={r['guard'].rollbacks} "
                      f"retries={r['guard'].retries}")
        print(f"  {r['name']:12s} {r['loss'][-1]:12.3e}{extra}")

    clean, bad, healed = runs
    assert not np.isfinite(bad["loss"][-1]) or \
        bad["loss"][-1] > 10 * clean["loss"][-1]
    assert np.isfinite(healed["model"]).all()
    assert healed["loss"][-1] < 0.1 * healed["loss"][0]
    print("\nundefended corruption blows the run up; the screened + "
          "guarded run tracks the clean trajectory.")


if __name__ == "__main__":
    main()
