"""60-second MTGC quickstart: hierarchical FL on synthetic non-i.i.d. data.

Builds a 4-group x 5-client hierarchy with Dirichlet(0.1) label skew at
both levels, then trains the paper's MLP with MTGC and with hierarchical
FedAvg on the identical batch stream -- watch the drift corrections win.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HFLConfig, global_model, hfl_init, make_global_round
from repro.data.partition import partition, sample_round_batches
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import accuracy, make_loss, mlp


def main():
    G, K, E, H, rounds = 4, 5, 4, 5, 15
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)

    for algo in ("mtgc", "hfedavg"):
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.1, algorithm=algo)
        state = hfl_init(init(jax.random.PRNGKey(0)), cfg)
        step = jax.jit(make_global_round(loss_fn, cfg))
        data_rng = np.random.default_rng(1)  # same stream for both algos
        print(f"\n== {algo} ==")
        for t in range(rounds):
            batches = sample_round_batches(train.x, train.y, idx, data_rng,
                                           E, H, batch_size=32)
            state, m = step(state, jax.tree.map(jnp.asarray, batches))
            if (t + 1) % 5 == 0:
                acc = accuracy(apply, global_model(state),
                               jnp.asarray(test.x), test.y)
                print(f"round {t+1:3d}  loss {float(np.mean(m.loss)):.4f}  "
                      f"test acc {acc:.4f}  ||z||^2 {float(m.z_norm):.3e}  "
                      f"||y||^2 {float(m.y_norm):.3e}")


if __name__ == "__main__":
    main()
