"""60-second MTGC quickstart: hierarchical FL on synthetic non-i.i.d. data.

Builds a 4-group x 5-client hierarchy with Dirichlet(0.1) label skew at
both levels, then trains the paper's MLP with MTGC and with hierarchical
FedAvg on the identical batch stream -- watch the drift corrections win.

Everything goes through the unified front door (``repro.api``): one
``ExperimentSpec`` declares the experiment, ``build`` adapts it onto the
round engine, ``engine.pack_arrays`` uploads the partitioned dataset once,
and ``fit`` runs all 15 rounds as a single donated scan dispatch with
batches gathered on device and test accuracy evaluated every 5 rounds
inside the compiled program (core/driver.py underneath).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, RoundSchedule, build, fit
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp


def main():
    G, K, E, H, rounds = 4, 5, 4, 5, 15
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    for algo in ("mtgc", "hfedavg"):
        spec = ExperimentSpec(
            levels=(G, K),
            schedule=RoundSchedule(group_rounds=E, local_steps=H),
            algorithm=algo, lr=0.1)
        engine = build(spec, loss_fn)

        def eval_fn(prev, state, engine=engine):
            # All clients hold the global model between full-participation
            # rounds.
            return {"acc": acc_of(engine.global_model(state))}

        # Same packing rng + selection key for both algos -> identical
        # batch streams, like the old host loop's shared data rng.
        data = engine.pack_arrays({"x": train.x, "y": train.y}, idx,
                                  batch_size=32, shards=8,
                                  rng=np.random.default_rng(1),
                                  key=jax.random.PRNGKey(1))
        state, hz = fit(engine, data, rounds,
                        params=init(jax.random.PRNGKey(0)),
                        eval_every=5, eval_fn=eval_fn)
        print(f"\n== {algo} ==")
        for i, r in enumerate(hz.eval_rounds):
            print(f"round {r:3d}  loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
                  f"test acc {float(hz.evals['acc'][i]):.4f}  "
                  f"||z||^2 {float(hz.metrics.z_norm[r-1]):.3e}  "
                  f"||y||^2 {float(hz.metrics.y_norm[r-1]):.3e}")


if __name__ == "__main__":
    main()
