"""60-second MTGC quickstart: hierarchical FL on synthetic non-i.i.d. data.

Builds a 4-group x 5-client hierarchy with Dirichlet(0.1) label skew at
both levels, then trains the paper's MLP with MTGC and with hierarchical
FedAvg on the identical batch stream -- watch the drift corrections win.

Training runs through the compiled horizon driver (core/driver.py): the
partitioned dataset is packed per client and uploaded once, all 15 rounds
execute as a single donated scan dispatch with batches gathered on device,
and test accuracy is evaluated every 5 rounds inside the compiled program.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HFLConfig,
    as_tree,
    hfl_init,
    make_global_round,
    pack_client_shards,
    run_rounds,
)
from repro.data.partition import partition
from repro.data.synthetic import make_classification, train_test_split
from repro.models.small import jit_accuracy, make_loss, mlp


def main():
    G, K, E, H, rounds = 4, 5, 4, 5, 15
    rng = np.random.default_rng(0)
    ds = make_classification(rng, num_samples=6000, num_classes=10, dim=32)
    train, test = train_test_split(ds, rng)
    idx = partition(train.y, G, K, mode="both_noniid", alpha=0.1, seed=0)

    init, apply = mlp(10, 32, hidden=64)
    loss_fn = make_loss(apply)
    acc_of = jit_accuracy(apply, jnp.asarray(test.x), jnp.asarray(test.y))

    def eval_fn(prev, state):
        # All clients hold the global model between full-participation rounds.
        params = as_tree(jax.tree.map(lambda v: v[0, 0], state.params))
        return {"acc": acc_of(params)}

    for algo in ("mtgc", "hfedavg"):
        cfg = HFLConfig(num_groups=G, clients_per_group=K, local_steps=H,
                        group_rounds=E, lr=0.1, algorithm=algo)
        state = hfl_init(init(jax.random.PRNGKey(0)), cfg)
        # Same packing rng + selection key for both algos -> identical
        # batch streams, like the old host loop's shared data rng.
        data = pack_client_shards({"x": train.x, "y": train.y}, idx,
                                  group_rounds=E, local_steps=H,
                                  batch_size=32, shards=8,
                                  rng=np.random.default_rng(1),
                                  key=jax.random.PRNGKey(1))
        state, data, hz = run_rounds(make_global_round(loss_fn, cfg), state,
                                     data, rounds, eval_every=5,
                                     eval_fn=eval_fn)
        print(f"\n== {algo} ==")
        for i, r in enumerate(hz.eval_rounds):
            print(f"round {r:3d}  loss {float(hz.metrics.loss[r-1].mean()):.4f}  "
                  f"test acc {float(hz.evals['acc'][i]):.4f}  "
                  f"||z||^2 {float(hz.metrics.z_norm[r-1]):.3e}  "
                  f"||y||^2 {float(hz.metrics.y_norm[r-1]):.3e}")


if __name__ == "__main__":
    main()
