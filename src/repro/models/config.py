"""Architecture configuration shared by the model zoo and the launcher."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    source: str = ""               # citation for the config

    # attention features
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_base: float = 10000.0
    sliding_window: int = 0        # 0 = global everywhere
    local_global_ratio: int = 0    # gemma3: N local layers per global layer
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_d_inner: int = 0

    # enc-dec (audio) / vlm stubs
    encoder_layers: int = 0
    encoder_frames: int = 0        # whisper: 1500 post-conv frames
    vision_tokens: int = 0         # internvl2: patch embeddings per image
    vision_dim: int = 0

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # execution knobs
    attn_block: int = 512          # blocked-attention KV block
    rwkv_chunk: int = 64
    remat: bool = True

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 512 so embedding/unembedding
        shard cleanly over a 16-way model axis (tokens stay < vocab_size)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 524k contexts without quadratic prefill /
        unbounded per-layer global attention? (DESIGN.md skip rule.)"""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        if self.sliding_window > 0:
            return True            # mixtral SWA
        if self.local_global_ratio > 0:
            return True            # gemma3 local:global (decode is linear)
        return False

    def reduced(self, **over) -> "ArchConfig":
        """2-layer, narrow variant of the same family for CPU smoke tests."""
        small = dict(
            num_layers=2,
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_head=32,
            d_ff=256,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=self.ssm_state,
            ssm_d_inner=128 if self.ssm_d_inner else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_frames=16 if self.encoder_frames else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            vision_dim=32 if self.vision_dim else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            param_dtype="float32",
            compute_dtype="float32",
            attn_block=16,
            rwkv_chunk=4,
            remat=False,
        )
        small.update(over)
        return dataclasses.replace(self, **small)
