"""RWKV-6 "Finch" time-mixing (attention-free, data-dependent decay).

Recurrence per head (state S in R^{Dk x Dv}):

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        with  w_t = exp(-exp(x_w(t)))

Data dependence: w_t, and the token-shift mixing coefficients, are functions
of the input (simplified LoRA-free projection of the token-shifted input --
the structural property the paper's systems contribution relies on, i.e.
per-token per-channel decay, is preserved).

Two execution paths with identical semantics:
* ``rwkv6_chunked``: chunked parallel form -- within-chunk work is batched
  matmuls (MXU-friendly; what the Pallas kernel implements per-block),
  cross-chunk state is a short lax.scan. Used for training/prefill.
* ``rwkv6_step``: O(1) single-token state update. Used for decode
  (this is why rwkv6 runs the 524k-context shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, init_rms, linear, rms_norm


def init_rwkv6(rng, d_model, n_heads, dtype):
    d_head = d_model // n_heads
    ks = jax.random.split(rng, 8)
    p = {
        "wr": init_linear(ks[0], d_model, d_model, dtype),
        "wk": init_linear(ks[1], d_model, d_model, dtype),
        "wv": init_linear(ks[2], d_model, d_model, dtype),
        "wg": init_linear(ks[3], d_model, d_model, dtype),
        "wd": init_linear(ks[4], d_model, d_model, dtype),  # decay projection
        "wo": init_linear(ks[5], d_model, d_model, dtype),
        "u": (0.1 * jax.random.normal(ks[6], (n_heads, d_head))).astype(jnp.float32),
        "decay_base": jnp.full((d_model,), -1.0, jnp.float32),
        "mix": (0.5 * jnp.ones((4, d_model))).astype(dtype),  # token-shift mix r/k/v/d
        "ln_out": init_rms(d_model, dtype),
    }
    return p


def _proj(p, x, x_prev, n_heads):
    """Token-shifted projections -> r, k, v, log-decay, gate."""
    B, T, D = x.shape
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # shifted input

    def mix(i):
        m = p["mix"][i]
        return x * m + xs * (1 - m)

    r = linear(p["wr"], mix(0))
    k = linear(p["wk"], mix(1))
    v = linear(p["wv"], mix(2))
    d = linear(p["wd"], mix(3)).astype(jnp.float32)
    g = jax.nn.silu(linear(p["wg"], x))
    # log w_t = -exp(base + d)  in (-inf, 0): per-token per-channel decay
    logw = -jnp.exp(p["decay_base"] + jnp.tanh(d))            # [B,T,D] f32
    H, Dh = n_heads, D // n_heads
    shp = (B, T, H, Dh)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), logw.reshape(shp), g)


def rwkv6_chunked(p, x, x_prev, state, *, n_heads, chunk=64):
    """x: [B,T,D]; state: [B,H,Dk,Dv] f32. Returns (out, last_x, new_state)."""
    return _rwkv6_chunked(p, x, x_prev, state, n_heads=n_heads, chunk=chunk)


def _rwkv6_chunked(p, x, x_prev, state, *, n_heads, chunk):
    scope = jax.named_scope("rwkv")
    scope.__enter__()
    B, T, D = x.shape
    H = n_heads
    Dh = D // H
    r, k, v, logw, g = _proj(p, x, x_prev, H)
    # Pad T to a chunk multiple: pad tokens carry k=0 (no state update) and
    # logw=0 (decay 1), so the carried-out state is exact; their outputs are
    # sliced off below.
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v, logw = (jnp.pad(a, zp) for a in (r, k, v, logw))
    nc = (T + pad) // chunk
    C = chunk

    def resh(a):  # [B,Tp,H,Dh] -> [nc, B, H, C, Dh]
        return a.reshape(B, nc, C, H, Dh).transpose(1, 0, 3, 2, 4)

    r_, k_, v_, lw_ = map(resh, (r, k, v, logw))
    u = p["u"].astype(jnp.float32)                              # [H, Dh]

    def chunk_fn(S, inp):
        rc, kc, vc, lwc = inp                                   # [B,H,C,Dh]
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        cum = jnp.cumsum(lwc, axis=2)                           # inclusive logcum
        cum_ex = cum - lwc                                       # exclusive
        # Contribution of the carried-in state: A = r_t * exp(cum_ex)
        a = rc * jnp.exp(cum_ex)
        o_state = jnp.einsum("bhcd,bhde->bhce", a, S)
        # Intra-chunk: pairwise decays exp(cum_ex[t] - cum[i]) for i < t,
        # plus the diag(u) bonus on i == t.
        dmat = cum_ex[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,H,C,C,Dh]
        tri = jnp.tril(jnp.ones((C, C), bool), -1)[None, None, :, :, None]
        w_pair = jnp.where(tri, jnp.exp(dmat), 0.0)
        att = jnp.einsum("bhcd,bhid,bhcid->bhci", rc, kc, w_pair)
        o_intra = jnp.einsum("bhci,bhie->bhce", att, vc)
        # diagonal (bonus) term: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bhcd,hd,bhcd->bhc", rc, u, kc)
        o_diag = bonus[..., None] * vc
        o = o_state + o_intra + o_diag
        # State update: S' = diag(prod w) S + sum_i exp(cum[C-1]-cum[i]) k_i v_i^T
        wtot = jnp.exp(cum[:, :, -1, :])                         # [B,H,Dh]
        kdec = kc * jnp.exp(cum[:, :, -1:, :] - cum)
        S = wtot[..., None] * S + jnp.einsum("bhid,bhie->bhde", kdec, vc)
        return S, o

    state, o = jax.lax.scan(chunk_fn, state.astype(jnp.float32), (r_, k_, v_, lw_))
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, T + pad, H, Dh)[:, :T]  # [B,T,H,Dh]
    o = rms_norm(o.reshape(B, T, D).astype(x.dtype), p["ln_out"])
    out = linear(p["wo"], o * g)
    scope.__exit__(None, None, None)
    return out, x[:, -1], state


def rwkv6_step(p, x_t, x_prev, state, *, n_heads):
    """Single-token decode. x_t: [B, D]; state: [B,H,Dk,Dv] f32."""
    B, D = x_t.shape
    r, k, v, logw, g = _proj(p, x_t[:, None], x_prev, n_heads)
    r, k, v, logw = (a[:, 0].astype(jnp.float32) for a in (r, k, v, logw))
    g = g[:, 0]
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    o = jnp.einsum("bhd,bhde->bhe", r, state + u[None, :, :, None] * kv)
    state = jnp.exp(logw)[..., None] * state + kv
    H, Dh = n_heads, D // n_heads
    o = rms_norm(o.reshape(B, D).astype(x_t.dtype), p["ln_out"])
    return linear(p["wo"], o * g), x_t, state
