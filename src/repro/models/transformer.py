"""Unified production model zoo: one scanned-layer decoder substrate with
pluggable mixers, covering all six assigned architecture families.

Per family:
  dense   -- pre-RMSNorm GQA attention + SwiGLU (glm4 / qwen2.5 / qwen3 /
             gemma3; per-layer sliding windows drive gemma3's 5:1 pattern)
  moe     -- attention + top-k MoE FFN (mixtral, granite)
  ssm     -- RWKV6 time-mix + RWKV channel-mix (attention-free)
  hybrid  -- parallel attention + Mamba-SSM heads, fused (hymba)
  audio   -- whisper enc-dec: bidirectional encoder over (stubbed) conv
             frames + causal decoder with cross-attention
  vlm     -- internvl2: projector over (stubbed) ViT patch embeddings
             prepended to the token stream, dense decoder

Layers are stacked on axis 0 and executed with ``lax.scan`` (compact HLO for
40+ layer configs) with optional per-layer remat. Every family provides:
  init(rng)                      -> params
  loss(params, batch)            -> scalar      (train path)
  init_cache(batch_size, seq)    -> cache pytree
  prefill(params, batch, cache)  -> (logits_last, cache)
  decode_step(params, batch, cache) -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rwkv6 as RWKV
from repro.models import ssm as SSM
from repro.models.config import ArchConfig

PyTree = Any


class ModelBundle(NamedTuple):
    cfg: ArchConfig
    init: Callable
    loss: Callable                # (params, batch) -> scalar
    forward: Callable             # (params, batch) -> logits (train shapes)
    init_cache: Callable          # (batch, seq) -> cache
    prefill: Callable             # (params, batch, cache) -> (logits, cache)
    decode_step: Callable         # (params, batch, cache) -> (logits, cache)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ layers


def _layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer sliding window sizes ([L] int32); 0 = global attention."""
    Lh = cfg.num_layers
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        w = np.full(Lh, cfg.sliding_window or 1024, np.int32)
        w[r::r + 1] = 0  # every (r+1)-th layer is global
        return w
    return np.full(Lh, cfg.sliding_window, np.int32)


def _init_decoder_layer(cfg: ArchConfig, rng) -> dict:
    ks = jax.random.split(rng, 8)
    dt = _dtype(cfg)
    d = cfg.d_model
    p = {"ln1": L.init_rms(d, dt), "ln2": L.init_rms(d, dt)}
    if cfg.arch_type == "ssm":
        p["rwkv"] = RWKV.init_rwkv6(ks[0], d, cfg.num_heads, dt)
        p["cmix"] = {
            "wr": L.init_linear(ks[1], d, d, dt),
            "wk": L.init_linear(ks[2], d, cfg.d_ff, dt),
            "wv": L.init_linear(ks[3], cfg.d_ff, d, dt),
            "mix": (0.5 * jnp.ones((2, d))).astype(dt),
        }
        return p
    p["attn"] = L.init_attention(
        ks[0], d, cfg.num_heads, cfg.num_kv_heads, cfg.d_head, dt,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
    )
    if cfg.arch_type == "hybrid":
        p["ssm"] = SSM.init_ssm(ks[1], d, cfg.ssm_d_inner or d, cfg.ssm_state, dt)
    if cfg.arch_type == "moe":
        p["moe"] = MOE.init_moe(ks[2], d, cfg.d_ff, cfg.num_experts, dt)
    else:
        p["mlp"] = L.init_swiglu(ks[2], d, cfg.d_ff, dt)
    if cfg.arch_type == "audio":
        p["ln_x"] = L.init_rms(d, dt)
        p["xattn"] = L.init_attention(
            ks[3], d, cfg.num_heads, cfg.num_kv_heads, cfg.d_head, dt
        )
    return p


def _rwkv_cmix(p, x, x_prev):
    """RWKV channel mixing with token shift. x: [B,T,D]."""
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mr, mk = p["mix"][0], p["mix"][1]
    xr = x * mr + xs * (1 - mr)
    xk = x * mk + xs * (1 - mk)
    r = jax.nn.sigmoid(L.linear(p["wr"], xr))
    k = jnp.square(jax.nn.relu(L.linear(p["wk"], xk)))
    return r * L.linear(p["wv"], k)


def _apply_decoder_layer(
    cfg: ArchConfig, p: dict, x, *, window, memory=None,
    cache=None, cache_index=None, mode: str = "train",
):
    """One decoder layer. Returns (x, new_cache).

    cache (per-layer slice) keys by family:
      attention: k, v           [B, S, Kv, Dh]
      ssm:       state, x_prev, ffn_prev
      hybrid:    k, v, sstate
      audio:     k, v (self-attention only; memory K/V recomputed)
    """
    B, T, D = x.shape
    new_cache = {}

    if cfg.arch_type == "ssm":
        h = L.rms_norm(x, p["ln1"])
        if mode == "decode":
            o, xp, st = RWKV.rwkv6_step(
                p["rwkv"], h[:, 0], cache["x_prev"], cache["state"],
                n_heads=cfg.num_heads,
            )
            o = o[:, None]
            new_cache.update(state=st, x_prev=xp)
        else:
            st0 = jnp.zeros(
                (B, cfg.num_heads, D // cfg.num_heads, D // cfg.num_heads), jnp.float32
            ) if cache is None else cache["state"]
            xp0 = jnp.zeros((B, D), x.dtype) if cache is None else cache["x_prev"]
            o, xp, st = RWKV.rwkv6_chunked(
                p["rwkv"], h, xp0, st0, n_heads=cfg.num_heads, chunk=cfg.rwkv_chunk
            )
            new_cache.update(state=st, x_prev=xp)
        x = x + o
        h = L.rms_norm(x, p["ln2"])
        fp = (
            cache["ffn_prev"]
            if (cache is not None and mode == "decode")
            else jnp.zeros((B, D), x.dtype)
        )
        x = x + _rwkv_cmix(p["cmix"], h, fp)
        new_cache["ffn_prev"] = h[:, -1]
        return x, new_cache, 0.0

    # --- attention families ------------------------------------------
    h = L.rms_norm(x, p["ln1"])
    kv_cache = None
    if cache is not None and "k" in cache:
        kv_cache = {"k": cache["k"], "v": cache["v"]}
    attn_out, kvc = L.attention_block(
        p["attn"], h,
        n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, d_head=cfg.d_head,
        rope_base=cfg.rope_base, causal=True, window=window,
        qk_norm=cfg.qk_norm, kv_cache=kv_cache, cache_index=cache_index,
        attn_impl="blocked" if (T > 1024 or kv_cache is not None) else "naive",
        block=cfg.attn_block,
    )
    if kvc is not None:
        new_cache.update(kvc)

    if cfg.arch_type == "hybrid":
        if mode == "decode":
            sout, st = SSM.ssm_step(p["ssm"], h[:, 0], cache["sstate"])
            sout = sout[:, None]
        else:
            di = cfg.ssm_d_inner or D
            st0 = (
                jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
                if cache is None else cache["sstate"]
            )
            sout, st = SSM.ssm_parallel(p["ssm"], h, st0)
        new_cache["sstate"] = st
        # Hymba: parallel heads, mean-fused.
        attn_out = 0.5 * (attn_out + sout.astype(attn_out.dtype))

    x = x + attn_out

    if cfg.arch_type == "audio" and memory is not None:
        h = L.rms_norm(x, p["ln_x"])
        xo, _ = L.attention_block(
            p["xattn"], h,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, d_head=cfg.d_head,
            rope_base=cfg.rope_base, causal=False, window=0,
            kv_memory=memory, attn_impl="naive",
        )
        x = x + xo

    h = L.rms_norm(x, p["ln2"])
    aux = 0.0
    if cfg.arch_type == "moe":
        # serve paths route dropless (prefill/decode consistency) whenever
        # the token count keeps the [E, S, D] buffers sane.
        S_tok = h.shape[0] * h.shape[1]
        mo, aux = MOE.moe_block(
            p["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
            dropless=(mode != "train" and S_tok <= 4096),
            # training keeps the dispatch buffers small (grad accumulation
            # multiplies live copies); serving prefers fewer, larger chunks
            chunk_tokens=4096 if mode == "train" else 16384,
            sequential=(mode == "train"),
        )
        x = x + mo
    else:
        x = x + L.swiglu(p["mlp"], h)
    return x, new_cache, aux


# ------------------------------------------------------------------ encoder
# (whisper: bidirectional attention over stubbed conv-frontend frames)


def _init_encoder_layer(cfg: ArchConfig, rng):
    ks = jax.random.split(rng, 2)
    dt = _dtype(cfg)
    return {
        "ln1": L.init_rms(cfg.d_model, dt),
        "ln2": L.init_rms(cfg.d_model, dt),
        "attn": L.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_head, dt
        ),
        "mlp": L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt),
    }


def _encode(cfg: ArchConfig, enc_params, pos_emb, frames):
    x = frames.astype(_dtype(cfg)) + pos_emb[None, : frames.shape[1]]

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"])
        o, _ = L.attention_block(
            lp["attn"], h,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, d_head=cfg.d_head,
            rope_base=cfg.rope_base, causal=False, window=0, attn_impl="naive",
        )
        x = x + o
        x = x + L.swiglu(lp["mlp"], L.rms_norm(x, lp["ln2"]))
        return x, None

    x, _ = jax.lax.scan(body, x, enc_params)
    return x


# ------------------------------------------------------------------ model


def _stack_init(fn, rng, n):
    return jax.vmap(fn)(jax.random.split(rng, n))


def chunked_xent(logits_fn, hidden, targets, chunk=512):
    """CE over the sequence in chunks: avoids materializing [T, vocab]."""
    import math

    B, T, D = hidden.shape
    c = math.gcd(T, chunk)
    if c < 64:
        c = T
    nc = T // c
    h = hidden.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    t = targets.reshape(B, nc, c).transpose(1, 0, 2)

    def body(tot, inp):
        hh, tt = inp
        lg = logits_fn(hh).astype(jnp.float32)
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, tt[..., None], axis=-1).sum()
        return tot + nll, None

    with jax.named_scope("xent"):
        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, t))
    return tot / (B * T)


def build_model(cfg: ArchConfig) -> ModelBundle:
    dt = _dtype(cfg)
    windows = jnp.asarray(_layer_windows(cfg))

    def init(rng):
        ks = jax.random.split(rng, 6)
        p = {
            "embed": L.init_embedding(ks[0], cfg.vocab_padded, cfg.d_model, dt),
            "ln_f": L.init_rms(cfg.d_model, dt),
            "layers": _stack_init(
                partial(_init_decoder_layer, cfg), ks[1], cfg.num_layers
            ),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = L.init_linear(ks[2], cfg.d_model, cfg.vocab_padded, dt)
        if cfg.arch_type == "audio":
            p["encoder"] = _stack_init(
                partial(_init_encoder_layer, cfg), ks[3], cfg.encoder_layers
            )
            p["enc_pos"] = (
                0.02 * jax.random.normal(ks[4], (cfg.encoder_frames, cfg.d_model))
            ).astype(dt)
        if cfg.arch_type == "vlm":
            p["projector"] = {
                "w1": L.init_linear(ks[3], cfg.vision_dim, cfg.d_model, dt),
                "w2": L.init_linear(ks[4], cfg.d_model, cfg.d_model, dt),
            }
        return p

    def _logits(p, hidden):
        if cfg.tie_embeddings:
            return L.unembed(p["embed"], hidden)
        return L.linear(p["unembed"], hidden)

    def _embed_inputs(p, batch):
        """Token (+ modality stub) embeddings: [B, T, D]."""
        x = L.embed(p["embed"], batch["tokens"])
        if cfg.arch_type == "vlm" and "patches" in batch:
            v = batch["patches"].astype(dt)
            v = L.linear(p["projector"]["w2"], jax.nn.gelu(L.linear(p["projector"]["w1"], v)))
            x = jnp.concatenate([v, x], axis=1)
        return x.astype(dt)

    def _memory(p, batch):
        if cfg.arch_type != "audio":
            return None
        if "memory" in batch:          # serving: encoder ran once at admission
            return batch["memory"].astype(dt)
        if "frames" in batch:
            return _encode(cfg, p["encoder"], p["enc_pos"], batch["frames"])
        return None

    def _run_layers(p, x, memory, cache=None, cache_index=None, mode="train"):
        def body(x, inp):
            if cache is None:
                lp, w = inp
                cl = None
            else:
                lp, w, cl = inp
            x, nc, aux = _apply_decoder_layer(
                cfg, lp, x, window=w, memory=memory,
                cache=cl, cache_index=cache_index, mode=mode,
            )
            return x, (nc, aux)

        fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        xs = (p["layers"], windows) if cache is None else (p["layers"], windows, cache)
        x, (new_cache, aux) = jax.lax.scan(fn, x, xs)
        return x, new_cache, jnp.sum(aux) if cfg.arch_type == "moe" else 0.0

    # ---------------- train -----------------
    def forward(p, batch):
        x = _embed_inputs(p, batch)
        mem = _memory(p, batch)
        x, _, _ = _run_layers(p, x, mem, mode="eval")
        x = L.rms_norm(x, p["ln_f"])
        return _logits(p, x)

    def loss(p, batch):
        x = _embed_inputs(p, batch)
        mem = _memory(p, batch)
        x, _, aux = _run_layers(p, x, mem, mode="train")
        x = L.rms_norm(x, p["ln_f"])
        tgt = batch["targets"]
        if cfg.arch_type == "vlm" and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]  # loss over text positions
        lfn = (lambda h: L.unembed(p["embed"], h)) if cfg.tie_embeddings else (
            lambda h: L.linear(p["unembed"], h)
        )
        ce = chunked_xent(lfn, x, tgt)
        return ce + 0.01 * aux

    # ---------------- serve ------------------
    def init_cache(batch_size: int, seq: int):
        B, S, Lh = batch_size, seq, cfg.num_layers
        c = {}
        if cfg.arch_type != "ssm":
            c["k"] = jnp.zeros((Lh, B, S, cfg.num_kv_heads, cfg.d_head), dt)
            c["v"] = jnp.zeros((Lh, B, S, cfg.num_kv_heads, cfg.d_head), dt)
        if cfg.arch_type == "ssm":
            dh = cfg.d_model // cfg.num_heads
            c["state"] = jnp.zeros((Lh, B, cfg.num_heads, dh, dh), jnp.float32)
            c["x_prev"] = jnp.zeros((Lh, B, cfg.d_model), dt)
            c["ffn_prev"] = jnp.zeros((Lh, B, cfg.d_model), dt)
        if cfg.arch_type == "hybrid":
            di = cfg.ssm_d_inner or cfg.d_model
            c["sstate"] = jnp.zeros((Lh, B, di, cfg.ssm_state), jnp.float32)
        return c

    def prefill(p, batch, cache):
        """Forward the prompt, writing the cache; returns last-pos logits."""
        x = _embed_inputs(p, batch)
        mem = _memory(p, batch)
        x, cache, _ = _run_layers(
            p, x, mem, cache=cache, cache_index=jnp.zeros((), jnp.int32),
            mode="prefill",
        )
        x = L.rms_norm(x[:, -1:], p["ln_f"])
        return _logits(p, x)[:, 0], cache

    def decode_step(p, batch, cache):
        """One-token decode. batch: {'token': [B,1], 'index': scalar}."""
        x = _embed_inputs(p, {"tokens": batch["token"]})
        mem = _memory(p, batch)
        x, cache, _ = _run_layers(
            p, x, mem, cache=cache, cache_index=batch["index"], mode="decode"
        )
        x = L.rms_norm(x, p["ln_f"])
        return _logits(p, x)[:, 0], cache

    return ModelBundle(
        cfg=cfg, init=init, loss=loss, forward=forward,
        init_cache=init_cache, prefill=prefill, decode_step=decode_step,
    )
