"""Mixture-of-Experts block (Mixtral-style top-k routing, Granite top-8).

Dispatch uses the capacity-based one-hot einsum formulation (Mesh-TF /
GShard style): it is dense linear algebra, so it (a) runs on the MXU, (b)
shards cleanly under GSPMD with experts on a mesh axis (the all-to-all
emerges from the dispatch einsums), and (c) has well-defined HLO FLOPs for
the roofline. Router load-balance aux loss follows Switch/Mixtral.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear


def init_moe(rng, d_model, d_ff, num_experts, dtype):
    ks = jax.random.split(rng, 4)

    def ew(key, n_in, n_out):
        w = (1.0 / n_in) ** 0.5 * jax.random.normal(key, (num_experts, n_in, n_out))
        return w.astype(dtype)

    return {
        "router": init_linear(ks[0], d_model, num_experts, dtype),
        "wi": ew(ks[1], d_model, d_ff),
        "wg": ew(ks[2], d_model, d_ff),
        "wo": ew(ks[3], d_ff, d_model),
    }


def moe_block(p, x, *, num_experts, top_k, capacity_factor=1.25, dropless=False,
              chunk_tokens=4096, sequential=True):
    """x: [B, T, D] -> (out [B, T, D], aux_loss scalar).

    ``dropless=True`` sets capacity = S (no token ever dropped) -- used by
    the serve paths so prefill/decode are bit-consistent; it is only safe
    for modest token counts (capacity buffers are [E, S, D]), so callers
    gate it on S. Training keeps GShard-style capacity dropping.
    """
    B, T, D = x.shape
    S = B * T
    scope = jax.named_scope("moe")
    scope.__enter__()
    # Capacity-based dispatch is O(S * E * C) with C ~ S: quadratic in
    # tokens. For long prefills, dispatch in chunks of <=16k tokens
    # (capacity budgeted per chunk -- standard blocked routing), which keeps
    # the dispatch linear in S and the [E, C, D] buffers bounded.
    chunk = S
    for cand in (chunk_tokens, chunk_tokens // 2, chunk_tokens // 4):
        if S > chunk_tokens and S % cand == 0:
            chunk = cand
            break
    if chunk < S:
        # training: sequential (lax.map) so only ONE chunk's [E, C, D]
        # dispatch buffers are live at a time (grad accumulation multiplies
        # live copies); serving: vmap (batched dispatch, fewer reshards).
        xc = x.reshape(S // chunk, 1, chunk, D)
        fn = lambda xx: moe_block(p, xx, num_experts=num_experts, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  dropless=dropless, chunk_tokens=chunk_tokens,
                                  sequential=sequential)
        outs, auxes = jax.lax.map(fn, xc) if sequential else jax.vmap(fn)(xc)
        scope.__exit__(None, None, None)
        return outs.reshape(B, T, D), jnp.mean(auxes)
    xf = x.reshape(S, D)

    logits = linear(p["router"], xf).astype(jnp.float32)        # [S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # [S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if dropless:
        capacity = S
    else:
        capacity = min(S, max(int(capacity_factor * S * top_k / num_experts), 4))

    # Position of each (token, choice) inside its expert's buffer.
    onehot = jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.int32)  # [S, k, E]
    flat = onehot.reshape(S * top_k, num_experts)
    pos = jnp.cumsum(flat, axis=0) - 1                                # [S*k, E]
    pos = (pos * flat).sum(-1).reshape(S, top_k)                      # [S, k]
    keep = pos < capacity

    # dispatch[S, k, E, C] -> combine with gates
    disp = (
        jax.nn.one_hot(gate_idx, num_experts, dtype=xf.dtype)[..., None]
        * jax.nn.one_hot(pos, capacity, dtype=xf.dtype)[..., None, :]
        * keep[..., None, None].astype(xf.dtype)
    )                                                                  # [S,k,E,C]
    disp_tok = disp.sum(1)                                             # [S, E, C]
    expert_in = jnp.einsum("sec,sd->ecd", disp_tok, xf)                # [E, C, D]

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # [E, C, D]

    combine = (disp * gate_vals[..., None, None].astype(xf.dtype)).sum(1)  # [S,E,C]
    out = jnp.einsum("sec,ecd->sd", combine, expert_out)

    # Load-balance auxiliary loss (Switch eq. 4).
    frac_tokens = jax.nn.one_hot(gate_idx[:, 0], num_experts).mean(0)
    frac_probs = probs.mean(0)
    aux = num_experts * jnp.sum(frac_tokens * frac_probs)

    scope.__exit__(None, None, None)
    return out.reshape(B, T, D), aux
