"""Shared transformer building blocks for the production architecture zoo.

Pure-functional: ``init_*`` builds param pytrees, ``apply`` functions are
shape-polymorphic. Conventions:

* params are stored in ``param_dtype`` (bf16 by default for the big archs);
  norms/softmax accumulate in f32.
* attention supports GQA, RoPE, optional QKV bias (qwen2.5), per-head
  qk-RMSNorm (qwen3), and sliding windows (mixtral / gemma3 local layers /
  hymba). ``window <= 0`` means global.
* ``blocked_attention`` is the fused-style jnp path (online softmax over KV
  blocks) used for long sequences; ``kernels/flash_attention`` is the Pallas
  TPU version of the same contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- basics


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms(d, dtype):
    return jnp.zeros((d,), dtype)  # stored as (scale - 1), gemma-style


def _norm_init(rng, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return ((1.0 / fan_in) ** 0.5 * jax.random.normal(rng, shape)).astype(dtype)


def init_linear(rng, n_in, n_out, dtype, bias=False):
    p = {"w": _norm_init(rng, (n_in, n_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------- RoPE


def rope_freqs(d_head: int, base: float):
    return 1.0 / (base ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, base: float):
    """x: [..., T, H, Dh]; positions: [..., T]."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, base)                       # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, Dh/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


def init_attention(rng, d_model, n_heads, n_kv, d_head, dtype, qkv_bias=False, qk_norm=False):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(ks[0], d_model, n_heads * d_head, dtype, qkv_bias),
        "wk": init_linear(ks[1], d_model, n_kv * d_head, dtype, qkv_bias),
        "wv": init_linear(ks[2], d_model, n_kv * d_head, dtype, qkv_bias),
        "wo": init_linear(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms(d_head, dtype)
        p["k_norm"] = init_rms(d_head, dtype)
    return p


def _expand_kv(k, n_heads):
    """[B, T, Kv, Dh] -> [B, T, H, Dh] by repeating each kv head."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Reference attention. q: [B, Tq, H, Dh], k/v: [B, Tk, H, Dh].

    ``q_offset``: absolute position of q[0] (decode: Tk-1). ``window``>0
    masks keys older than ``window`` positions (sliding window).
    """
    B, Tq, H, Dh = q.shape
    Tk = k.shape[1]
    scale = Dh ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Tq) + q_offset
    kpos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    # Sliding window (no-op when window <= 0). ``window`` may be a traced
    # per-layer scalar (gemma3's 5:1 local:global pattern inside lax.scan).
    window = jnp.asarray(window)
    lo = qpos[:, None] - jnp.where(window > 0, window, Tk + Tq)
    mask &= kpos[None, :] > lo
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blocked_attention(q, k, v, *, causal=True, window=0, q_offset=0, block=512):
    """Online-softmax attention: scans KV blocks, O(Tq*block) live memory.

    Same contract as ``naive_attention``; used for long sequences and as the
    jnp twin of the Pallas flash kernel.
    """
    B, Tq, H, Dh = q.shape
    Tk = k.shape[1]
    nb = -(-Tk // block)
    pad = nb * block - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block, H, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, H, Dh).transpose(1, 0, 2, 3, 4)
    scale = Dh ** -0.5
    qpos = jnp.arange(Tq) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, bidx = inp
        kpos = bidx * block + jnp.arange(block)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kblk).astype(jnp.float32) * scale
        msk = kpos[None, :] < Tk
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        w = jnp.asarray(window)
        lo = qpos[:, None] - jnp.where(w > 0, w, Tk + Tq)
        msk &= kpos[None, :] > lo
        logits = jnp.where(msk[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    a0 = jnp.zeros((B, H, Tq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def gqa_decode_attention(q, k, v, *, window=0, q_offset=0):
    """One-token decode attention WITHOUT expanding GQA KV heads.

    q: [B, 1, H, Dh]; k/v: [B, S, Kv, Dh]. The grouped einsum keeps the
    cache at Kv heads (expanding to H would materialize group x the cache --
    the dominant decode temp at 32k/500k contexts). Softmax runs over the
    (possibly sequence-sharded) S axis; under GSPMD the partial max/sum
    reductions lower to tiny all-reduces (flash-decode combine).
    """
    B, Tq, H, Dh = q.shape
    S, Kv = k.shape[1], k.shape[2]
    grp = H // Kv
    qg = q.reshape(B, Tq, Kv, grp, Dh)
    # bf16 inputs, f32 accumulate: casting k/v would materialize an f32
    # copy of the whole cache per layer (dominant decode HBM traffic).
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * (Dh ** -0.5)
    kpos = jnp.arange(S)
    mask = kpos <= q_offset
    # window may be a traced per-layer scalar (gemma3's 5:1 pattern in scan)
    w = jnp.asarray(window)
    mask &= kpos > jnp.where(w > 0, q_offset - w, -1)
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Tq, H, Dh).astype(q.dtype)


def attention_block(
    p, x, *, n_heads, n_kv, d_head, rope_base, causal=True, window=0,
    qk_norm=False, positions=None, kv_cache=None, cache_index=None,
    attn_impl="blocked", block=512, kv_memory=None,
):
    """Full attention sub-block: proj -> rope -> (cache) -> attn -> out proj.

    kv_cache: optional dict(k=[B, S, Kv, Dh], v=...) for decode; the new
    token is written at ``cache_index`` and attention runs over the cache.
    kv_memory: optional [B, S_mem, d_model] for cross-attention (whisper) --
    keys/values come from memory and no cache/rope is used on them.
    Returns (out, new_cache).
    """
    B, T, _ = x.shape
    scope = jax.named_scope("attn")
    scope.__enter__()
    q = linear(p["wq"], x).reshape(B, T, n_heads, d_head)
    src = kv_memory if kv_memory is not None else x
    k = linear(p["wk"], src).reshape(B, src.shape[1], n_kv, d_head)
    v = linear(p["wv"], src).reshape(B, src.shape[1], n_kv, d_head)

    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    if kv_memory is None:
        if positions is None:
            # absolute positions: prefill writes T tokens starting at
            # cache_index (0); decode writes one token at cache_index.
            base = 0 if cache_index is None else cache_index
            positions = jnp.broadcast_to(jnp.arange(T)[None, :] + base, (B, T))
        q = apply_rope(q, positions, rope_base)
        k = apply_rope(k, positions, rope_base)

    new_cache = None
    q_offset = 0
    if kv_cache is not None:
        # decode: write the new K/V at cache_index, attend over full cache
        idx = cache_index  # scalar int
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        q_offset = idx

    # One-token decode (Tq == 1) takes the GQA-grouped one-shot path: no
    # KV-head expansion (which would materialize group x the cache) and no
    # KV-block scan (which would all-gather a sequence-sharded cache per
    # block). Under GSPMD the softmax over the sharded S axis lowers to
    # flash-decode-style partial max/sum + tiny all-reduce combines.
    # (Perf iteration 1, EXPERIMENTS.md §Perf.)
    if T == 1 and kv_cache is not None:
        o = gqa_decode_attention(q, k, v, window=window, q_offset=q_offset)
    else:
        k = _expand_kv(k, n_heads)
        v = _expand_kv(v, n_heads)
        if attn_impl == "blocked":
            # flash-style custom VJP: backward recomputes per-block
            # probabilities instead of saving per-block softmax state
            # (Perf iteration "flash-vjp", EXPERIMENTS.md §Perf)
            from repro.models.flash_jnp import blocked_attention_flash
            o = blocked_attention_flash(
                q, k, v, causal=causal and kv_memory is None, window=window,
                q_offset=q_offset, block=block)
        else:
            o = naive_attention(q, k, v, causal=causal and kv_memory is None,
                                window=window, q_offset=q_offset)
    out = linear(p["wo"], o.reshape(B, T, n_heads * d_head))
    scope.__exit__(None, None, None)
    return out, new_cache


# ---------------------------------------------------------------- MLP


def init_swiglu(rng, d_model, d_ff, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "wi": init_linear(ks[0], d_model, d_ff, dtype),
        "wg": init_linear(ks[1], d_model, d_ff, dtype),
        "wo": init_linear(ks[2], d_ff, d_model, dtype),
    }


def swiglu(p, x):
    with jax.named_scope("mlp"):
        return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))


def init_embedding(rng, vocab, d_model, dtype):
    return {"table": (0.02 * jax.random.normal(rng, (vocab, d_model))).astype(dtype)}


def embed(p, tokens):
    with jax.named_scope("embed"):
        return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    return x @ p["table"].T
