"""Blocked online-softmax attention with a flash-style custom VJP.

Differentiating through the naive blocked scan makes jax stack every
per-block (m, l, acc) carry and materialize the [Tq, bk] probability
blocks as saved residuals -- the dominant HBM term of every train_4k
dry-run (EXPERIMENTS.md §Perf, iteration "flash-vjp"). This version:

* forward: same online-softmax block scan, but only (o, m, l) survive;
* backward: flash-attention recompute -- per kv block the probabilities
  are rebuilt from (q, k, m, l) and consumed immediately:

      D     = rowsum(do * o)
      p     = exp(q k^T * scale - m) / l        (masked)
      dv_b  = p^T do
      ds    = p * (do v_b^T - D)
      dq   += ds k_b * scale
      dk_b  = ds^T q * scale

``window`` may be a traced per-layer scalar (gemma3's 5:1 pattern inside
lax.scan), so it is a regular (integer, non-differentiable) argument.
This is also exactly the recompute schedule of the Pallas TPU kernel
(kernels/flash_attention.py); on CPU the dry-run uses this jnp twin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window, Tk):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m &= kpos[None, :] < Tk
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    w = jnp.asarray(window)
    lo = qpos[:, None] - jnp.where(w > 0, w, Tk + qpos.shape[0])
    m &= kpos[None, :] > lo
    return m


def _blocks(a, block):
    """[B, S, H, Dh] -> [nb, B, H, block, Dh] (zero-padded)."""
    B, S, H, Dh = a.shape
    nb = -(-S // block)
    pad = nb * block - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return a.reshape(B, nb, block, H, Dh).transpose(1, 0, 3, 2, 4)


def _fwd(q, k, v, window, causal, q_offset, block):
    B, Tq, H, Dh = q.shape
    Tk = k.shape[1]
    scale = Dh ** -0.5
    qpos = jnp.arange(Tq) + q_offset
    qh = q.transpose(0, 2, 1, 3).astype(jnp.float32)          # [B,H,Tq,Dh]
    kb = _blocks(k, block)
    vb = _blocks(v, block)
    nb = kb.shape[0]

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ib = inp
        kpos = ib * block + jnp.arange(block)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kblk.astype(jnp.float32)) * scale
        msk = _mask(qpos, kpos, causal, window, Tk)
        logits = jnp.where(msk[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    a0 = jnp.zeros((B, H, Tq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    o = (acc / jnp.maximum(l[..., None], 1e-30)).transpose(0, 2, 1, 3)
    return o.astype(q.dtype), (m, l)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention_vjp(q, k, v, window, q_offset, causal, block):
    o, _ = _fwd(q, k, v, window, causal, q_offset, block)
    return o


def _vjp_fwd(q, k, v, window, q_offset, causal, block):
    o, (m, l) = _fwd(q, k, v, window, causal, q_offset, block)
    return o, (q, k, v, window, q_offset, o, m, l)


def _vjp_bwd(causal, block, res, do):
    q, k, v, window, q_offset, o, m, l = res
    B, Tq, H, Dh = q.shape
    Tk = k.shape[1]
    scale = Dh ** -0.5
    qpos = jnp.arange(Tq) + q_offset
    qh = q.transpose(0, 2, 1, 3).astype(jnp.float32)
    doh = do.transpose(0, 2, 1, 3).astype(jnp.float32)
    oh = o.transpose(0, 2, 1, 3).astype(jnp.float32)
    Dvec = jnp.sum(doh * oh, axis=-1)                          # [B,H,Tq]
    linv = 1.0 / jnp.maximum(l, 1e-30)
    kb = _blocks(k, block)
    vb = _blocks(v, block)
    nb = kb.shape[0]

    def body(dq, inp):
        kblk, vblk, ib = inp
        kpos = ib * block + jnp.arange(block)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kblk.astype(jnp.float32)) * scale
        msk = _mask(qpos, kpos, causal, window, Tk)
        logits = jnp.where(msk[None, None], logits, NEG_INF)
        p = jnp.exp(logits - m[..., None]) * linv[..., None]    # [B,H,Tq,bk]
        dv = jnp.einsum("bhqk,bhqd->bhkd", p, doh)
        dp = jnp.einsum("bhqd,bhkd->bhqk", doh, vblk.astype(jnp.float32))
        ds = p * (dp - Dvec[..., None])
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kblk.astype(jnp.float32)) * scale
        dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qh) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, H, Tq, Dh), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))

    def unblock(a):  # [nb,B,H,block,Dh] -> [B,S,H,Dh]
        a = a.transpose(1, 0, 3, 2, 4).reshape(B, nb * block, H, Dh)
        return a[:, :Tk]

    dq = dq.transpose(0, 2, 1, 3).astype(q.dtype)
    dk = unblock(dkb).astype(k.dtype)
    dv = unblock(dvb).astype(v.dtype)
    dwin = np.zeros(jnp.shape(window), jax.dtypes.float0)
    doff = np.zeros(jnp.shape(q_offset), jax.dtypes.float0)
    return dq, dk, dv, dwin, doff


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def blocked_attention_flash(q, k, v, *, causal=True, window=0, q_offset=0,
                            block=512):
    """Drop-in for layers.blocked_attention with the flash custom VJP.
    ``window``/``q_offset`` may be traced scalars (per-layer windows inside
    lax.scan; prefill cache offsets)."""
    win = jnp.asarray(window, jnp.int32)
    off = jnp.asarray(q_offset, jnp.int32)
    return flash_attention_vjp(q, k, v, win, off, causal, int(block))
