"""Selective (Mamba-style) diagonal SSM used by the Hymba hybrid heads.

Diagonal selective state space:
    h_t = exp(-softplus(dt_t) * A) * h_{t-1} + (dt_t * B_t) x_t
    y_t = C_t . h_t + D * x_t

with input-dependent dt_t, B_t, C_t (the "selective" part). Parallel mode
uses ``jax.lax.associative_scan`` over (decay, increment) pairs -- the
TPU-idiomatic log-depth evaluation; decode mode is the O(1) recurrence
(why hybrid archs run the 524k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear


def init_ssm(rng, d_model, d_inner, d_state, dtype):
    ks = jax.random.split(rng, 6)
    return {
        "win": init_linear(ks[0], d_model, d_inner, dtype),
        "wdt": init_linear(ks[1], d_model, d_inner, dtype, bias=True),
        "wb": init_linear(ks[2], d_model, d_state, dtype),
        "wc": init_linear(ks[3], d_model, d_state, dtype),
        "wout": init_linear(ks[4], d_inner, d_model, dtype),
        "log_a": jnp.log(jnp.linspace(1.0, float(d_state), d_state, dtype=jnp.float32))[None, :]
        + jnp.zeros((d_inner, d_state), jnp.float32),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
    }


def _gates(p, x):
    u = jax.nn.silu(linear(p["win"], x))                      # [B,T,Di]
    dt = jax.nn.softplus(linear(p["wdt"], x).astype(jnp.float32))  # [B,T,Di]
    Bm = linear(p["wb"], x).astype(jnp.float32)                # [B,T,S]
    Cm = linear(p["wc"], x).astype(jnp.float32)                # [B,T,S]
    A = -jnp.exp(p["log_a"])                                   # [Di,S] (negative)
    decay = jnp.exp(dt[..., None] * A[None, None])             # [B,T,Di,S]
    drive = (dt * u.astype(jnp.float32))[..., None] * Bm[:, :, None, :]  # [B,T,Di,S]
    return u, decay, drive, Cm


def _combine(a, b):
    (da, ia), (db, ib) = a, b
    return (da * db, ia * db + ib)


def ssm_parallel(p, x, state, chunk: int = 2048):
    """x: [B,T,D] -> (y [B,T,D], new_state [B,Di,S]).

    Time is processed in chunks (associative scan inside, sequential state
    carry across) to bound the [B,C,Di,S] live activation footprint on long
    sequences.
    """
    scope = jax.named_scope("ssm")
    scope.__enter__()
    B, T, D = x.shape
    C = min(chunk, T)
    pad = (-T) % C
    u, decay, drive, Cm = _gates(p, x)
    if pad:
        # pad tokens: decay 1, drive 0 -> state passes through unchanged
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        drive = jnp.pad(drive, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // C
    Di, S = decay.shape[-2:]

    def resh(a):
        return a.reshape(B, nc, C, *a.shape[2:]).transpose(1, 0, *range(2, a.ndim + 1))

    dec_c, drv_c, cm_c, u_c = map(resh, (decay, drive, Cm, u))

    def chunk_fn(st, inp):
        dec, drv, cm, uu = inp
        drv = drv.at[:, 0].add(dec[:, 0] * st)
        _, h = jax.lax.associative_scan(_combine, (dec, drv), axis=1)
        y = jnp.einsum("btds,bts->btd", h, cm) + p["d_skip"] * uu.astype(jnp.float32)
        return h[:, -1], y

    state, ys = jax.lax.scan(chunk_fn, state, (dec_c, drv_c, cm_c, u_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, T + pad, Di)[:, :T]
    out = y.astype(x.dtype) @ p["wout"]["w"] + p["wout"].get("b", 0.0)
    scope.__exit__(None, None, None)
    return out, state


def ssm_step(p, x_t, state):
    """x_t: [B,D]; state: [B,Di,S] -> (y [B,D], new_state)."""
    u, decay, drive, Cm = _gates(p, x_t[:, None])
    state = decay[:, 0] * state + drive[:, 0]
    y = jnp.einsum("bds,bs->bd", state, Cm[:, 0]) + p["d_skip"] * u[:, 0].astype(jnp.float32)
    return (y.astype(x_t.dtype) @ p["wout"]["w"] + p["wout"].get("b", 0.0)), state
