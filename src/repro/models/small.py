"""The paper's own model zoo (Sec. 5.1), in pure JAX.

* MLP: 2 hidden layers x 200 units + softmax  (EMNIST-L / Fashion-MNIST)
* CNN: the McMahan et al. CIFAR CNN            (CIFAR-10 / CINIC-10)
* ResNet-GN: ResNet with GroupNorm in place of BatchNorm (CIFAR-100);
  depth is configurable (the paper uses ResNet-18; smoke tests shrink it)
* LSTM: char-level LSTM (Shakespeare)

Each factory returns ``(init_fn(rng) -> params, apply_fn(params, x) -> logits)``.
Models are plain pytrees -- no framework dependency -- so the HFL engine's
[G, K]-stacked vmapping works untouched.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

Init = Callable[[jax.Array], dict]
Apply = Callable[[dict, jax.Array], jax.Array]


def _dense(rng, n_in, n_out, scale=None):
    scale = scale if scale is not None else (2.0 / n_in) ** 0.5
    w = scale * jax.random.normal(rng, (n_in, n_out), jnp.float32)
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


def mlp(num_classes: int, input_dim: int, hidden: int = 200) -> Tuple[Init, Apply]:
    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "l1": _dense(k1, input_dim, hidden),
            "l2": _dense(k2, hidden, hidden),
            "out": _dense(k3, hidden, num_classes),
        }

    def apply(p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["l1"]["w"] + p["l1"]["b"])
        x = jax.nn.relu(x @ p["l2"]["w"] + p["l2"]["b"])
        return x @ p["out"]["w"] + p["out"]["b"]

    return init, apply


def deep_mlp(num_classes: int, input_dim: int, hidden: int = 32,
             depth: int = 48) -> Tuple[Init, Apply]:
    """Deep, narrow MLP: ``depth`` hidden layers of ``hidden`` units.

    The leaf-rich stress model for the round engines: per-parameter work is
    tiny while the leaf count is ~``2 * depth``, so per-leaf dispatch and
    trace cost dominate -- exactly the regime the flat-state hot path
    (core/packer.py) collapses. Used by benchmarks/bench_round.py.
    """

    def init(rng):
        ks = jax.random.split(rng, depth + 2)
        p = {"in": _dense(ks[0], input_dim, hidden)}
        for i in range(depth):
            p[f"h{i:03d}"] = _dense(ks[i + 1], hidden, hidden)
        p["out"] = _dense(ks[-1], hidden, num_classes)
        return p

    def apply(p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["in"]["w"] + p["in"]["b"])
        for i in range(depth):
            x = jax.nn.relu(x @ p[f"h{i:03d}"]["w"] + p[f"h{i:03d}"]["b"])
        return x @ p["out"]["w"] + p["out"]["b"]

    return init, apply


def _conv(rng, kh, kw, cin, cout):
    scale = (2.0 / (kh * kw * cin)) ** 0.5
    return {
        "w": scale * jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _apply_conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def cnn(num_classes: int, image_shape=(8, 8, 1)) -> Tuple[Init, Apply]:
    """McMahan-style CNN: conv5x32 - pool - conv5x64 - pool - fc512 - fc."""
    h, w, c = image_shape

    def init(rng):
        ks = jax.random.split(rng, 4)
        flat = (h // 4) * (w // 4) * 64
        return {
            "c1": _conv(ks[0], 5, 5, c, 32),
            "c2": _conv(ks[1], 5, 5, 32, 64),
            "f1": _dense(ks[2], flat, 512),
            "out": _dense(ks[3], 512, num_classes),
        }

    def apply(p, x):
        x = x.reshape(x.shape[0], h, w, c)
        x = jax.nn.relu(_apply_conv(p["c1"], x))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = jax.nn.relu(_apply_conv(p["c2"], x))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["f1"]["w"] + p["f1"]["b"])
        return x @ p["out"]["w"] + p["out"]["b"]

    return init, apply


def _groupnorm(p, x, groups):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    x = xg.reshape(n, h, w, c)
    return x * p["scale"] + p["bias"]


def _gn_params(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def resnet_gn(
    num_classes: int,
    image_shape=(8, 8, 3),
    widths=(16, 32, 64),
    blocks_per_stage: int = 2,
    gn_groups: int = 8,
) -> Tuple[Init, Apply]:
    """ResNet with GroupNorm (paper CIFAR-100 config modulo width/depth)."""
    h, w, c = image_shape

    def init(rng):
        ks = iter(jax.random.split(rng, 4 + 6 * len(widths) * blocks_per_stage))
        p = {"stem": _conv(next(ks), 3, 3, c, widths[0]), "stem_gn": _gn_params(widths[0])}
        cin = widths[0]
        for s, width in enumerate(widths):
            for b in range(blocks_per_stage):
                blk = {
                    "c1": _conv(next(ks), 3, 3, cin, width),
                    "gn1": _gn_params(width),
                    "c2": _conv(next(ks), 3, 3, width, width),
                    "gn2": _gn_params(width),
                }
                if cin != width:
                    blk["proj"] = _conv(next(ks), 1, 1, cin, width)
                p[f"s{s}b{b}"] = blk
                cin = width
        p["out"] = _dense(next(ks), cin, num_classes)
        return p

    def apply(p, x):
        x = x.reshape(x.shape[0], h, w, c)
        x = jax.nn.relu(_groupnorm(p["stem_gn"], _apply_conv(p["stem"], x), gn_groups))
        cin = widths[0]
        for s, width in enumerate(widths):
            for b in range(blocks_per_stage):
                blk = p[f"s{s}b{b}"]
                stride = 2 if (b == 0 and s > 0) else 1
                y = jax.nn.relu(
                    _groupnorm(blk["gn1"], _apply_conv(blk["c1"], x, stride), gn_groups))
                y = _groupnorm(blk["gn2"], _apply_conv(blk["c2"], y), gn_groups)
                sc = x if "proj" not in blk else _apply_conv(blk["proj"], x, stride)
                if stride != 1 and "proj" not in blk:
                    sc = sc[:, ::2, ::2, :]
                x = jax.nn.relu(y + sc)
                cin = width
        x = x.mean(axis=(1, 2))
        return x @ p["out"]["w"] + p["out"]["b"]

    return init, apply


def lstm(vocab: int, hidden: int = 128, embed: int = 32) -> Tuple[Init, Apply]:
    """Char-LSTM for next-token prediction (paper Shakespeare config)."""

    def init(rng):
        ks = jax.random.split(rng, 4)
        return {
            "emb": 0.02 * jax.random.normal(ks[0], (vocab, embed), jnp.float32),
            "wx": _dense(ks[1], embed, 4 * hidden),
            "wh": _dense(ks[2], hidden, 4 * hidden, scale=(1.0 / hidden) ** 0.5),
            "out": _dense(ks[3], hidden, vocab),
        }

    def apply(p, x):
        # x: [B, T] int tokens -> logits [B, T, vocab]
        e = p["emb"][x]                       # [B, T, E]
        B = x.shape[0]
        h0 = jnp.zeros((B, p["wh"]["w"].shape[0]), jnp.float32)
        c0 = jnp.zeros_like(h0)

        def step(carry, et):
            h, c = carry
            gates = et @ p["wx"]["w"] + p["wx"]["b"] + h @ p["wh"]["w"] + p["wh"]["b"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        _, hs = jax.lax.scan(step, (h0, c0), e.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)            # [B, T, H]
        return hs @ p["out"]["w"] + p["out"]["b"]

    return init, apply


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()


def make_loss(apply: Apply) -> Callable[[dict, dict], jax.Array]:
    """Standard classification / next-token loss over {'x','y'} batches."""

    def loss(params, batch):
        logits = apply(params, batch["x"])
        return softmax_xent(logits, batch["y"])

    return loss


def jit_accuracy(apply: Apply, x: jax.Array, y: jax.Array):
    """Jit-traceable eval accuracy over the full (x, y) set: ``params ->
    scalar``.

    The traceable counterpart of :func:`accuracy` (which streams batches on
    the host and cannot be jitted): meant to be traced *inside* an already
    jitted program, e.g. the horizon driver's ``eval_fn`` (core/driver.py).
    Standalone callers should wrap it in ``jax.jit`` themselves and need
    the whole eval set to fit in one forward pass.
    """

    def acc(params) -> jax.Array:
        pred = jnp.argmax(apply(params, x), axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))

    return acc


def accuracy(apply: Apply, params, x, y, batch: int = 512) -> float:
    """Streaming eval accuracy."""
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = apply(params, x[i : i + batch])
        pred = jnp.argmax(logits, -1)
        yy = y[i : i + batch]
        correct += int((pred == yy).sum())
    return correct / y.size
