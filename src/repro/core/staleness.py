"""Async group rounds: the static staleness plan behind every engine.

MTGC's two-timescale schedule assumes every group completes the same E
group rounds before each global aggregation. Real hierarchical systems
have straggler edges (Wang & Wang, *Asynchronous Hierarchical Federated
Learning*): groups run at their own pace and report late. This module
turns a heterogeneous per-group round count ``(E_1, ..., E_G)`` plus a
staleness policy into the *static* quantities both round engines need, so
the compiled program shape never depends on which group is slow:

* **Padded inner loop**: every global round ("window") scans
  ``e_pad = max(E_g)`` group rounds; group g is live only for iterations
  ``e < E_g`` (:meth:`StalenessPlan.iteration_mask`, a ``[e_pad, G]``
  constant). Masked iterations gate the local steps, the z update and the
  within-group dissemination exactly like participation masks -- data, not
  structure.
* **Report cadence**: under an async policy a straggler does not truncate
  its cycle to the window; it keeps working across windows and reports
  (uploads its group model, downloads the fresh global model) only every
  ``r_g = ceil(e_pad / E_g)`` windows. Its report is then *stale*: the
  global model advanced ``tau_g = r_g - 1`` aggregations since the group
  last downloaded. ``max_staleness`` bounds the cadence -- a group whose
  staleness would exceed the bound is force-synced at
  ``r_g = max_staleness + 1`` windows, reporting whatever partial cycle it
  has. Cadences are static, so the per-window report/fresh masks are pure
  functions of the carried round counter ``t`` (same shapes every window).
* **Stale-merge policy** (what the global aggregation does with a report
  that is ``tau_g`` windows old):

  - ``"sync"``: no late reporting at all -- every group reports every
    window with whatever ``E_g`` rounds of work it finished (the
    heterogeneous-work, zero-staleness baseline; ``r_g = 1``).
  - ``"naive"``: stale reports merge at full weight, no correction -- the
    control the staleness-aware policies are measured against
    (benchmarks/bench_async.py).
  - ``"discount"``: a report ``tau`` windows old is down-weighted by
    ``1 / (1 + tau)`` in the global mean (FedAsync-style polynomial
    staleness weighting). The discount applies to the *merge only*: the
    y-correction update always runs at full rate, because y is a
    tracking estimator -- discounting its increment makes a transient y
    decay only geometrically across report cycles, and the stale
    correction then biases every descent step in between
    (benchmarks/bench_async.py measures exactly this failure mode).
  - ``"delay_compensated"``: the report is shifted by the global progress
    the group missed -- ``xbar_g + (glob - snap_g)`` where ``snap_g`` is
    the global model the group last downloaded and ``glob`` the current
    one (first-order delay compensation, DC-ASGD-style); the y update
    sees the compensated model. Needs the ``snap``/``glob`` state fields
    (``hfl_init(..., staleness_snapshots=True)`` /
    ``sharded_init(..., staleness_snapshots=True)``).

The y-correction update generalizes per group: a reporting group ran
``E_g * r_g`` group rounds since its last download, so its increment is
``(xbar_g - xbar) / (H * E_g * r_g * lr)`` (times the discount weight
under ``"discount"``) -- for the uniform sync schedule this is exactly
Algorithm 1 line 11.

``make_plan`` returns ``None`` for a uniform schedule under ``"sync"``:
the engines then take their legacy code path untouched, so the async
machinery is provably a superset (tests/test_async_rounds.py gates the
uniform tuple bit-exactly against the scalar-E engines).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

#: Stale-merge policies accepted by ``ExperimentSpec.staleness``.
STALENESS_POLICIES = ("sync", "naive", "discount", "delay_compensated")


@dataclasses.dataclass(frozen=True)
class StalenessPlan:
    """Static async-round quantities for one two-level experiment.

    group_rounds: per-group E_g, one entry per group.
    policy: one of :data:`STALENESS_POLICIES`.
    max_staleness: bound on tau_g; groups whose cadence would exceed it
        are force-synced every ``max_staleness + 1`` windows.
    """

    group_rounds: tuple[int, ...]
    policy: str = "sync"
    max_staleness: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "group_rounds",
                           tuple(int(e) for e in self.group_rounds))
        if self.policy not in STALENESS_POLICIES:
            raise ValueError(f"unknown staleness policy {self.policy!r} "
                             f"(choose from {STALENESS_POLICIES})")
        if any(e < 1 for e in self.group_rounds):
            raise ValueError(f"group_rounds must be >= 1: {self.group_rounds}")
        if self.max_staleness is not None and self.max_staleness < 1:
            raise ValueError(
                f"max_staleness must be None or >= 1, got {self.max_staleness}")

    # ------------------------------------------------------------- static

    @property
    def num_groups(self) -> int:
        return len(self.group_rounds)

    @property
    def e_pad(self) -> int:
        """Padded inner-loop length: max(E_g) group rounds per window."""
        return max(self.group_rounds)

    @property
    def periods(self) -> tuple[int, ...]:
        """Report cadence r_g in windows (1 = reports every window)."""
        if self.policy == "sync":
            return (1,) * self.num_groups
        rs = tuple(math.ceil(self.e_pad / e) for e in self.group_rounds)
        if self.max_staleness is not None:
            rs = tuple(min(r, self.max_staleness + 1) for r in rs)
        return rs

    @property
    def staleness(self) -> tuple[int, ...]:
        """tau_g: global aggregations a group's report is behind by."""
        return tuple(r - 1 for r in self.periods)

    @property
    def effective_rounds(self) -> tuple[int, ...]:
        """Group rounds a group runs per report cycle (the y divisor)."""
        return tuple(e * r for e, r in zip(self.group_rounds, self.periods))

    @property
    def needs_round_counter(self) -> bool:
        """True when report/fresh masks depend on the round counter t."""
        return any(r > 1 for r in self.periods)

    @property
    def needs_snapshots(self) -> bool:
        """True when the state must carry snap/glob (delay compensation)."""
        return self.policy == "delay_compensated"

    @property
    def fastest_group(self) -> int:
        """A group with r_g = 1: its replicas always hold the fresh global
        model between windows (used to read the global model out of an
        async state)."""
        return int(np.argmax(np.asarray(self.group_rounds)))

    def iteration_mask(self) -> np.ndarray:
        """[e_pad, G] float32: group g is live at inner iteration e < E_g."""
        e = np.arange(self.e_pad)[:, None]
        return (e < np.asarray(self.group_rounds)[None, :]).astype(np.float32)

    def discount_weights(self) -> np.ndarray:
        """[G] float32 stale-merge weights (1/(1+tau) under 'discount')."""
        if self.policy == "discount":
            return (1.0 / (1.0 + np.asarray(self.staleness))).astype(np.float32)
        return np.ones(self.num_groups, np.float32)

    # ------------------------------------------------------------- traced

    def report_mask(self, t) -> jax.Array:
        """[G] 0/1: group g reports (uploads + downloads) at window t.

        ``t`` is the 0-based carried round counter; a group with cadence
        r reports at windows r-1, 2r-1, ... (everyone reports at the end
        of its first full cycle). Constant ones when no cadence exceeds 1.
        """
        if not self.needs_round_counter:
            return jnp.ones((self.num_groups,), jnp.float32)
        r = jnp.asarray(self.periods, jnp.int32)
        return ((t + 1) % r == 0).astype(jnp.float32)

    def fresh_mask(self, t) -> jax.Array:
        """[G] 0/1: group g starts window t from a fresh download (it
        reported at the end of window t-1; everyone is fresh at t=0), so
        its z correction re-initializes this window."""
        if not self.needs_round_counter:
            return jnp.ones((self.num_groups,), jnp.float32)
        r = jnp.asarray(self.periods, jnp.int32)
        return (t % r == 0).astype(jnp.float32)


def make_plan(group_rounds, num_groups: int, policy: str = "sync",
              max_staleness: int | None = None) -> StalenessPlan | None:
    """The plan for a schedule, or ``None`` for the legacy sync path.

    ``group_rounds`` is a scalar E or a per-group tuple; a uniform
    schedule under ``"sync"`` returns None so callers dispatch to the
    unmodified (bit-exact) uniform round builders.
    """
    if isinstance(group_rounds, (list, tuple)):
        vec = tuple(int(e) for e in group_rounds)
        if len(vec) != num_groups:
            raise ValueError(f"per-group group_rounds needs one entry per "
                             f"group: {len(vec)} entries for {num_groups} "
                             "groups")
    else:
        vec = (int(group_rounds),) * num_groups
    uniform = all(e == vec[0] for e in vec)
    if uniform and policy == "sync":
        if max_staleness is not None:
            raise ValueError("max_staleness only bounds async (non-sync) "
                             "staleness policies")
        return None
    return StalenessPlan(group_rounds=vec, policy=policy,
                         max_staleness=max_staleness)
