"""Configuration for hierarchical-FL training runs (the paper's setting)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HFLConfig:
    """Two-level HFL topology + algorithm knobs (paper notation).

    Attributes:
      num_groups:        N  -- number of group aggregators.
      clients_per_group: n  -- clients under each group aggregator (uniform
                              n_j = n; the weighted case folds coefficients
                              into F_i as in the paper, Sec. 2.1).
      local_steps:       H  -- local SGD iterations per group round.
      group_rounds:      E  -- group aggregations per global round.
      lr:                gamma.
      algorithm:         one of core.algorithms.ALGORITHMS.
      correction_init:   'zero' (paper's experiments, footnote 2) or
                         'gradient' (paper's theoretical initialization).
      prox_mu:           FedProx proximal coefficient (only used by fedprox).
      feddyn_alpha:      FedDyn regularization coefficient.
      server_lr:         aggregator-side learning rate (1.0 = plain average,
                         kept for beyond-paper experimentation).
    """

    num_groups: int = 2
    clients_per_group: int = 2
    local_steps: int = 5
    group_rounds: int = 2
    lr: float = 0.1
    algorithm: str = "mtgc"
    correction_init: str = "zero"
    prox_mu: float = 0.0
    feddyn_alpha: float = 0.0
    server_lr: float = 1.0

    @property
    def total_clients(self) -> int:
        return self.num_groups * self.clients_per_group

    def validate(self) -> "HFLConfig":
        assert self.num_groups >= 1 and self.clients_per_group >= 1
        assert self.local_steps >= 1 and self.group_rounds >= 1
        assert self.correction_init in ("zero", "gradient")
        return self
