"""Configuration for hierarchical-FL training runs (the paper's setting)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HFLConfig:
    """Two-level HFL topology + algorithm knobs (paper notation).

    Attributes:
      num_groups:        N  -- number of group aggregators.
      clients_per_group: n  -- clients under each group aggregator (uniform
                              n_j = n; the weighted case folds coefficients
                              into F_i as in the paper, Sec. 2.1).
      local_steps:       H  -- local SGD iterations per group round.
      group_rounds:      E  -- group aggregations per global round.
      lr:                gamma.
      algorithm:         one of core.algorithms.ALGORITHMS.
      correction_init:   'zero' (paper's experiments, footnote 2) or
                         'gradient' (paper's theoretical initialization).
      prox_mu:           FedProx proximal coefficient (only used by fedprox).
      feddyn_alpha:      FedDyn regularization coefficient.
      server_lr:         aggregator-side learning rate (1.0 = plain average,
                         kept for beyond-paper experimentation).
      client_participation: C_k -- fraction of each group's clients sampled
                         per global round (1.0 = the paper's full
                         participation).
      group_participation:  C_g -- fraction of groups reachable per global
                         round; a skipped group freezes all of its clients
                         and its y_j for the round.
      participation_mode: 'uniform' (independent Bernoulli draws) or 'fixed'
                         (exactly the nearest count max(1, floor(C*n + 0.5))
                         participants -- half-up, never banker's rounding;
                         see participation.fixed_count -- sampled without
                         replacement).
      participation_weighting: 'none' divides masked aggregations by the
                         *realized* participant count; 'inverse_prob'
                         divides by the *expected* count (Horvitz-Thompson:
                         ``inclusion_prob * n`` per level, the group level
                         composing ``group_participation``), which keeps the
                         group/global aggregates -- and the averages the
                         z/y corrections track -- unbiased under Bernoulli
                         sampling at the cost of variance. The two coincide
                         under 'fixed' sampling and at full participation
                         (see core/participation.py).
      use_fused_update:  route the MTGC local step through the fused Pallas
                         kernel (kernels/mtgc_update.py); interpret-mode off
                         TPU. Only valid for algorithm='mtgc'. Combined with
                         ``use_flat_state`` the whole model is one batched
                         kernel call with the participation mask folded in.
      use_flat_state:    store params/z/dyn as contiguous ``[G, K, N]``
                         buffers (one per dtype) and ``y`` as ``[G, N]``
                         (see core/packer.py). The round hot path then runs
                         as a handful of whole-model ops instead of
                         per-leaf dispatch; ``hfl_init`` returns a
                         FlatBuffers-state and the round function adapts to
                         whichever state layout it is traced with. Default
                         on (the simulator engine's flat/tree parity is
                         covered by tests/test_flat_state.py).
    """

    num_groups: int = 2
    clients_per_group: int = 2
    local_steps: int = 5
    group_rounds: int = 2
    lr: float = 0.1
    algorithm: str = "mtgc"
    correction_init: str = "zero"
    prox_mu: float = 0.0
    feddyn_alpha: float = 0.0
    server_lr: float = 1.0
    client_participation: float = 1.0
    group_participation: float = 1.0
    participation_mode: str = "uniform"
    participation_weighting: str = "none"
    use_fused_update: bool = False
    use_flat_state: bool = True

    @property
    def total_clients(self) -> int:
        return self.num_groups * self.clients_per_group

    @property
    def full_participation(self) -> bool:
        return self.client_participation >= 1.0 and self.group_participation >= 1.0

    def validate(self) -> "HFLConfig":
        """Raise ``ValueError`` on an invalid config (never ``assert``:
        asserts vanish under ``python -O``, silently accepting bad configs;
        ``ExperimentSpec.validate`` mirrors these checks)."""
        def require(cond: bool, msg: str) -> None:
            if not cond:
                raise ValueError(msg)

        require(self.num_groups >= 1 and self.clients_per_group >= 1,
                f"topology dims must be >= 1, got G={self.num_groups} "
                f"K={self.clients_per_group}")
        require(self.local_steps >= 1 and self.group_rounds >= 1,
                f"schedule must be >= 1 step/round, got H={self.local_steps} "
                f"E={self.group_rounds}")
        require(self.correction_init in ("zero", "gradient"),
                f"correction_init must be 'zero' or 'gradient', "
                f"got {self.correction_init!r}")
        require(0.0 < self.client_participation <= 1.0,
                f"client_participation must be in (0, 1], "
                f"got {self.client_participation}")
        require(0.0 < self.group_participation <= 1.0,
                f"group_participation must be in (0, 1], "
                f"got {self.group_participation}")
        require(self.participation_mode in ("uniform", "fixed"),
                f"participation_mode must be 'uniform' or 'fixed', "
                f"got {self.participation_mode!r}")
        require(self.participation_weighting in ("none", "inverse_prob"),
                f"participation_weighting must be 'none' or 'inverse_prob', "
                f"got {self.participation_weighting!r}")
        require(not (self.use_fused_update and self.algorithm != "mtgc"),
                "use_fused_update fuses exactly g + z + y: mtgc only")
        return self
