"""The hierarchical-FL round engine (paper Algorithm 1, generalized).

One *global round* ``t`` is the engine's unit of work -- a single jittable
program (though no longer the largest one: ``core/driver.py`` lifts whole
training horizons over this round function into one compiled
scan-over-rounds with donated state buffers and on-device batch
selection; the round function itself is driver-agnostic):

    for e in range(E):                 # lax.scan over group rounds
        for h in range(H):             # lax.scan over local steps
            g_i   = grad F_i(x_i, xi)                  # vmapped over [G, K]
            x_i  -= lr * (g_i + z_i + y_j [+ prox/dyn terms])
        group aggregation + z update (Alg. 1, lines 8-9)
    global aggregation + y update     (Alg. 1, lines 10-11)

All per-client state is stacked with leading axes ``[G, K, ...]`` so the same
engine runs (a) as a CPU simulator for the paper's experiments and (b) under
GSPMD with the leading axes sharded over the (group, client) mesh axes, where
the group/global aggregations lower to hierarchical all-reduces.

Baselines are the same engine with corrections toggled off (HFedAvg), one
correction only (local / group correction, Fig. 4), or with FedProx / FedDyn
gradient modifiers (Fig. 3).

Partial participation (beyond the paper, the regime where correction
methods are stress-tested): when ``cfg.client_participation`` /
``cfg.group_participation`` < 1, per-round 0/1 masks are drawn from
``state.rng`` (see ``core.participation``); inactive clients keep their
params and corrections frozen, every aggregation becomes a masked mean, and
``z``/``y`` updates fire only for participants. Masks are data, not
structure -- the scans and the jitted program shape are unchanged. With
full participation the masked machinery is compiled out entirely, so the
default path is bit-for-bit the paper engine.

``cfg.participation_weighting`` picks the masked-mean estimator:
``"none"`` divides by the realized participant count (the subpopulation
mean), ``"inverse_prob"`` divides by the expected count (Horvitz-Thompson
-- group client-means by ``inclusion_prob(C_k) * K``, the global
group-mean by ``inclusion_prob(C_g) * G`` over *reachable* groups, with a
reachable-but-empty group legitimately contributing zero). The same
denominators flow into the z/y control-variable updates and the
``correction_init='gradient'`` means, so the averages the corrections
track stay unbiased under Bernoulli sampling instead of compounding the
count randomness across both timescales (tests/test_weighting.py). State
gating is weighting-independent: frozen replicas stay frozen, y updates
still fire only for groups with at least one active client.

Flat state (``cfg.use_flat_state``, default on): ``hfl_init`` packs params,
``z`` and ``dyn`` into contiguous ``[G, K, N]`` buffers (one per dtype) and
``y`` into ``[G, N]`` (see ``core.packer``); the round function detects the
layout at trace time from the state itself. Every aggregation, correction
update, drift norm and dissemination then runs as a single whole-model op
instead of per-leaf dispatch. The gradient hot loop still consumes tree
views -- ``packer.unflatten`` produces them once per *local phase* (not per
step, so the hot loop pays no repack traffic), the phase constants z and y
unpack once at the phase boundary (y deliberately kept ``[G, N]``, a
factor K smaller than the replicas, broadcasting per step), and the
participation ``where`` folds into the same fused update expression. With
``use_fused_update`` the local step becomes a single batched Pallas call
over the entire flat model (mask folded in, ``y`` broadcast by the kernel's
index map; kernels/mtgc_update.py) -- the TPU path. Flat/tree parity is
enforced by tests/test_flat_state.py; models are untouched either way.

Cohort shapes: the round reads ``G, K`` from the state's leading axes at
trace time, never from a global registry -- so ``K`` need not be the whole
client population. ``core.population`` exploits exactly this: it keeps a
host-side store of per-client corrections for ``P >> K`` virtual clients
and swaps each sampled cohort's rows in and out of the same ``[G, K,
...]`` state between driver chunks, leaving this round function byte-for-
byte unchanged.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree as tu
from repro.core.config import HFLConfig
from repro.core.packer import FlatBuffers, as_tree, is_flat, make_packer
from repro.core.participation import inclusion_prob, round_masks

PyTree = Any


class HFLState(NamedTuple):
    """State carried between global rounds.

    params: [G, K, ...]  per-client models (all equal right after a round
                         under full participation; frozen replicas keep
                         stale params under partial participation).
    z:      [G, K, ...]  client->group correction (zeros when unused).
    y:      [G, ...]     group->global correction (zeros when unused).
    dyn:    [G, K, ...]  FedDyn gradient memory (zeros when unused).
    rng:    PRNG key for stochastic batching / participation sampling.
    round:  global round counter t.
    snap:   [G, ...]     global model each group last downloaded -- only
                         carried for delay-compensated async rounds
                         (``hfl_init(..., staleness_snapshots=True)``);
                         None otherwise (no pytree leaves).
    glob:   [...]        the last aggregated global model, paired with
                         ``snap`` (None otherwise).
    dl:     [G]          realized-download mask: which groups actually
                         downloaded at the end of the last window -- only
                         carried when group-timeout faults meet an async
                         schedule (``hfl_init(..., fault_download=True)``),
                         where the static fresh cadence no longer predicts
                         downloads; None otherwise (no pytree leaves).
    efc:    [G, K, ...]  client-link error-feedback residual -- only
                         carried when a ``CompressionPlan`` with error
                         feedback compresses the client->group uploads
                         (``hfl_init(..., ef_client=True)``); None
                         otherwise (no pytree leaves).
    efg:    [G, ...]     group-link error-feedback residual, likewise
                         (``hfl_init(..., ef_group=True)``).
    """

    params: PyTree
    z: PyTree
    y: PyTree
    dyn: PyTree
    rng: jax.Array
    round: jax.Array
    snap: PyTree | None = None
    glob: PyTree | None = None
    dl: jax.Array | None = None
    efc: PyTree | None = None
    efg: PyTree | None = None


class RoundMetrics(NamedTuple):
    loss: jax.Array          # [E, H] mean training loss per local step
    client_drift: jax.Array  # [E] mean ||x_i - xbar_j||^2 at group agg
    group_drift: jax.Array   # scalar mean ||xbar_j - xbar||^2 at global agg
    z_norm: jax.Array        # scalar mean ||z||^2 after the round
    y_norm: jax.Array        # scalar mean ||y||^2 after the round
    participation: jax.Array  # scalar fraction of clients active this round
    screened: jax.Array      # scalar count of screened contributions (0 undefended)
    comm_bytes: jax.Array    # scalar modeled upload bytes on the wire this round


def hfl_init(params0: PyTree, cfg: HFLConfig, rng: jax.Array | None = None,
             *, staleness_snapshots: bool = False,
             fault_download: bool = False, ef_client: bool = False,
             ef_group: bool = False) -> HFLState:
    """Broadcast a single model to every client and zero the corrections.

    With ``cfg.use_flat_state`` the state leaves are contiguous flat
    buffers (FlatBuffers; see core/packer.py) rather than model pytrees --
    recover tree views with ``packer.as_tree`` / ``FlatBuffers.to_tree``.

    ``staleness_snapshots`` additionally carries the per-group download
    snapshots (``snap``/``glob``) that delay-compensated async rounds need
    (core/staleness.py); both start at the initial model, so the first
    compensation is exactly zero.

    ``fault_download`` carries the realized-download mask ``dl`` that
    group-timeout faults under an async schedule need (core/faults.py);
    every group starts fresh (all ones -- matching the static
    ``fresh_mask`` at t=0).

    ``ef_client`` / ``ef_group`` carry the zero-initialized per-link
    error-feedback residuals (``efc`` [G, K, ...] / ``efg`` [G, ...])
    that a ``CompressionPlan`` with ``error_feedback=True`` accumulates
    (core/compression.py).
    """
    G, K = cfg.num_groups, cfg.clients_per_group
    rng = jax.random.PRNGKey(0) if rng is None else rng
    dl = jnp.ones((G,), jnp.float32) if fault_download else None
    if cfg.use_flat_state:
        packer = make_packer(params0)
        flat0 = packer.flatten(params0)
        params = FlatBuffers(
            {k: jnp.broadcast_to(b, (G, K) + b.shape) for k, b in flat0.bufs.items()},
            packer,
        )
        snap = glob = None
        if staleness_snapshots:
            glob = flat0
            snap = FlatBuffers(
                {k: jnp.broadcast_to(b, (G,) + b.shape)
                 for k, b in flat0.bufs.items()},
                packer,
            )
        return HFLState(
            params=params,
            z=packer.zeros((G, K)),
            y=packer.zeros((G,)),
            dyn=packer.zeros((G, K)),
            rng=rng,
            round=jnp.zeros((), jnp.int32),
            snap=snap,
            glob=glob,
            dl=dl,
            efc=packer.zeros((G, K)) if ef_client else None,
            efg=packer.zeros((G,)) if ef_group else None,
        )
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (G, K) + x.shape), params0
    )
    y0 = jax.tree.map(lambda x: jnp.zeros((G,) + x.shape, x.dtype), params0)
    snap = glob = None
    if staleness_snapshots:
        # jnp.array copies: glob must not alias the caller's params, or
        # the driver's donated scans would delete them out from under it.
        glob = jax.tree.map(jnp.array, params0)
        snap = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape), params0)
    return HFLState(
        params=stacked,
        z=tu.tree_zeros_like(stacked),
        y=y0,
        dyn=tu.tree_zeros_like(stacked),
        rng=rng,
        round=jnp.zeros((), jnp.int32),
        snap=snap,
        glob=glob,
        dl=dl,
        efc=tu.tree_zeros_like(stacked) if ef_client else None,
        efg=tu.tree_zeros_like(y0) if ef_group else None,
    )


def _client_grads(loss_fn: Callable, params: PyTree, batch: PyTree):
    """(loss, grad) of the local loss, vmapped over the [G, K] leading axes."""
    vg = jax.value_and_grad(loss_fn)
    return jax.vmap(jax.vmap(vg))(params, batch)


def make_global_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    cfg: HFLConfig,
) -> Callable[[HFLState, PyTree], tuple[HFLState, RoundMetrics]]:
    """Build the jittable global-round function for ``cfg.algorithm``.

    .. deprecated::
        ``make_global_round`` is the legacy constructor; new code should
        declare an ``ExperimentSpec(backend="simulator")`` and use
        ``repro.api.build(spec, loss_fn)`` -- this shim delegates to that
        adapter, so both paths are the same program.

    ``loss_fn(params, batch) -> scalar`` is a single-client loss; the engine
    vmaps it over the [G, K] axes. ``batches`` passed to the returned function
    must have leaves shaped ``[E, H, G, K, ...]`` (one batch per local step
    per client).

    The returned function adapts at trace time to the state layout it is
    given: a flat state (from ``hfl_init`` under ``cfg.use_flat_state``)
    runs the flat hot path, a pytree state runs the per-leaf reference
    path; ``loss_fn`` always sees model pytrees.
    """
    import warnings

    from repro.core.api import ExperimentSpec, build

    warnings.warn(
        "make_global_round is deprecated: declare an "
        "ExperimentSpec(backend='simulator') and use "
        "repro.api.build(spec, loss_fn)", DeprecationWarning, stacklevel=2)
    return build(ExperimentSpec.from_hfl_config(cfg), loss_fn).round_fn


def _build_global_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    cfg: HFLConfig,
    plan=None,
    faults=None,
    defense=None,
    compression=None,
) -> Callable[[HFLState, PyTree], tuple[HFLState, RoundMetrics]]:
    """The real round builder behind ``repro.api``'s simulator adapter.

    ``plan`` (a ``core.staleness.StalenessPlan``) switches the round into
    async group-round mode: batches carry ``e_pad = max(E_g)`` group rounds
    per global round ("window"), the static per-group iteration mask gates
    stragglers' dead iterations exactly like a participation mask, and the
    global aggregation becomes a staleness-aware merge of the groups
    reporting this window (report cadence, discount weights and delay
    compensation all from the plan -- see core/staleness.py). With
    ``plan=None`` (the uniform sync schedule) the traced program is the
    legacy round, bit for bit.

    ``faults`` (a ``core.faults.FaultPlan``) injects per-round crash /
    timeout / corrupted-upload faults drawn from the state rng *after* the
    participation draw (the zero-fault rng stream is untouched);
    ``defense`` (a ``core.faults.DefensePlan``) screens/clips uploads
    before any aggregate or correction update sees them. A disabled (or
    None) plan traces the legacy program, bit for bit.

    ``compression`` (a ``core.compression.CompressionPlan``) compresses
    the client->group and/or group->global uploads at the same seam the
    corruption faults and the defense use -- compression first, so
    faults corrupt and the defense screens the *dequantized* upload --
    with optional per-link error-feedback residuals carried in the state
    (``efc``/``efg``). A disabled (or None) plan traces the legacy
    program, bit for bit, and consumes no rng keys.
    """
    cfg.validate()
    faults = faults if (faults is not None and faults.enabled) else None
    defense = defense if (defense is not None and defense.enabled) else None
    fault_mode = faults is not None
    defended = defense is not None
    if fault_mode:
        faults.validate()
        f_crash = faults.crash_rate > 0
        f_timeout = faults.timeout_rate > 0
        f_corrupt = faults.corrupt_rate > 0
    else:
        f_crash = f_timeout = f_corrupt = False
    if defended:
        defense.validate()
    if fault_mode or defended:
        if cfg.correction_init != "zero":
            raise ValueError(
                "fault injection / screened aggregation require "
                "correction_init='zero' (the gradient init has no "
                "screened analogue)")
        if cfg.server_lr != 1.0:
            raise ValueError(
                "fault injection / screened aggregation require "
                "server_lr=1.0")
        from repro.core import faults as _flt
    comp = compression if (compression is not None
                           and compression.enabled) else None
    comp_mode = comp is not None
    if comp_mode:
        comp.validate()
        if plan is not None:
            raise ValueError(
                "compressed uploads under an async schedule are not "
                "supported yet (the staleness merge would need per-window "
                "residual bookkeeping; see ROADMAP)")
        if cfg.correction_init != "zero":
            raise ValueError(
                "compressed uploads require correction_init='zero' (the "
                "gradient init has no compressed analogue)")
        if cfg.server_lr != 1.0:
            raise ValueError("compressed uploads require server_lr=1.0")
    # Imported unconditionally: the comm_bytes metric is reported (at the
    # uncompressed wire size) whether or not a plan is active.
    from repro.core import compression as _cmp
    comp_c = comp_mode and comp.client_mode != "none"
    comp_g = comp_mode and comp.group_mode != "none"
    ef_c = comp_mode and comp.ef_client
    ef_g = comp_mode and comp.ef_group
    comp_stoch = comp_mode and comp.stochastic
    c_noise = comp_c and comp.client_mode == "int8_stochastic"
    algo = cfg.algorithm
    use_z = algo in ("mtgc", "local_corr")
    use_y = algo in ("mtgc", "group_corr")
    use_prox = algo == "fedprox"
    use_dyn = algo == "feddyn"
    if algo not in ("mtgc", "hfedavg", "local_corr", "group_corr", "fedprox", "feddyn"):
        raise ValueError(f"unknown algorithm {algo!r}")

    G, K, H, E = cfg.num_groups, cfg.clients_per_group, cfg.local_steps, cfg.group_rounds
    lr = cfg.lr
    partial = not cfg.full_participation
    async_mode = plan is not None
    if async_mode:
        if plan.num_groups != G:
            raise ValueError(f"staleness plan covers {plan.num_groups} "
                             f"groups, config has {G}")
        if plan.e_pad != E:
            raise ValueError(f"cfg.group_rounds must be the padded loop "
                             f"length max(E_g)={plan.e_pad}, got {E}")
        if cfg.correction_init != "zero":
            raise ValueError(
                "async group rounds require correction_init='zero' (the "
                "gradient init has no per-cycle analogue)")
        if cfg.server_lr != 1.0:
            raise ValueError("async group rounds require server_lr=1.0")
        # Static plan constants, captured by the traced round as literals.
        em_all = jnp.asarray(plan.iteration_mask())              # [E_pad, G]
        dw = jnp.asarray(plan.discount_weights())                # [G]
        e_eff = jnp.asarray(plan.effective_rounds, jnp.float32)  # [G]
    # Horvitz-Thompson denominators (expected active counts per level);
    # None = realized-count weighting.
    ht = partial and cfg.participation_weighting == "inverse_prob"
    cdenom = (inclusion_prob(cfg.client_participation, K,
                             cfg.participation_mode) * K if ht else None)
    gdenom = (inclusion_prob(cfg.group_participation, G,
                             cfg.participation_mode) * G if ht else None)
    use_fused = cfg.use_fused_update
    if use_fused:
        from repro.kernels import ops as kops
        fused_mode = "pallas" if jax.default_backend() == "tpu" else "interpret"
    # Compression rides the same fusion knob: a fused spec runs the batched
    # quantize kernels (interpret off-TPU, so the pallas_call contract is
    # auditable on CPU), an unfused spec the bit-identical jnp reference.
    comp_dispatch = (("pallas" if jax.default_backend() == "tpu"
                      else "interpret") if use_fused else "ref")

    def global_round(state: HFLState, batches: PyTree) -> tuple[HFLState, RoundMetrics]:
        x, z, y, dyn = state.params, state.z, state.y, state.dyn
        flat = is_flat(state.params)
        packer = state.params.packer if flat else None

        if partial:
            masks, rng = round_masks(state.rng, cfg)
            cmask = masks.client                              # [G, K]
            gmask = masks.group                               # [G]
        else:
            cmask = None
            rng = state.rng

        if fault_mode:
            # Fault draw AFTER the participation draw, off the same carried
            # stream: the zero-fault stream (and trajectory) is untouched.
            fm, rng = _flt.fault_masks(rng, faults, G, K)
            if f_crash:
                # A crashed client is frozen exactly like an unsampled one.
                alive = 1.0 - fm.crash
                cmask = alive if cmask is None else cmask * alive
            if f_timeout:
                tm_keep = 1.0 - fm.timeout                    # [G]
        if comp_stoch:
            # Compression-noise draw AFTER the participation and fault
            # draws, off the same carried stream; deterministic modes
            # (bf16/topk) consume no keys, so their rng stream -- and
            # trajectory -- matches the uncompressed run's exactly.
            ckey, rng = jax.random.split(rng)
            kc, kg = jax.random.split(ckey)
        if (fault_mode or defended) and cmask is None:
            # Force the masked machinery on so screens/faults have a mask
            # to compose with even under full participation.
            cmask = jnp.ones((G, K), jnp.float32)
        masked = cmask is not None
        if masked:
            n_active = jnp.maximum(jnp.sum(cmask), 1.0)

        if async_mode:
            # Per-window report/fresh masks from the carried round counter
            # (constant ones when every cadence is 1, i.e. policy "sync").
            rep = plan.report_mask(state.round)               # [G]
            fresh = plan.fresh_mask(state.round)              # [G]
            if f_timeout:
                # A timed-out group misses its report window; the static
                # fresh cadence no longer predicts downloads, so freshness
                # comes from the carried realized-download mask instead.
                if state.dl is None:
                    raise ValueError(
                        "group-timeout faults under an async schedule carry "
                        "the realized-download mask in the state: build it "
                        "with hfl_init(..., fault_download=True) "
                        "(repro.api.build does this for you)")
                rep = rep * tm_keep
                fresh = state.dl

        def step_loss_mean(loss, am, n_act):
            if defended:
                # A corrupted client that has not healed yet (downloaded a
                # clean model) produces a non-finite loss while its upload
                # is screened -- keep the loss metric (and the guarded
                # horizon's divergence predicate) meaningful by screening
                # the metric the same way.
                w = am * jnp.isfinite(loss).astype(jnp.float32)
                return (jnp.sum(jnp.where(w != 0, loss, 0))
                        / jnp.maximum(jnp.sum(w), 1.0))
            if am is not None:
                return jnp.sum(jnp.where(am != 0, loss, 0)) / n_act
            return jnp.mean(loss)

        def local_phase_tree(x, z, y, dyn, anchor, batches_eh, am, n_act):
            """H local SGD steps (Alg. 1, lines 6-7). batches_eh: [H, G, K, ...]."""
            y_b = tu.tree_broadcast_to_axis(y, 1, K)  # [G, K, ...]

            def step(carry, batch):
                x = carry
                loss, g = _client_grads(loss_fn, x, batch)
                if use_fused:
                    # Hot-spot AXPY fused through VMEM (Alg. 1 line 7).
                    x_new = jax.tree.map(
                        lambda xi, gi, zi, yi: kops.mtgc_update(
                            xi, gi, zi, yi, lr=lr, mode=fused_mode),
                        x, g, z, y_b,
                    )
                else:
                    # Corrected direction: g + z + y (MTGC); baselines
                    # toggle terms.
                    d = g
                    if use_z:
                        d = tu.tree_add(d, z)
                    if use_y:
                        d = tu.tree_add(d, y_b)
                    if use_prox:
                        d = jax.tree.map(
                            lambda di, xi, ai: di + cfg.prox_mu * (xi - ai),
                            d, x, anchor)
                    if use_dyn:
                        d = jax.tree.map(
                            lambda di, mi, xi, ai: di - mi + cfg.feddyn_alpha * (xi - ai),
                            d, dyn, x, anchor,
                        )
                    x_new = jax.tree.map(lambda xi, di: xi - lr * di, x, d)
                if am is not None:
                    x = tu.tree_select(am, x_new, x)
                else:
                    x = x_new
                return x, step_loss_mean(loss, am, n_act)

            x, losses = jax.lax.scan(step, x, batches_eh)
            return x, losses

        def local_phase_flat(x, z, y, dyn, anchor, batches_eh, am, n_act):
            """Flat local phase: repack at the phase boundary, never per step.

            z and y are constant for the whole phase, so they unpack once
            here (y kept at its [G, ...] shape -- a factor K smaller than
            the replicas -- and broadcast per step, unlike the sharded
            round which pre-sums z + y at full [G, K] size); the
            participation gate folds into the same fused update expression
            (no separate parameter-sized ``tree_select`` pass).
            """
            if use_fused:
                # One batched Pallas call over the entire flat model per
                # step: y stays [G, N] (broadcast by the kernel index map)
                # and the mask is applied in-register.
                def step(xf, batch):
                    loss, g = _client_grads(loss_fn, packer.unflatten(xf), batch)
                    gf = packer.flatten(g)
                    xf = FlatBuffers(
                        {k: kops.mtgc_update_flat(
                            xf.bufs[k], gf.bufs[k], z.bufs[k], y.bufs[k],
                            am, lr=lr, mode=fused_mode)
                         for k in xf.bufs},
                        packer,
                    )
                    return xf, step_loss_mean(loss, am, n_act)

                return jax.lax.scan(step, x, batches_eh)

            # Unpack the phase constants once ([G, N] y stays a factor K
            # smaller than the replicas until it broadcasts in-kernel).
            z_t = z.to_tree() if use_z else None
            y_t = y.to_tree() if use_y else None
            anchor_t = anchor.to_tree() if (use_prox or use_dyn) else None
            dyn_t = dyn.to_tree() if use_dyn else None

            def step(x_t, batch):
                loss, g = _client_grads(loss_fn, x_t, batch)

                def upd(xi, gi, *rest):
                    it = iter(rest)
                    d = gi
                    if use_z:
                        d = d + next(it)
                    if use_y:
                        d = d + jnp.expand_dims(next(it), 1)
                    if use_prox or use_dyn:
                        ai = next(it)
                    if use_prox:
                        d = d + cfg.prox_mu * (xi - ai)
                    if use_dyn:
                        d = d - next(it) + cfg.feddyn_alpha * (xi - ai)
                    x_new = xi - lr * d
                    if am is not None:
                        return jnp.where(tu.expand_mask(am, x_new) != 0, x_new, xi)
                    return x_new

                extra = [t for t, used in ((z_t, use_z), (y_t, use_y),
                                           (anchor_t, use_prox or use_dyn),
                                           (dyn_t, use_dyn)) if used]
                x_t = jax.tree.map(upd, x_t, g, *extra)
                return x_t, step_loss_mean(loss, am, n_act)

            x_t, losses = jax.lax.scan(step, packer.unflatten(x), batches_eh)
            return packer.flatten(x_t), losses

        local_phase = local_phase_flat if flat else local_phase_tree

        def group_round(carry, inp):
            """One group round e: local phase + group aggregation (lines 5-9)."""
            x, z, y, dyn, anchor, efc = carry
            if async_mode:
                # Iteration liveness joins the participation mask: a
                # straggler past its E_g rounds this window is frozen
                # exactly like an unsampled client (mask data, static
                # shape), so the group mean, z update and dissemination
                # below need no further gating.
                batches_eh, em = inp
                am = (em[:, None] * cmask if masked
                      else jnp.broadcast_to(em[:, None], (G, K)))
                n_act = jnp.maximum(jnp.sum(am), 1.0)
            else:
                if c_noise:
                    batches_eh, ek = inp
                else:
                    batches_eh = inp
                    ek = None
                am = cmask if masked else None
                n_act = n_active if masked else None
            x_end, losses = local_phase(x, z, y, dyn, anchor, batches_eh,
                                        am, n_act)

            # Upload view: compression first -- the wire carries the
            # dequantized delta, so corruption faults then rewrite (and
            # the defense screens) exactly what the group server would
            # reconstruct; clean/frozen clients keep their exact bits
            # either way (where-selects, never arithmetic).
            x_up = x_end
            if comp_c:
                delta = tu.tree_sub(x_end, x)
                u = tu.tree_add(delta, efc) if ef_c else delta
                deq = _cmp.roundtrip(
                    u, mode=comp.client_mode, lead_ndim=2,
                    frac=comp.topk_frac, key=ek, dispatch=comp_dispatch)
                x_cmp = tu.tree_add(x, deq)
                x_up = (tu.tree_select(am, x_cmp, x_end)
                        if am is not None else x_cmp)
            if f_corrupt:
                x_up = _flt.corrupt_uploads(x, x_up, fm.corrupt * am, faults)
            if defended:
                x_up, ok = _flt.screen_and_clip(x, x_up, defense)
                smask = am * ok
                scr = jnp.sum(am) - jnp.sum(smask)
                n_srv = jnp.maximum(jnp.sum(smask), 1.0)
            else:
                smask = am
                n_srv = n_act
            # Correction-state view: z is client-side state -- the client
            # updates it from its *own* local model plus the broadcast it
            # receives -- so the error-feedback residual re-applied on the
            # wire must never enter z (feeding released residual mass back
            # through the correction destabilizes EF). Uncompressed, the
            # wire view is the local model and the legacy program is
            # untouched, screening and clipping included.
            x_loc = x_up
            if comp_c:
                x_loc = x_end
                if f_corrupt:
                    x_loc = _flt.corrupt_uploads(x, x_loc, fm.corrupt * am,
                                                 faults)
            if ef_c:
                # Residual carries forward only for contributions that
                # entered the aggregate: a screened or inactive client
                # leaves its error-feedback state untouched.
                err = tu.tree_sub(u, deq)
                efc = (tu.tree_select(smask, err, efc)
                       if smask is not None else err)

            # Group aggregation (line 8): xbar_j = mean over (active,
            # surviving) clients (realized-count or expected-count
            # denominator per weighting).
            if smask is not None:
                xbar = tu.tree_masked_mean(x_up, smask, axis=1,
                                           denom=cdenom)            # [G, ...]
            else:
                xbar = tu.tree_mean(x_up, axis=1)                   # [G, ...]
            xbar_b = tu.tree_broadcast_to_axis(xbar, 1, K)          # [G, K, ...]

            diff = tu.tree_sub(x_up, xbar_b)
            if smask is not None:
                drift = tu.tree_masked_sq_norm(diff, smask) / n_srv
            else:
                drift = tu.tree_sq_norm(diff) / (G * K)

            # Client-group correction update (line 9):
            #   z_i += (x_{i,H} - xbar_j) / (H * lr)
            # Gated on the screen mask: a screened contribution never
            # integrates into the correction state.
            if use_z:
                z_new = jax.tree.map(
                    lambda zi, xe, xb: zi + (xe - xb) / (H * lr), z, x_loc, xbar_b
                )
                z = tu.tree_select(smask, z_new, z) if smask is not None else z_new
            # Model dissemination: every active client restarts from the
            # group model; inactive clients stay frozen. Under the defense,
            # active-but-screened clients also download -- that is what
            # heals a corrupted client -- unless the group has no surviving
            # contribution at all (its hardened mean is an exact, unusable
            # zero), in which case the group's active clients revert to
            # their group-round start model: a screened upload must never
            # survive in a replica, or the global recovery mean would
            # integrate it anyway (`x` still holds the round-start
            # replicas here; for frozen clients it is bit-identical to
            # x_up, so only the fully-screened case changes).
            if smask is None:
                x = xbar_b
            elif defended:
                has_srv = (jnp.sum(smask, axis=1) > 0).astype(jnp.float32)
                x = tu.tree_select(am * has_srv[:, None], xbar_b, x)
            else:
                x = tu.tree_select(am, xbar_b, x_up)
            out = (losses, drift, scr) if defended else (losses, drift)
            return (x, z, y, dyn, anchor, efc), out

        # --- Round initialization (lines 2-4) ---------------------------
        # Group model init is implicit: params enter equal across clients.
        if use_z:
            if cfg.correction_init == "zero":
                # Footnote 2: experiments initialize z = 0 each round
                # (participants only -- frozen clients keep their z).
                if async_mode:
                    # Generalized per report cycle: only groups starting
                    # from a fresh download reset; mid-cycle stragglers
                    # keep accumulating z across windows.
                    zmask = (fresh[:, None] * cmask if masked
                             else jnp.broadcast_to(fresh[:, None], (G, K)))
                    z = tu.tree_select(zmask, tu.tree_zeros_like(z), z)
                else:
                    z0 = tu.tree_zeros_like(z)
                    z = tu.tree_select(cmask, z0, z) if masked else z0
            else:
                # Theoretical init (line 3): z_i = -g_i + mean_group g_i,
                # evaluated with the first local batch xi_{i,0}^{t,0}.
                b00 = jax.tree.map(lambda b: b[0, 0], batches)
                _, g0 = _client_grads(loss_fn, as_tree(x), b00)
                if flat:
                    g0 = packer.flatten(g0)
                if partial:
                    g0m = tu.tree_broadcast_to_axis(
                        tu.tree_masked_mean(g0, cmask, axis=1, denom=cdenom),
                        1, K)
                    z = tu.tree_select(cmask, tu.tree_sub(g0m, g0), z)
                else:
                    g0m = tu.tree_broadcast_to_axis(tu.tree_mean(g0, axis=1), 1, K)
                    z = tu.tree_sub(g0m, g0)
        if use_y and cfg.correction_init == "gradient":
            is_first = state.round == 0
            if partial:
                # Gate on actual activity, not mere reachability: a group
                # whose client draws all came up empty must keep y frozen
                # and stay out of the global mean (its masked group mean
                # would fall back to garbage batches).
                gact0 = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)

            def grad_init_y(y):
                b00 = jax.tree.map(lambda b: b[0, 0], batches)
                _, g0 = _client_grads(loss_fn, as_tree(x), b00)
                if flat:
                    g0 = packer.flatten(g0)
                if partial:
                    gj = tu.tree_masked_mean(g0, cmask, axis=1,
                                             denom=cdenom)         # [G, ...]
                    gg = (tu.tree_masked_mean(gj, gmask, axis=0, denom=gdenom)
                          if ht else
                          tu.tree_masked_mean(gj, gact0, axis=0))  # [...]
                else:
                    gj = tu.tree_mean(g0, axis=1)                  # [G, ...]
                    gg = tu.tree_mean(gj, axis=0)                  # [...]
                return jax.tree.map(lambda gjj, ggg: ggg - gjj, gj, gg)

            y_init = grad_init_y(y)
            if partial:
                y_init = tu.tree_select(gact0, y_init, y)
            y = jax.tree.map(
                lambda yg, yo: jnp.where(is_first, yg, yo), y_init, y
            )

        anchor = x  # group-round-start model (FedProx / FedDyn reference)

        # Error-feedback residuals ride the scan carry; disabled links
        # carry None (zero pytree leaves -- the traced program is the
        # legacy one, bit for bit).
        efc = state.efc if ef_c else None
        if ef_c and efc is None:
            raise ValueError(
                "client-link error feedback carries per-client residuals "
                "in the state: build it with hfl_init(..., ef_client=True) "
                "(repro.api.build does this for you)")

        # --- E group rounds (lines 5-9) ---------------------------------
        # Async windows scan the padded e_pad = max(E_g) iterations and
        # feed the static per-group iteration mask alongside the batches;
        # stochastic client compression feeds one noise key per group round.
        if async_mode:
            scan_xs = (batches, em_all)
        elif c_noise:
            scan_xs = (batches, jax.random.split(kc, E))
        else:
            scan_xs = batches
        if flat:
            # y, dyn and anchor are constant across the E group rounds:
            # close over them instead of threading parameter-sized flat
            # buffers through the scan carry (loop-invariant constants
            # instead of per-iteration carry traffic).
            def group_round_flat(carry, inp):
                xc, zc, ec = carry
                (xc, zc, _, _, _, ec), out = group_round(
                    (xc, zc, y, dyn, anchor, ec), inp)
                return (xc, zc, ec), out

            (x, z, efc), scan_out = jax.lax.scan(
                group_round_flat, (x, z, efc), scan_xs)
        else:
            (x, z, y, dyn, _, efc), scan_out = jax.lax.scan(
                group_round, (x, z, y, dyn, anchor, efc), scan_xs
            )
        if defended:
            losses, drifts, scrs = scan_out
            screened = jnp.sum(scrs)
        else:
            losses, drifts = scan_out
            screened = jnp.zeros((), jnp.float32)

        # --- Global aggregation (line 10) --------------------------------
        efg = state.efg if ef_g else None
        if ef_g and efg is None:
            raise ValueError(
                "group-link error feedback carries per-group residuals in "
                "the state: build it with hfl_init(..., ef_group=True) "
                "(repro.api.build does this for you)")

        def compress_group(xbar_j, gref, gact):
            """Compress each group's report delta against its round-start
            model -- the reference both ends of the link share -- and
            where-select so non-reporting groups' recovered means keep
            their exact bits. Returns (xbar_j', u, deq) for the EF carry.
            """
            gdelta = tu.tree_sub(xbar_j, gref)
            ug = tu.tree_add(gdelta, efg) if ef_g else gdelta
            deqg = _cmp.roundtrip(
                ug, mode=comp.group_mode, lead_ndim=1,
                frac=comp.topk_frac, key=kg if comp_stoch else None,
                dispatch=comp_dispatch)
            xbar_c = tu.tree_add(gref, deqg)
            if gact is not None:
                xbar_c = tu.tree_select(gact, xbar_c, xbar_j)
            return xbar_c, ug, deqg

        if async_mode:
            # Staleness-aware merge of the groups reporting this window:
            # reports enter a weighted mean -- report cadence (rep) x policy
            # weight (dw) x the participation estimator -- and non-reporting
            # groups neither upload nor download (see core/staleness.py).
            if masked:
                gact = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)
                gup = jnp.sum(rep * gact)  # reports actually sent (pre-screen)
                # Recovery, not estimation: active replicas of group j all
                # hold the disseminated xbar_j from its last live iteration.
                xbar_j = tu.tree_masked_mean(x, cmask, axis=1)
                if defended and defense.screen_nonfinite:
                    # Backstop group-level screen: a recovered report that
                    # still carries non-finite bits never enters the merge
                    # (counts every active client it would have spoken for).
                    gfin = _flt.all_finite_mask(xbar_j, 1)
                    screened = screened + jnp.sum(
                        cmask * ((gact * (1.0 - gfin))[:, None]))
                    gact = gact * gfin
                obs = rep * gact
            else:
                xbar_j = jax.tree.map(lambda xi: xi[:, 0], x)
                obs = rep
                gup = jnp.sum(rep)
            if plan.needs_snapshots:
                if state.snap is None or state.glob is None:
                    raise ValueError(
                        "staleness='delay_compensated' carries per-group "
                        "download snapshots in the state: build it with "
                        "hfl_init(..., staleness_snapshots=True) "
                        "(repro.api.build does this for you)")
                # First-order delay compensation: shift a stale report by
                # the global progress its group missed since it last
                # downloaded (glob - snap_g; exactly zero for fresh groups).
                xbar_used = jax.tree.map(
                    lambda xj, gl, sn: xj + (jnp.expand_dims(gl, 0) - sn),
                    xbar_j, state.glob, state.snap)
            else:
                xbar_used = xbar_j

            w = rep * dw                        # [G] deterministic weights
            if partial and ht:
                # Horvitz-Thompson over reachable groups composed with the
                # deterministic report/policy weights: an empty reachable
                # report contributes an exact zero while the denominator
                # stays the expected reporting mass.
                wsum = w * gmask
                sup = wsum * gact
                den = (gdenom / G) * jnp.sum(w)
            elif masked:
                wsum = w * gact
                sup = wsum
                den_raw = jnp.sum(wsum)
                den = jnp.where(den_raw > 0, den_raw, 1.0)
            else:
                # >= 1 always: the pace-setting group (r_g = 1) reports
                # every window at full weight.
                wsum = w
                sup = wsum
                den = jnp.sum(w)

            def _stale_merge(v):
                live = tu.expand_mask(sup, v) != 0
                return jnp.sum(
                    jnp.where(live, v, 0) * tu.expand_mask(wsum, v),
                    axis=0) / den

            xbar = jax.tree.map(_stale_merge, xbar_used)
            gdrift = tu.tree_masked_sq_norm(
                tu.tree_sub(xbar_j, tu.tree_broadcast_to_axis(xbar, 0, G)), obs
            ) / jnp.maximum(jnp.sum(obs), 1.0)
        elif masked and (fault_mode or defended or comp_g):
            # The legacy recovery/estimation split of tree_group_global_mean,
            # opened up so group-timeout faults, the group-level finite
            # screen and group-link compression can compose into the
            # estimation mask between the two stages (recovery over active
            # replicas is unchanged).
            xbar_j = tu.tree_masked_mean(x, cmask, axis=1)
            gact = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)
            if f_timeout:
                # A timed-out group misses the global exchange entirely:
                # no upload, no y update, no download -- frozen this round.
                gact = gact * tm_keep
            gup = jnp.sum(gact)  # reports actually sent (pre-screen)
            if comp_g:
                # Compression happens at the upload, i.e. after the
                # timeout composition (a timed-out group never sent bytes,
                # so its residual must not advance) and before the finite
                # screen (the backstop screens the dequantized report).
                gref = tu.tree_masked_mean(state.params, cmask, axis=1)
                xbar_srv = xbar_j  # group server's own (pre-wire) aggregate
                xbar_j, ug, deqg = compress_group(xbar_j, gref, gact)
            if defended and defense.screen_nonfinite:
                gfin = _flt.all_finite_mask(xbar_j, 1)
                screened = screened + jnp.sum(
                    cmask * ((gact * (1.0 - gfin))[:, None]))
                gact = gact * gfin
            if ht:
                xbar_j0 = jax.tree.map(
                    lambda v: jnp.where(tu.expand_mask(gact, v) != 0, v, 0),
                    xbar_j)
                xbar = tu.tree_masked_mean(xbar_j0, gmask, axis=0,
                                           denom=gdenom)
            else:
                xbar = tu.tree_masked_mean(xbar_j, gact, axis=0)
            gdrift = tu.tree_masked_sq_norm(
                tu.tree_sub(xbar_j, tu.tree_broadcast_to_axis(xbar, 0, G)), gact
            ) / jnp.maximum(jnp.sum(gact), 1.0)
        elif partial:
            # A group with zero sampled clients never feeds the y update or
            # dissemination of its own replicas (gact gating). Under
            # realized-count weighting it is also renormalized out of the
            # global mean; under inverse_prob every *reachable* group enters
            # the Horvitz-Thompson sum, an empty one contributing zero --
            # see tree_group_global_mean for the recovery/estimation split.
            xbar_j, xbar, gact = tu.tree_group_global_mean(
                x, cmask, gmask if ht else None, gdenom)
            gup = jnp.sum(gact)
            gdrift = tu.tree_masked_sq_norm(
                tu.tree_sub(xbar_j, tu.tree_broadcast_to_axis(xbar, 0, G)), gact
            ) / jnp.maximum(jnp.sum(gact), 1.0)
        else:
            xbar_j = jax.tree.map(lambda xi: xi[:, 0], x)   # [G, ...] (clients equal)
            gup = jnp.float32(G)
            if comp_g:
                gref = jax.tree.map(lambda xi: xi[:, 0], state.params)
                xbar_srv = xbar_j  # group server's own (pre-wire) aggregate
                xbar_j, ug, deqg = compress_group(xbar_j, gref, None)
            xbar = tu.tree_mean(xbar_j, axis=0)             # [...]
            gdrift = tu.tree_sq_norm(
                tu.tree_sub(xbar_j, tu.tree_broadcast_to_axis(xbar, 0, G))
            ) / G

        if ef_g:
            # Gated on the FINAL estimation mask (post-timeout, post-
            # screen): only a report that entered the merge advances the
            # group's residual.
            errg = tu.tree_sub(ug, deqg)
            efg = tu.tree_select(gact, errg, efg) if masked else errg

        # Group-global correction update (line 11):
        #   y_j += (xbar_j^{t,E} - xbar^{t+1}) / (H * E * lr)
        if use_y:
            if async_mode:
                # Per report cycle: a reporting group ran E_g * r_g group
                # rounds since its last download. The policy discount dw
                # applies to the *merge* only -- y is a tracking estimator
                # and must update at full rate, or a transient y decays
                # geometrically (factor 1 - dw/G per report) and its bias
                # dominates the trajectory (see core/staleness.py).
                coef = 1.0 / (e_eff * H * lr)                         # [G]
                xbar_g = tu.tree_broadcast_to_axis(xbar, 0, G)
                y_new = jax.tree.map(
                    lambda yj, xj, xg: yj + tu.expand_mask(coef, yj) * (xj - xg),
                    y, xbar_used, xbar_g)
                y = tu.tree_select(obs, y_new, y)
            else:
                # Like z above, y is group-server-side state: it updates
                # from the group's own aggregate (pre-wire), never from
                # the dequantized view carrying the EF residual.
                y_src = xbar_srv if comp_g else xbar_j
                y_new = jax.tree.map(
                    lambda yj, xj, xg: yj + (xj - xg) / (H * E * lr), y, y_src, xbar
                )
                y = tu.tree_select(gact, y_new, y) if masked else y_new

        # FedDyn gradient-memory update (per client, after its local work).
        if use_dyn:
            dyn_new = jax.tree.map(
                lambda mi, xi, ai: mi - cfg.feddyn_alpha * (xi - ai), dyn, x, anchor
            )
            dyn = tu.tree_select(cmask, dyn_new, dyn) if masked else dyn_new

        # Dissemination: active clients restart from the (server-lr) global
        # model; frozen clients keep what they have.
        if cfg.server_lr != 1.0:
            if partial:
                # No stored global model under partial participation: anchor
                # the server step on the mean over all replicas.
                prev = tu.tree_mean(state.params, axis=(0, 1))
            else:
                prev = jax.tree.map(lambda xi: xi[0, 0], state.params)
            xbar = jax.tree.map(lambda p, xb: p + cfg.server_lr * (xb - p), prev, xbar)
        x_glob = jax.tree.map(
            lambda xg: jnp.broadcast_to(xg, (G, K) + xg.shape), xbar
        )
        if async_mode:
            if fault_mode or defended:
                # Reporting groups download only when the window actually
                # aggregated something: with the defense decoupling "has
                # active clients" from "entered the merge", a window whose
                # every report was screened must not disseminate its
                # hardened (exact-zero) merge.
                any_obs = (jnp.sum(obs) > 0).astype(jnp.float32)
                dmask = rep[:, None] * cmask * any_obs
            elif masked:
                # Only reporting groups download; stragglers keep their
                # mid-cycle replicas (that lag is exactly what makes their
                # next report stale).
                dmask = rep[:, None] * cmask
            else:
                dmask = jnp.broadcast_to(rep[:, None], (G, K))
            x = tu.tree_select(dmask, x_glob, x)
        else:
            if fault_mode or defended:
                # Timed-out groups miss the download too (frozen), and no
                # one downloads a global mean with zero surviving groups.
                any_g = (jnp.sum(gact) > 0).astype(jnp.float32)
                dm = cmask * any_g
                if f_timeout:
                    dm = dm * tm_keep[:, None]
                x = tu.tree_select(dm, x_glob, x)
            elif masked:
                x = tu.tree_select(cmask, x_glob, x)
            else:
                x = x_glob

        snap, glob = state.snap, state.glob
        if async_mode and plan.needs_snapshots:
            # Reporting groups record the global model they just
            # downloaded; the server records it as the latest global
            # (guarded: a window where every reporter came up empty under
            # partial participation aggregates nothing).
            any_obs = (jnp.sum(obs) > 0).astype(jnp.float32)
            snap = tu.tree_select(
                obs, tu.tree_broadcast_to_axis(xbar, 0, G), snap)
            glob = tu.tree_select(any_obs, xbar, glob)

        dl = state.dl
        if async_mode and f_timeout:
            # Realized downloads this window (rep already excludes timed-out
            # groups): next round's freshness for the z re-init.
            dl = rep * any_obs

        # Bytes on the wire: every upload actually sent this round counts
        # (screened uploads spent their bytes; crashed/unsampled clients
        # and timed-out groups sent none).
        if async_mode:
            n_up_c = (jnp.sum(em_all[:, :, None] * cmask[None])
                      if masked else jnp.sum(em_all) * K)
        else:
            n_up_c = (E * jnp.sum(cmask) if masked
                      else jnp.float32(E * G * K))
        comm = _cmp.round_comm_bytes(state.params, comp, n_up_c, gup)

        metrics = RoundMetrics(
            loss=losses,
            client_drift=drifts,
            group_drift=gdrift,
            z_norm=tu.tree_sq_norm(z) / (G * K),
            y_norm=tu.tree_sq_norm(y) / G,
            participation=(jnp.sum(cmask) / (G * K)) if masked
            else jnp.ones((), jnp.float32),
            screened=screened,
            comm_bytes=comm,
        )
        new_state = HFLState(
            params=x, z=z, y=y, dyn=dyn, rng=rng, round=state.round + 1,
            snap=snap, glob=glob, dl=dl,
            efc=efc if ef_c else state.efc,
            efg=efg if ef_g else state.efg,
        )
        return new_state, metrics

    return global_round


def global_model(state: HFLState) -> PyTree:
    """The current global model xbar (all clients are equal between rounds).

    Under partial participation frozen replicas may hold stale params, so
    index a client that certainly received the last dissemination is not
    statically known; callers tracking the exact global model under partial
    participation should average active replicas via the round's masks.
    Between full-participation rounds every replica is the global model.
    Flat states are unpacked back into the model tree.
    """
    return as_tree(jax.tree.map(lambda x: x[0, 0], state.params))
