"""repro.core -- the paper's contribution: MTGC and its HFL baselines.

New code should construct experiments through the unified front door,
``repro.api`` (``ExperimentSpec`` -> ``build`` -> ``fit``); the
constructors below remain the stable low-level surface (the three
``make_*_round`` entry points are delegating shims over the api
adapters).

Public API:
  HFLConfig, HFLState, hfl_init, make_global_round, global_model
  ScaffoldState, scaffold_init, make_scaffold_round
  MultiLevelState, multilevel_init, make_multilevel_round
  Packer, FlatBuffers, make_packer, as_tree (flat-state plumbing)
  PackedBatches, run_rounds, make_round_step (compiled horizon driver)
  PopulationStore, run_population_rounds, stateless_round (virtual clients)
  FaultPlan, DefensePlan, GuardSpec (fault injection / self-healing horizon)
"""
from repro.core.config import HFLConfig
from repro.core.driver import (
    GuardReport,
    GuardSpec,
    Horizon,
    PackedBatches,
    dispatch_chunk,
    make_round_step,
    pack_client_shards,
    pack_lm_shards,
    run_rounds,
    select_round,
)
from repro.core.faults import (
    FAULT_KINDS,
    DefensePlan,
    FaultMasks,
    FaultPlan,
    fault_masks,
)
from repro.core.engine import HFLState, RoundMetrics, global_model, hfl_init, make_global_round
from repro.core.multilevel import (
    MultiLevelState,
    make_multilevel_round,
    multilevel_global_model,
    multilevel_init,
)
from repro.core.packer import FlatBuffers, Packer, as_tree, is_flat, make_packer
from repro.core.participation import ParticipationMasks, round_masks, sample_hfl_masks
from repro.core.population import (
    PopulationStore,
    draw_cohort,
    population_fields,
    run_population_rounds,
    stateless_round,
)
from repro.core.scaffold import ScaffoldState, make_scaffold_round, scaffold_init

ALGORITHMS = ("mtgc", "hfedavg", "local_corr", "group_corr", "fedprox", "feddyn")

__all__ = [
    "ALGORITHMS",
    "HFLConfig",
    "FlatBuffers",
    "Packer",
    "as_tree",
    "is_flat",
    "make_packer",
    "ParticipationMasks",
    "round_masks",
    "sample_hfl_masks",
    "HFLState",
    "RoundMetrics",
    "global_model",
    "hfl_init",
    "make_global_round",
    "FAULT_KINDS",
    "DefensePlan",
    "FaultMasks",
    "FaultPlan",
    "fault_masks",
    "GuardReport",
    "GuardSpec",
    "Horizon",
    "PackedBatches",
    "dispatch_chunk",
    "make_round_step",
    "pack_client_shards",
    "pack_lm_shards",
    "run_rounds",
    "select_round",
    "PopulationStore",
    "draw_cohort",
    "population_fields",
    "run_population_rounds",
    "stateless_round",
    "MultiLevelState",
    "make_multilevel_round",
    "multilevel_global_model",
    "multilevel_init",
    "ScaffoldState",
    "make_scaffold_round",
    "scaffold_init",
]
