"""Per-round participation sampling (partial client / group availability).

The paper's experiments assume full participation; real hierarchical
deployments sample a subset of clients -- and sometimes whole groups (cell
towers, hospital networks) -- each round. Masks are *data*, not structure:
the engines stay fully jittable, inactive replicas simply have their
updates gated out with ``where`` and every aggregation becomes a masked
mean (see ``core.tree``).

Masks are drawn from the engine state's PRNG key, so a host data pipeline
can call :func:`round_masks` with ``state.rng`` *before* the round to skip
packing batches for inactive clients -- it reproduces exactly the masks the
jitted round function derives internally.

Weighting (``cfg.participation_weighting``): masked aggregations can either
divide by the *realized* participant count (``"none"``, the historical
behaviour) or by the *expected* count ``inclusion_prob * n``
(``"inverse_prob"``, a Horvitz-Thompson estimator). Under Bernoulli
(``uniform``) sampling the realized-count mean is unbiased only for a
single aggregation of mask-independent values; once the aggregate feeds
back into the next timescale (E group rounds per global round, the z / y
control-variable updates) its count randomness compounds into a systematic
bias of the tracked group/global averages. ``inverse_prob`` replaces the
random denominator with the fixed expected count: the one-shot aggregate
becomes exactly unbiased (empty draws legitimately contribute zero instead
of renormalizing), and the MTGC corrections absorb -- rather than compound
-- the remaining dissemination noise (gated by tests/test_weighting.py).
Under ``fixed`` sampling the realized count *is* the expected count, so the
two weightings coincide there. The price of ``inverse_prob`` is variance: a
round with fewer participants than expected disseminates a down-scaled
aggregate (see the bias/variance section of benchmarks/fig_participation).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MODES = ("uniform", "fixed")
WEIGHTINGS = ("none", "inverse_prob")


class ParticipationMasks(NamedTuple):
    """0/1 float masks for one global round.

    group:  [G]    -- group j is reachable this round.
    client: [G, K] -- client (j, i) is active (already gated by its group).
    """

    group: jax.Array
    client: jax.Array


def fixed_count(frac: float, n: int) -> int:
    """Participants per parent under 'fixed' sampling: never zero.

    Nearest count with half-up tie-breaking: Python's ``round`` is
    banker's rounding (``round(2.5) == 2``), which would give 2 of 5
    participants at ``frac=0.5`` instead of the documented nearest count 3.
    """
    return max(1, int(frac * n + 0.5))


def inclusion_prob(frac: float, n: int, mode: str) -> float:
    """Per-unit inclusion probability of :func:`sample_axis_mask`.

    'uniform' draws each unit independently with probability ``frac``;
    'fixed' includes exactly ``fixed_count(frac, n)`` of ``n`` units, so
    each unit is included with probability ``fixed_count / n`` (and the
    realized count always equals the expected count -- inverse-probability
    weighting coincides with realized-count weighting in that mode).
    """
    if frac >= 1.0:
        return 1.0
    if mode == "uniform":
        return float(frac)
    if mode == "fixed":
        return fixed_count(frac, n) / n
    raise ValueError(f"unknown participation mode {mode!r}")


def sample_axis_mask(key: jax.Array, shape: tuple, frac: float, mode: str) -> jax.Array:
    """0/1 float mask of ``shape``; the last axis is the sampled population.

    'uniform': independent Bernoulli(frac) per entry -- a row may come up
    empty, which downstream code treats as a frozen (skipped) aggregation.
    'fixed': exactly ``fixed_count(frac, shape[-1])`` ones per row, uniformly
    without replacement (rank the uniform draws and threshold).
    """
    if frac >= 1.0:
        return jnp.ones(shape, jnp.float32)
    u = jax.random.uniform(key, shape)
    if mode == "uniform":
        return (u < frac).astype(jnp.float32)
    if mode == "fixed":
        k = fixed_count(frac, shape[-1])
        rank = jnp.argsort(jnp.argsort(u, axis=-1), axis=-1)
        return (rank < k).astype(jnp.float32)
    raise ValueError(f"unknown participation mode {mode!r}")


def sample_hfl_masks(
    key: jax.Array,
    num_groups: int,
    clients_per_group: int,
    client_frac: float,
    group_frac: float,
    mode: str = "uniform",
) -> ParticipationMasks:
    """Two-level masks: group availability gates every client under it."""
    kg, kc = jax.random.split(key)
    gmask = sample_axis_mask(kg, (num_groups,), group_frac, mode)
    cmask = sample_axis_mask(
        kc, (num_groups, clients_per_group), client_frac, mode
    ) * gmask[:, None]
    return ParticipationMasks(group=gmask, client=cmask)


def round_masks(rng: jax.Array, cfg) -> tuple[ParticipationMasks, jax.Array]:
    """(masks for the upcoming round, carried key) from a state's ``rng``.

    The engine consumes the key the same way, so host-side batch packing and
    the jitted round agree on who participates without any side channel.
    """
    mkey, next_rng = jax.random.split(rng)
    masks = sample_hfl_masks(
        mkey, cfg.num_groups, cfg.clients_per_group,
        cfg.client_participation, cfg.group_participation,
        cfg.participation_mode,
    )
    return masks, next_rng
