"""Pytree algebra used by every HFL algorithm.

All hierarchical-FL state in this framework is *stacked*: each leaf carries
leading "topology" axes (e.g. ``[G, K, ...]`` = groups x clients-per-group).
These helpers implement the handful of algebraic primitives Algorithm 1
needs -- axpy-style updates, means over leading axes, and broadcasts -- so
the algorithm files read like the paper's pseudocode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PyTree = object


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_mean(a: PyTree, axis) -> PyTree:
    """Mean over one or more leading axes (group/client aggregation)."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=axis), a)


def expand_mask(mask: jax.Array, x: jax.Array) -> jax.Array:
    """Right-pad a leading-axes mask with unit dims so it broadcasts to x."""
    return mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))


def tree_select(mask: jax.Array, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise where(mask != 0, a, b); mask covers the leading topology axes.

    The unselected branch never propagates (frozen replicas keep their exact
    bits even if the rejected update is NaN from a dummy batch).
    """
    return jax.tree.map(
        lambda ai, bi: jnp.where(expand_mask(mask, ai) != 0, ai, bi), a, b
    )


def tree_masked_mean(a: PyTree, mask: jax.Array, axis: int,
                     denom: float | None = None) -> PyTree:
    """Mean over ``axis`` counting only entries with mask != 0.

    ``mask`` spans the leading topology axes of every leaf.

    With ``denom=None`` (realized-count weighting) the masked sum is
    divided by the number of active entries; slices with no active entries
    return exact zeros (masked sum 0 over a clamped count of 1) -- callers
    gate those slices out downstream (their activity indicator is zero), so
    the value is never observed, it just keeps the program NaN-free even
    when *every* contribution in a slice was screened out or carries
    non-finite bits (gated by the all-empty-group freeze tests in
    tests/test_weighting.py and the empty-slice test in
    tests/test_participation.py).

    With a fixed ``denom`` (inverse-probability weighting: the *expected*
    active count ``inclusion_prob * axis_size``, see
    ``participation.inclusion_prob``) the masked sum is divided by that
    constant instead: the Horvitz-Thompson estimator of the full mean. No
    fallback is needed -- an all-empty slice legitimately estimates zero
    (its realizations are part of what makes the estimator unbiased), and
    callers still gate state updates on the activity indicator.

    Masked-out entries go through ``where`` (not multiplication) either
    way, so non-finite values in frozen replicas cannot poison the
    aggregate.
    """
    if denom is not None:
        def _ht(x):
            w = expand_mask(mask, x) != 0
            return jnp.sum(jnp.where(w, x, 0), axis=axis) / denom

        return jax.tree.map(_ht, a)

    cnt = jnp.sum(mask, axis=axis)
    dn = jnp.maximum(cnt, 1)

    def _m(x):
        w = expand_mask(mask, x) != 0
        s = jnp.sum(jnp.where(w, x, 0), axis=axis)
        return s / expand_mask(dn, s)

    return jax.tree.map(_m, a)


def tree_group_global_mean(x: PyTree, cmask: jax.Array,
                           gmask: jax.Array | None = None,
                           gdenom: float | None = None):
    """Global aggregate of disseminated ``[G, K, ...]`` replicas under
    partial participation (Alg. 1 line 10 as both round engines compute it).

    Axis 1 is *recovery*, not estimation: every active replica of group j
    holds the identical disseminated xbar_j (whose own weighting was
    applied when it was produced at the last group aggregation), so the
    realized-count mean reads it back exactly under either weighting --
    a fixed denominator here would double-scale. Axis 0 is estimation:
    with ``gdenom=None`` the realized-count mean over groups with at least
    one active client; with a fixed ``gdenom`` (inverse-probability
    weighting: expected reachable-group count) the Horvitz-Thompson sum
    over the *reachable*-group mask ``gmask``, an empty reachable group
    contributing an exact zero (``where``, not multiplication -- an empty
    group's recovered mean is an exact zero, never an unmasked mean over
    possibly non-finite frozen replicas).

    Returns ``(xbar_j [G, ...], xbar [...], gact [G])``.
    """
    gact = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)
    xbar_j = tree_masked_mean(x, cmask, axis=1)
    if gdenom is None:
        return xbar_j, tree_masked_mean(xbar_j, gact, axis=0), gact
    xbar_j0 = jax.tree.map(
        lambda v: jnp.where(expand_mask(gact, v) != 0, v, 0), xbar_j)
    xbar = tree_masked_mean(xbar_j0, gmask, axis=0, denom=gdenom)
    return xbar_j, xbar, gact


def tree_masked_sq_norm(a: PyTree, mask: jax.Array):
    """||a||^2 restricted to entries with mask != 0 on the leading axes."""
    zeroed = jax.tree.map(lambda x: jnp.where(expand_mask(mask, x) != 0, x, 0), a)
    return tree_sq_norm(zeroed)


def tree_broadcast_to_axis(a: PyTree, axis: int, size: int) -> PyTree:
    """Insert a broadcasted leading axis (dissemination after aggregation).

    Uses ``broadcast_to`` (a view under XLA fusion), not ``tile``: tiling
    materialized ``size`` parameter-sized copies of the aggregate at every
    dissemination.
    """

    def _b(x):
        x = jnp.expand_dims(x, axis)
        shape = list(x.shape)
        shape[axis] = size
        return jnp.broadcast_to(x, shape)

    return jax.tree.map(_b, a)


def tree_dot(a: PyTree, b: PyTree):
    """Global inner product <a, b> (used by FedDyn's regularizer tests)."""
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(a: PyTree):
    return tree_dot(a, a)


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(lambda x, y: jnp.allclose(x, y, rtol=rtol, atol=atol), a, b)
    return bool(jax.tree.reduce(jnp.logical_and, oks))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)
