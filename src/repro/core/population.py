"""Virtual client population: O(cohort) device state for million-client HFL.

Every engine materializes its per-client state as ``[G, K, ...]`` device
buffers, so K -- the number of clients -- is a compile-time shape bounded
by device memory. Production FL is the opposite regime: a server *samples*
a small cohort from a huge population each round. This module decouples
the two: K stays the compiled cohort shape, while the population lives in
a host-side store holding only what genuinely persists per client -- the
multi-timescale corrections ``z`` (and FedDyn's gradient memory ``dyn``).
Per-client ``params`` need no store: every participant re-downloads the
global model at dissemination, so a client entering a cohort starts from
the current global model plus its persistent correction.

The store reuses the :class:`~repro.core.packer.Packer` segment table: per
persistent field, one contiguous numpy buffer per dtype with leading axes
``[G, P]`` (``P`` virtual clients per group). Each driver chunk then runs

    gather -> fused round(s) -> scatter

gather the sampled cohort's rows into the existing flat ``[G, K, N]``
device buffer, dispatch the unchanged compiled chunk, scatter the updated
rows back. With ``overlap=True`` the host half double-buffers against the
device half: JAX dispatch is asynchronous, so while the device scans a
chunk the host draws the *next* cohort and pre-gathers its rows, then
after syncing scatters the finished cohort and patches only the staged
rows the two cohorts share -- the gather/scatter cost hides behind
compute (measured in ``benchmarks/bench_population.py``).

Cohort draws follow the ``round_masks`` key discipline (split the state
rng once per draw, fold per group) -- except in the degenerate
``population == cohort`` case, where every client is materialized, no
draw happens, and the rng is left untouched: the cohort path is then
bit-exact against the materialized engines (gated in
tests/test_population.py).

Stateless clients (``client_state="stateless"``) need no store at all:
:func:`stateless_round` zero-initializes the persistent fields at every
round boundary, the assumption large-cohort FL systems make.

Front door: set ``ExperimentSpec.population`` / ``cohort_size`` /
``client_state`` and ``repro.api.fit`` routes through
:func:`run_population_rounds` automatically.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tu
from repro.core.driver import (
    Horizon,
    PackedBatches,
    RoundFn,
    dispatch_chunk,
    eval_mask_for_chunk,
)
from repro.core.packer import FlatBuffers, Packer, is_flat, make_packer

PyTree = Any

HostBuffers = dict[str, dict[str, np.ndarray]]  # field -> dtype key -> [G,P,N]


def population_fields(algorithm: str) -> tuple[str, ...]:
    """Which state fields persist per client for this algorithm.

    ``z`` (the client->group correction) persists for every correction
    algorithm; FedDyn additionally carries its per-client gradient memory
    ``dyn``. Fields absent from a given state type (the sharded state has
    no ``dyn``) are skipped at store construction.
    """
    return ("z", "dyn") if algorithm == "feddyn" else ("z",)


def draw_cohort(key: jax.Array, num_groups: int, population: int,
                cohort: int) -> np.ndarray:
    """Sample one cohort: ``[G, cohort]`` distinct client ids per group.

    One subkey per group (same fold discipline as ``round_masks``), each
    drawing ``cohort`` ids from ``population`` without replacement.
    """
    keys = jax.random.split(key, num_groups)
    return np.stack([
        np.asarray(jax.random.choice(k, population, (cohort,), replace=False))
        for k in keys
    ])


class PopulationStore:
    """Host-side per-client persistent state for ``P`` virtual clients/group.

    data: per persistent field, one contiguous numpy buffer per dtype with
        shape ``[G, P, N_dtype]`` -- the ``Packer`` segment table of the
        corresponding state field, with the cohort axis widened to the
        population. New clients start at zero, exactly like a freshly
        initialized materialized state.
    packers / flat: per-field segment table and whether the *state* holds
        that field as :class:`FlatBuffers` (gathers then install buffers
        directly) or as a template tree (gathers unflatten through the
        table).

    Registered as a pytree whose leaves are the numpy buffers, so
    ``checkpoint.save`` / ``restore`` round-trip a ``{"state": ...,
    "population": store}`` tree with no special casing; unflattening
    coerces leaves back to host numpy so in-place scatter keeps working
    on a restored store.
    """

    __slots__ = ("fields", "num_groups", "population", "packers", "flat",
                 "data")

    def __init__(self, fields: tuple[str, ...], num_groups: int,
                 population: int, packers: dict[str, Packer],
                 flat: dict[str, bool], data: HostBuffers):
        self.fields = tuple(fields)
        self.num_groups = int(num_groups)
        self.population = int(population)
        self.packers = dict(packers)
        self.flat = dict(flat)
        self.data = data

    @classmethod
    def from_state(cls, state: PyTree, population: int,
                   fields: tuple[str, ...] = ("z",)) -> "PopulationStore":
        """Build a zeroed store matching ``state``'s persistent fields.

        ``state`` is any engine state whose ``fields`` carry ``[G, K,
        ...]`` leading axes (FlatBuffers or tree layout); fields the state
        type lacks (or holds as None) are dropped.
        """
        present = tuple(f for f in fields
                        if getattr(state, f, None) is not None)
        if not present:
            raise ValueError(
                f"state has none of the persistent fields {fields!r}")
        packers: dict[str, Packer] = {}
        flat: dict[str, bool] = {}
        num_groups = None
        for f in present:
            value = getattr(state, f)
            if is_flat(value):
                packers[f] = value.packer
                flat[f] = True
                lead = value.lead_shape
            else:
                leaves = jax.tree.leaves(value)
                template = jax.tree.map(lambda x: x[0, 0], value)
                packers[f] = make_packer(template)
                flat[f] = False
                lead = leaves[0].shape[:2]
            if len(lead) != 2:
                raise ValueError(
                    f"field {f!r} needs [G, K, ...] leading axes, got lead "
                    f"shape {lead}")
            num_groups = lead[0]
            if population < lead[1]:
                raise ValueError(
                    f"population ({population}) < materialized cohort "
                    f"({lead[1]})")
        data: HostBuffers = {
            f: {key: np.zeros((num_groups, population, n), np.dtype(key))
                for key, n in packers[f].buffer_sizes}
            for f in present
        }
        store = cls(present, num_groups, population, packers, flat, data)
        # Seed rows [0, K) from the state's current values (identity
        # mapping): a fresh state scatters zeros (no-op), while a resumed
        # mid-training state keeps its corrections instead of having them
        # silently zeroed by the first cohort install. The store is
        # authoritative from here on.
        cohort = store.cohort_of(state)
        idx = np.broadcast_to(np.arange(cohort), (num_groups, cohort))
        store.scatter(idx, store.extract(state))
        return store

    # -------------------------------------------------- host <-> device

    def gather(self, idx: np.ndarray) -> HostBuffers:
        """Copy the cohort rows ``idx [G, K]`` out of the store (host)."""
        rows = np.arange(self.num_groups)[:, None]
        return {
            f: {key: buf[rows, idx] for key, buf in bufs.items()}
            for f, bufs in self.data.items()
        }

    def scatter(self, idx: np.ndarray, host_vals: HostBuffers) -> None:
        """Write the cohort rows back into the store, in place."""
        rows = np.arange(self.num_groups)[:, None]
        for f, bufs in host_vals.items():
            for key, arr in bufs.items():
                self.data[f][key][rows, idx] = arr

    def refresh(self, staged: HostBuffers, idx_new: np.ndarray,
                idx_old: np.ndarray) -> None:
        """Re-read staged rows that ``idx_old``'s scatter just updated.

        The overlapped driver pre-gathers the next cohort while the device
        is still training the current one; rows shared between the two
        cohorts are stale in that staging copy. Patch exactly those rows
        from the (now freshly scattered) store, in place.
        """
        for g in range(self.num_groups):
            stale = np.isin(idx_new[g], idx_old[g])
            if not stale.any():
                continue
            rows = idx_new[g][stale]
            for f, bufs in staged.items():
                for key, arr in bufs.items():
                    arr[g, stale] = self.data[f][key][g, rows]

    def install(self, state: PyTree, staged: HostBuffers) -> PyTree:
        """Replace the state's persistent fields with staged cohort rows."""
        updates = {}
        for f in self.fields:
            bufs = {key: jnp.asarray(arr) for key, arr in staged[f].items()}
            value = FlatBuffers(bufs, self.packers[f])
            updates[f] = value if self.flat[f] else value.to_tree()
        return state._replace(**updates)

    def extract(self, state: PyTree) -> HostBuffers:
        """Pull the persistent fields off the device (blocks until ready)."""
        out: HostBuffers = {}
        for f in self.fields:
            value = getattr(state, f)
            if not self.flat[f]:
                value = self.packers[f].flatten(value)
            out[f] = {key: np.asarray(buf) for key, buf in value.bufs.items()}
        return out

    # -------------------------------------------------------- reporting

    def cohort_of(self, state: PyTree) -> int:
        """The materialized cohort size K of this state's leading axes."""
        value = getattr(state, self.fields[0])
        lead = (value.lead_shape if is_flat(value)
                else jax.tree.leaves(value)[0].shape[:2])
        return int(lead[1])

    def state_bytes(self) -> int:
        """Host bytes of the full ``[G, P]`` population store."""
        return sum(
            self.packers[f].state_bytes((self.num_groups, self.population))
            for f in self.fields
        )

    def device_bytes(self, cohort: int) -> int:
        """Device bytes of the persistent fields at cohort size K."""
        return sum(
            self.packers[f].state_bytes((self.num_groups, cohort))
            for f in self.fields
        )

    def size_report(self, cohort: int | None = None) -> dict[str, Any]:
        """Segment-table size breakdown, host store vs device cohort."""
        report: dict[str, Any] = {
            "num_groups": self.num_groups,
            "population": self.population,
            "fields": {
                f: self.packers[f].size_report(
                    (self.num_groups, self.population))
                for f in self.fields
            },
            "host_bytes": self.state_bytes(),
        }
        if cohort is not None:
            report["cohort"] = int(cohort)
            report["device_bytes"] = self.device_bytes(cohort)
        return report

    def __repr__(self) -> str:
        return (f"PopulationStore(G={self.num_groups}, P={self.population}, "
                f"fields={self.fields}, bytes={self.state_bytes()})")


def _store_flatten_with_keys(store: PopulationStore):
    children = []
    for f in store.fields:
        for key in sorted(store.data[f]):
            path = jax.tree_util.DictKey(f"{f}.{key}")
            children.append((path, store.data[f][key]))
    aux = (store.fields, store.num_groups, store.population,
           tuple(sorted(store.packers.items())),
           tuple(sorted(store.flat.items())),
           tuple((f, tuple(sorted(store.data[f]))) for f in store.fields))
    return tuple(children), aux


def _store_flatten(store: PopulationStore):
    children, aux = _store_flatten_with_keys(store)
    return tuple(c for _, c in children), aux


def _store_unflatten(aux, children) -> PopulationStore:
    fields, num_groups, population, packers, flat, keys = aux
    it = iter(children)
    # np.asarray: restored leaves may arrive as device arrays; the store
    # must stay host numpy for in-place scatter.
    data = {f: {key: np.asarray(next(it)) for key in dtkeys}
            for f, dtkeys in keys}
    return PopulationStore(fields, num_groups, population, dict(packers),
                           dict(flat), data)


jax.tree_util.register_pytree_with_keys(
    PopulationStore, _store_flatten_with_keys, _store_unflatten,
    _store_flatten,
)


def stateless_round(round_fn: RoundFn,
                    fields: tuple[str, ...] = ("z", "dyn")) -> RoundFn:
    """Zero the persistent per-client fields at every round boundary.

    The stateless-client contract (``client_state="stateless"``): a cohort
    member arrives with no memory of earlier rounds, so ``z`` (and
    ``dyn``) start from zero each round and no population store is needed
    -- corrections act purely within-round. Fields the state lacks (or
    holds as None) pass through untouched. The wrapper is built once per
    engine so the driver's chunk-runner cache keys on a stable identity.
    """

    def wrapped(state, batches):
        resets = {
            f: tu.tree_zeros_like(getattr(state, f))
            for f in fields if getattr(state, f, None) is not None
        }
        return round_fn(state._replace(**resets), batches)

    return wrapped


def run_population_rounds(
    round_fn: RoundFn,
    state: PyTree,
    store: PopulationStore,
    data: PackedBatches,
    T: int,
    *,
    chunk: int | None = None,
    eval_every: int = 1,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None = None,
    donate: bool = True,
    overlap: bool = True,
) -> tuple[PyTree, PackedBatches, Horizon]:
    """``run_rounds`` over a virtual population: gather -> chunk -> scatter.

    Per driver chunk: draw a cohort of K (the state's materialized shape)
    from the store's P virtual clients per group, gather its persistent
    rows into the device state, dispatch the compiled chunk, scatter the
    updated rows back. A cohort is held fixed *within* a chunk (its rounds
    share one gather/scatter), so ``chunk`` trades cohort refresh rate
    against amortized transfer cost exactly as it already trades dispatch
    overhead.

    With ``overlap`` (default) the next cohort's draw + gather runs while
    the device scans the current chunk, and only the rows the consecutive
    cohorts share are re-read after the scatter -- the double-buffered
    path whose overhead ``benchmarks/bench_population.py`` gates under
    30% of round time. ``overlap=False`` is the strictly sequential
    baseline (bit-exact against the overlapped path; gated in
    tests/test_population.py).

    Degenerate ``P == K`` runs materialize everyone: no draws, rng
    untouched, bit-exact against ``run_rounds`` on the same round_fn.

    Returns ``(state, data, Horizon)`` with ``Horizon.population`` set to
    the store (mutated in place; returned for symmetry with ``data``).
    """
    assert T >= 1 and eval_every >= 1
    if chunk is not None and chunk < 0:
        raise ValueError(f"chunk must be None or >= 0, got {chunk}")
    chunk = T if not chunk else min(int(chunk), T)

    G, P = store.num_groups, store.population
    K = store.cohort_of(state)
    full = P == K
    rng = getattr(state, "rng", None)
    if not full and rng is None:
        raise ValueError(
            "virtual-population cohort draws need state.rng; initialize the "
            "state with an rng key")

    def draw() -> np.ndarray:
        nonlocal rng
        if full:
            return np.broadcast_to(np.arange(K), (G, K))
        ckey, rng = jax.random.split(rng)
        return draw_cohort(ckey, G, P, K)

    idx = draw()
    state = store.install(state, store.gather(idx))

    mets, evs, masks = [], [], []
    done = 0
    while done < T:
        n = min(chunk, T - done)
        mask = eval_mask_for_chunk(done, n, T, eval_every)
        state, data, metrics, ev = dispatch_chunk(
            round_fn, state, data, mask, eval_fn=eval_fn, donate=donate)
        done += n
        # The dispatch above is asynchronous: everything between here and
        # extract() runs on the host while the device scans the chunk.
        idx_next = staged_next = None
        if done < T:
            idx_next = draw()
            if overlap:
                staged_next = store.gather(idx_next)
        host_vals = store.extract(state)        # sync point
        store.scatter(idx, host_vals)
        if idx_next is not None:
            if overlap:
                store.refresh(staged_next, idx_next, idx)
            else:
                staged_next = store.gather(idx_next)
            state = store.install(state, staged_next)
            idx = idx_next
        mets.append(metrics)
        if eval_fn is not None:
            evs.append(ev)
        masks.append(mask)

    if not full:
        state = state._replace(rng=rng)

    def _cat(*xs):
        return np.concatenate([np.asarray(x) for x in xs])

    metrics = jax.tree.map(_cat, *mets)
    mask_all = np.concatenate(masks)
    eval_rounds = np.nonzero(mask_all)[0] + 1
    evals = None
    if eval_fn is not None:
        evals = jax.tree.map(lambda *xs: _cat(*xs)[mask_all], *evs)
    return state, data, Horizon(metrics, evals, eval_rounds, data, store)
