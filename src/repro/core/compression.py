"""Compressed hierarchical uploads: per-link quantization/sparsification
plans, error-feedback residuals, and bytes-on-the-wire accounting.

In a client-edge-cloud deployment the binding constraint is upload
bandwidth at each aggregation level, not FLOPs. This module makes the
two upload links first-class compression boundaries:

* **client -> group**: each active client uploads its local-phase delta
  ``x_end - x_start`` once per group round (E times per global round);
* **group -> global**: each reporting group uploads its aggregate delta
  ``xbar_g - x_start_g`` once per global round.

:class:`CompressionPlan` configures each link independently with one of
``none | bf16 | int8_stochastic | topk``:

* ``bf16`` -- deterministic truncation to bfloat16 (2 bytes/elem);
* ``int8_stochastic`` -- per-row scale ``amax(|u|)/127`` + stochastic
  rounding to int8 (1 byte/elem + one f32 scale per row), unbiased:
  ``E[deq] = u``;
* ``topk`` -- keep the ``ceil(topk_frac * N)`` largest-magnitude entries
  per row (8 bytes per kept entry: value + index), biased.

**Error feedback** (Seide et al. 2014; Karimireddy et al. 2019): with
``error_feedback=True`` each link carries a residual state field (``efc``
[G, K, ...] per client, ``efg`` [G, ...] per group). The link compresses
``u = delta + residual`` and carries ``residual' = u - Q(u)`` forward, so
compression error re-enters the next upload instead of accumulating as
bias -- the difference between topk converging and stalling. Residuals
update only for contributions that actually enter an aggregate: a
screened or inactive client/group leaves its residual untouched.

The engines apply a plan at exactly the seam ``corrupt_uploads`` /
``screen_and_clip`` use, *before* fault injection -- so the defense
screens the dequantized upload, and the quantize -> dequantize round
trip runs through the batched Pallas kernels (kernels/quantize.py) when
the spec's fusion knob is on, the jnp reference otherwise. Both paths
are bit-identical; a disabled plan adds no state leaves and traces the
legacy program bit-for-bit.

Bytes on the wire are *modeled* (the simulation never materializes the
int8 payload): :func:`upload_bytes` maps one model's leaves x mode to
the per-upload wire size, and :func:`round_comm_bytes` multiplies by the
realized upload counts -- the ``comm_bytes`` metric every engine reports
per round.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

COMPRESSION_MODES = ("none", "bf16", "int8_stochastic", "topk")

# Wire-format constants for the modeled byte accounting.
_SCALE_BYTES = 4        # one f32 scale per int8 row
_TOPK_ENTRY_BYTES = 8   # f32 value + int32 index per kept entry


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Per-link upload compression config.

    client_mode: compressor on the client -> group upload link.
    group_mode: compressor on the group -> global upload link.
    error_feedback: carry per-link residuals (``efc``/``efg`` state
        fields) so compression error re-enters the next upload instead
        of becoming bias. Applies to every non-``none`` link.
    topk_frac: fraction of entries a ``topk`` link keeps per row
        (``k = ceil(topk_frac * N)``, at least 1).
    """

    client_mode: str = "none"
    group_mode: str = "none"
    error_feedback: bool = True
    topk_frac: float = 0.01

    @property
    def enabled(self) -> bool:
        return self.client_mode != "none" or self.group_mode != "none"

    @property
    def stochastic(self) -> bool:
        """True when either link draws rounding noise from the state rng."""
        return "int8_stochastic" in (self.client_mode, self.group_mode)

    @property
    def ef_client(self) -> bool:
        return self.error_feedback and self.client_mode != "none"

    @property
    def ef_group(self) -> bool:
        return self.error_feedback and self.group_mode != "none"

    def validate(self) -> "CompressionPlan":
        for name in ("client_mode", "group_mode"):
            mode = getattr(self, name)
            _require(mode in COMPRESSION_MODES,
                     f"unknown {name} {mode!r} "
                     f"(choose from {COMPRESSION_MODES})")
        _require(0.0 < self.topk_frac <= 1.0,
                 f"topk_frac must be in (0, 1], got {self.topk_frac}")
        return self


def _leaf_roundtrip(leaf, lead_ndim: int, mode: str, frac: float,
                    key, dispatch: str):
    """Quantize + dequantize one [*, lead, ...] leaf, row = one upload."""
    lead = leaf.shape[:lead_ndim]
    rows = int(np.prod(lead, dtype=np.int64)) if lead else 1
    n = int(np.prod(leaf.shape[lead_ndim:], dtype=np.int64)) if \
        leaf.ndim > lead_ndim else 1
    u = leaf.reshape(rows, n)
    if mode == "bf16":
        deq = u.astype(jnp.bfloat16).astype(u.dtype)
    elif mode == "int8_stochastic":
        amax = jnp.max(jnp.abs(u).astype(jnp.float32), axis=1)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        noise = jax.random.uniform(key, u.shape, jnp.float32)
        deq = kops.int8_roundtrip(u, scale, noise, mode=dispatch)
    elif mode == "topk":
        k = max(1, min(n, math.ceil(frac * n)))
        thresh = jax.lax.top_k(jnp.abs(u), k)[0][:, -1]
        deq = kops.topk_mask(u, thresh, mode=dispatch)
    else:
        raise ValueError(f"unknown compression mode {mode!r}")
    return deq.reshape(leaf.shape)


def roundtrip(delta, *, mode: str, lead_ndim: int, frac: float = 0.01,
              key=None, dispatch: str = "ref"):
    """Quantize + dequantize every leaf of an upload-delta pytree.

    ``lead_ndim`` leading axes index independent uploads (2 for the
    [G, K, ...] client link, 1 for the [G, ...] group link); each upload
    row gets its own scale/threshold. ``key`` is required (and consumed
    per leaf via ``fold_in``) only for ``int8_stochastic``; the other
    modes are deterministic and consume no keys.
    """
    if mode == "none":
        return delta
    leaves, treedef = jax.tree.flatten(delta)
    out = []
    for i, leaf in enumerate(leaves):
        lk = None if key is None else jax.random.fold_in(key, i)  # key-ok
        out.append(_leaf_roundtrip(leaf, lead_ndim, mode, frac, lk, dispatch))
    return jax.tree_util.tree_unflatten(treedef, out)


def model_leaf_sizes(params, lead_ndim: int = 2) -> tuple:
    """One model's wire-relevant leaf geometry from a stacked state pytree:
    ``((elements, dtype_name), ...)`` with the ``lead_ndim`` replica axes
    stripped. Works on abstract (ShapeDtypeStruct) leaves too."""
    out = []
    for leaf in jax.tree.leaves(params):
        n = int(np.prod(leaf.shape[lead_ndim:], dtype=np.int64)) if \
            len(leaf.shape) > lead_ndim else 1
        out.append((n, jnp.dtype(leaf.dtype).name))
    return tuple(out)


def upload_bytes(leaf_sizes, mode: str, topk_frac: float = 0.01) -> float:
    """Modeled wire bytes of ONE upload (one client or one group) under
    ``mode``, from :func:`model_leaf_sizes` geometry."""
    total = 0
    for n, dtype_name in leaf_sizes:
        if mode == "none":
            total += n * jnp.dtype(dtype_name).itemsize
        elif mode == "bf16":
            total += 2 * n
        elif mode == "int8_stochastic":
            total += n + _SCALE_BYTES
        elif mode == "topk":
            total += _TOPK_ENTRY_BYTES * max(1, min(n, math.ceil(
                topk_frac * n)))
        else:
            raise ValueError(f"unknown compression mode {mode!r}")
    return float(total)


def round_comm_bytes(params, plan, n_client_uploads, n_group_uploads,
                     lead_ndim: int = 2):
    """Total modeled upload bytes of one global round (f32 scalar).

    ``n_client_uploads`` / ``n_group_uploads`` are the realized upload
    counts across the whole round (traced scalars or python ints): every
    active client that *sent* bytes counts -- including uploads the
    defense later screens -- while crashed/unsampled clients and
    timed-out groups count zero.
    """
    sizes = model_leaf_sizes(params, lead_ndim)
    on = plan is not None and plan.enabled
    cmode = plan.client_mode if on else "none"
    gmode = plan.group_mode if on else "none"
    frac = plan.topk_frac if on else 0.01
    cb = upload_bytes(sizes, cmode, frac)
    gb = upload_bytes(sizes, gmode, frac)
    return (jnp.asarray(n_client_uploads, jnp.float32) * cb
            + jnp.asarray(n_group_uploads, jnp.float32) * gb)
