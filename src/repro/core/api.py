"""One front door for every experiment: ``repro.api``.

The paper's MTGC algorithm is one algorithm, but this repo grew three
divergent constructor stacks for it -- ``make_global_round`` (the
simulator engine), ``make_multilevel_round`` (Appendix E, M levels) and
``make_sharded_round`` (the production microbatched round) -- each with
its own init, state type and kwarg sprawl. :class:`ExperimentSpec` is the
single declarative surface over all of them: topology, schedule,
algorithm, participation, state layout, fusion and backend in one frozen
dataclass; :func:`build` turns a spec into an :class:`Engine` (a uniform
``init`` / ``round_fn`` / ``global_model`` / packing adapter over the
existing engines) and :func:`fit` drives any engine through the compiled
horizon driver (``core.driver``) without the caller ever touching packing
internals.

Quickstart (the 60-second version; see examples/quickstart.py)::

    from repro import api
    spec = api.ExperimentSpec(
        levels=(4, 5), algorithm="mtgc", lr=0.1,
        schedule=api.RoundSchedule(group_rounds=4, local_steps=5))
    engine = api.build(spec, loss_fn)
    data = engine.pack_arrays({"x": X, "y": Y}, client_index_pools,
                              batch_size=32, rng=np.random.default_rng(0),
                              key=jax.random.PRNGKey(1))
    state, horizon = api.fit(engine, data, 30, params=model_params,
                             eval_every=5, eval_fn=my_eval_fn)
    model = engine.global_model(state)

Backends share semantics, not just shape: ``build(spec)`` for the same
algorithm/topology/participation is state-for-state identical to the
legacy constructors (tests/test_api_conformance.py), and the legacy
constructors themselves are now thin shims over this module, so every
pre-existing parity/oracle test gates the redesign.

**Async group rounds** land through the hook :class:`RoundSchedule`
reserved for them: ``group_rounds`` accepts a per-group tuple
``(E_1, ..., E_G)`` -- heterogeneous edges run at their own pace -- and
``ExperimentSpec.staleness`` picks what the global aggregation does with
groups that report late (:data:`STALENESS_POLICIES`: ``"sync"`` every
group reports each window with its own E_g rounds of work; ``"naive"``
stale reports merge at full weight; ``"discount"`` down-weights a report
by ``1/(1+staleness)``; ``"delay_compensated"`` shifts it by the global
progress the group missed). ``max_staleness`` bounds how late a report
may be (groups beyond it are force-synced). All of it is implemented in
the simulator and sharded engines behind :func:`build` -- no new
constructor stack -- via static iteration masks over a padded
``max(E_g)`` inner loop (core/staleness.py); the uniform/sync
configuration stays bit-for-bit the legacy program
(tests/test_async_rounds.py)::

    spec = api.ExperimentSpec(
        levels=(3, 4),
        schedule=api.RoundSchedule(group_rounds=(4, 2, 1), local_steps=5),
        staleness="discount", max_staleness=3)

The multilevel backend keeps requiring a uniform schedule (validated up
front).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import HFLConfig
from repro.core.driver import (
    GuardReport,
    GuardSpec,
    Horizon,
    LoweredChunk,
    PackedBatches,
    pack_client_shards,
    pack_lm_shards,
    run_rounds,
    trace_chunk,
)
from repro.core.compression import COMPRESSION_MODES, CompressionPlan
from repro.core.faults import DefensePlan, FAULT_KINDS, FaultPlan
from repro.core.packer import as_tree
from repro.core.population import (
    PopulationStore,
    population_fields,
    run_population_rounds,
    stateless_round,
)
from repro.core.staleness import STALENESS_POLICIES

PyTree = Any

ALGORITHMS = ("mtgc", "hfedavg", "local_corr", "group_corr", "fedprox", "feddyn")
BACKENDS = ("simulator", "multilevel", "sharded")
LAYOUTS = ("tree", "flat")
FUSIONS = ("none", "fused")
CLIENT_STATES = ("stateful", "stateless")

# Which algorithms each backend implements (the simulator engine is the
# paper's full baseline zoo; the production round keeps the two deployed
# ones; the M-level engine is MTGC by construction).
BACKEND_ALGORITHMS = {
    "simulator": ALGORITHMS,
    "multilevel": ("mtgc",),
    "sharded": ("mtgc", "hfedavg"),
}


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """When each timescale fires, declared once for every backend.

    group_rounds: E -- group aggregations per global round. A scalar, or a
        per-group tuple ``(E_1, ..., E_G)`` (length ``levels[0]``): a
        non-uniform tuple enables async group rounds -- each group runs its
        own E_g inside a padded ``max(E_g)`` window, and
        ``ExperimentSpec.staleness`` picks the stale-report policy
        (simulator and sharded backends; the multilevel backend requires a
        uniform schedule).
    local_steps: H -- local SGD steps per group round.
    microbatches: A -- gradient-accumulation chunks per local step; only
        meaningful on the sharded backend (None elsewhere).
    periods: explicit M-level aggregation periods ``(P_1 > ... > P_M)``
        for the multilevel backend; for a two-level topology they default
        to ``(E * H, H)``.
    """

    group_rounds: int | tuple[int, ...] = 2
    local_steps: int = 5
    microbatches: int | None = None
    periods: tuple[int, ...] | None = None

    def __post_init__(self):
        if isinstance(self.group_rounds, (list, tuple)):
            object.__setattr__(self, "group_rounds",
                               tuple(int(e) for e in self.group_rounds))
        if self.periods is not None:
            object.__setattr__(self, "periods",
                               tuple(int(p) for p in self.periods))

    @property
    def is_uniform(self) -> bool:
        """True when every group runs the same number of group rounds."""
        if isinstance(self.group_rounds, tuple):
            return all(e == self.group_rounds[0] for e in self.group_rounds)
        return True

    @property
    def uniform_group_rounds(self) -> int:
        """E as a scalar; raises for non-uniform (async) schedules --
        callers that can handle the padded loop use
        :attr:`max_group_rounds` instead."""
        if isinstance(self.group_rounds, tuple):
            first = self.group_rounds[0]
            _require(self.is_uniform,
                     "this code path needs a uniform group-round schedule "
                     f"(got {self.group_rounds}); async per-group schedules "
                     "run through the padded max(E_g) loop "
                     "(max_group_rounds)")
            return first
        return int(self.group_rounds)

    @property
    def max_group_rounds(self) -> int:
        """The padded inner-loop length max(E_g) -- what one global round's
        batches carry; equals E for uniform schedules."""
        if isinstance(self.group_rounds, tuple):
            return max(self.group_rounds)
        return int(self.group_rounds)

    def level_periods(self, num_levels: int) -> tuple[int, ...]:
        """Aggregation periods for an ``num_levels``-deep topology."""
        if self.periods is not None:
            return self.periods
        E, H = self.uniform_group_rounds, self.local_steps
        _require(num_levels == 2,
                 f"a {num_levels}-level topology needs explicit "
                 "schedule.periods (group_rounds/local_steps only define "
                 "the two-level schedule)")
        return (E * H, H)

    def validate(self, levels: tuple[int, ...]) -> "RoundSchedule":
        gr = self.group_rounds
        if isinstance(gr, tuple):
            _require(len(gr) == levels[0],
                     f"per-group group_rounds needs one entry per group: "
                     f"{len(gr)} entries for {levels[0]} groups")
            _require(all(e >= 1 for e in gr), f"group_rounds must be >= 1: {gr}")
        else:
            _require(gr >= 1, f"group_rounds must be >= 1, got {gr}")
        _require(self.local_steps >= 1,
                 f"local_steps must be >= 1, got {self.local_steps}")
        _require(self.microbatches is None or self.microbatches >= 1,
                 f"microbatches must be None or >= 1, got {self.microbatches}")
        if self.periods is not None:
            _require(self.is_uniform,
                     "explicit schedule.periods (the multilevel backend) "
                     "require a uniform group-round schedule, got "
                     f"group_rounds={self.group_rounds}")
            _require(len(self.periods) == len(levels),
                     f"one period per level: {len(self.periods)} periods for "
                     f"{len(levels)} levels")
            for a, b in zip(self.periods, self.periods[1:]):
                _require(a > b and a % b == 0,
                         f"periods must nest (P_m > P_m+1, divisible): "
                         f"{self.periods}")
            # periods are authoritative: an explicitly different E/H would
            # be silently ignored, so reject the conflict. Field defaults
            # count as "unset" (you can't declare periods without them).
            derived = (self.periods[0] // self.periods[-1], self.periods[-1])
            given = (self.uniform_group_rounds, self.local_steps)
            defaults = (RoundSchedule.group_rounds, RoundSchedule.local_steps)
            _require(given == derived or given == defaults,
                     f"schedule.periods={self.periods} implies "
                     f"(group_rounds, local_steps)={derived}, which "
                     f"conflicts with the explicit {given}; set periods "
                     "alone or keep them consistent")
        return self


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Everything that defines one HFL experiment, in one place.

    levels: topology dims -- ``(G, K)`` for the two-level engines, or the
        full ``(N_1, ..., N_M)`` tree for the multilevel backend.
    schedule: the :class:`RoundSchedule` (E / H / microbatches / periods).
    algorithm: one of :data:`ALGORITHMS` (backend support varies; see
        :data:`BACKEND_ALGORITHMS`).
    backend: "simulator" (``core.engine``), "multilevel"
        (``core.multilevel``) or "sharded" (``launch.train``).
    state_layout: "flat" packs state into contiguous ``[*dims, N]``
        buffers (``core.packer``); "tree" keeps model pytrees.
    fusion: "fused" routes the MTGC local step through the Pallas kernel.
    fused_mode: sharded-backend kernel dispatch override
        ("auto" | "pallas" | "interpret"); None = backend default.
    correction_dtype: narrow (e.g. "bfloat16") z/y storage -- sharded
        backend, tree layout only.
    client_participation / group_participation / participation_mode /
    participation_weighting: exactly ``HFLConfig``'s semantics.
    level_participation: per-level live-uplink fractions for M-level
        topologies (overrides the two scalar fractions there).
    staleness: stale-report policy for async (non-uniform) group-round
        schedules, one of :data:`STALENESS_POLICIES` -- "sync" (every group
        reports each window; the only policy valid with uniform rounds),
        "naive" (stale reports merge at full weight), "discount"
        (1/(1+staleness) weighting) or "delay_compensated" (reports are
        shifted by the global progress the group missed). See
        core/staleness.py.
    max_staleness: bound on report staleness -- groups whose cadence would
        exceed it are force-synced; requires an async (non-"sync") policy.
    population: virtual clients per group. ``levels[1]`` stays the compiled
        cohort shape; each driver chunk samples that many clients from the
        population, gathers their persistent corrections out of a host-side
        :class:`~repro.core.population.PopulationStore` and scatters them
        back -- device memory and round time scale with the cohort, not the
        population (``core.population``). ``population == levels[1]``
        materializes everyone (bit-exact vs. the plain path); larger
        populations require full participation (cohort sampling *is* the
        participation mechanism) and a uniform sync schedule.
    cohort_size: declarative alias for the compiled cohort shape; when set
        it must equal ``levels[1]`` (the single authoritative topology) and
        requires ``population``.
    client_state: "stateful" (default) persists per-client corrections in
        the population store; "stateless" zero-initializes them every round
        -- the large-cohort FL assumption -- and needs no store at all.
    faults: a :class:`~repro.core.faults.FaultPlan` -- deterministic
        per-round fault injection (client crashes, group timeouts,
        corrupted uploads) drawn from the state rng after the
        participation draw, so the zero-fault stream is untouched.
        None / all-zero rates trace the legacy program bit-for-bit.
        Two-level simulator/sharded backends only.
    defense: a :class:`~repro.core.faults.DefensePlan` -- screened
        aggregation (non-finite and norm screening of per-client deltas,
        optional norm clipping) applied at the upload boundary; screened
        contributions never enter aggregates or the z/y corrections, and
        the per-round ``screened`` metric counts them.
    compression: a :class:`~repro.core.compression.CompressionPlan` --
        per-link quantized/sparsified uploads (client->group and
        group->global independently: bf16 | int8_stochastic | topk) with
        optional error-feedback residuals carried in the state, applied
        at the same upload boundary the faults/defense use (compress ->
        corrupt -> screen). Every engine reports the modeled per-round
        ``comm_bytes`` metric whether or not a plan is set. Two-level
        simulator/sharded backends, sync schedules only.
    """

    levels: tuple[int, ...] = (2, 2)
    schedule: RoundSchedule = RoundSchedule()
    algorithm: str = "mtgc"
    lr: float = 0.1
    backend: str = "simulator"
    state_layout: str = "flat"
    fusion: str = "none"
    fused_mode: str | None = None
    correction_init: str = "zero"
    prox_mu: float = 0.0
    feddyn_alpha: float = 0.0
    server_lr: float = 1.0
    client_participation: float = 1.0
    group_participation: float = 1.0
    level_participation: tuple[float, ...] | None = None
    participation_mode: str = "uniform"
    participation_weighting: str = "none"
    correction_dtype: str | None = None
    staleness: str = "sync"
    max_staleness: int | None = None
    population: int | None = None
    cohort_size: int | None = None
    client_state: str = "stateful"
    faults: FaultPlan | None = None
    defense: DefensePlan | None = None
    compression: CompressionPlan | None = None

    def __post_init__(self):
        object.__setattr__(self, "levels", tuple(int(n) for n in self.levels))
        if self.level_participation is not None:
            object.__setattr__(self, "level_participation",
                               tuple(float(p) for p in self.level_participation))

    # ------------------------------------------------------------ checks

    def validate(self) -> "ExperimentSpec":
        _require(len(self.levels) >= 2,
                 f"levels needs at least (groups, clients), got {self.levels}")
        _require(all(n >= 1 for n in self.levels),
                 f"every topology dim must be >= 1: {self.levels}")
        _require(self.backend in BACKENDS,
                 f"unknown backend {self.backend!r} (choose from {BACKENDS})")
        _require(self.algorithm in ALGORITHMS,
                 f"unknown algorithm {self.algorithm!r} "
                 f"(choose from {ALGORITHMS})")
        _require(self.algorithm in BACKEND_ALGORITHMS[self.backend],
                 f"algorithm {self.algorithm!r} is not implemented by the "
                 f"{self.backend!r} backend "
                 f"(supported: {BACKEND_ALGORITHMS[self.backend]})")
        _require(len(self.levels) == 2 or self.backend == "multilevel",
                 f"{len(self.levels)}-level topologies need "
                 f"backend='multilevel', got {self.backend!r}")
        self.schedule.validate(self.levels)
        _require(self.schedule.microbatches is None
                 or self.backend == "sharded",
                 "schedule.microbatches is a sharded-backend knob")
        if self.backend == "multilevel":
            self.schedule.level_periods(len(self.levels))

        # Async group rounds: contradictory combos are rejected up front.
        _require(self.staleness in STALENESS_POLICIES,
                 f"unknown staleness policy {self.staleness!r} "
                 f"(choose from {STALENESS_POLICIES})")
        uniform = self.schedule.is_uniform
        _require(uniform or self.backend != "multilevel",
                 "non-uniform group_rounds (async group rounds) are a "
                 "two-level feature: the multilevel backend requires a "
                 "uniform schedule")
        _require(self.staleness == "sync" or not uniform,
                 f"staleness={self.staleness!r} is a no-op with uniform "
                 "group_rounds: stale reports only arise when groups run "
                 "different round counts -- set a per-group tuple or drop "
                 "the policy")
        _require(self.max_staleness is None or self.staleness != "sync",
                 "max_staleness bounds async reporting; it needs a non-"
                 "'sync' staleness policy")
        _require(self.max_staleness is None or self.max_staleness >= 1,
                 f"max_staleness must be None or >= 1, "
                 f"got {self.max_staleness}")
        _require(uniform or self.correction_init == "zero",
                 "async group rounds require correction_init='zero' (the "
                 "gradient init has no per-cycle analogue)")
        _require(uniform or self.server_lr == 1.0,
                 "async group rounds require server_lr=1.0")

        _require(self.state_layout in LAYOUTS,
                 f"unknown state_layout {self.state_layout!r} "
                 f"(choose from {LAYOUTS})")
        _require(self.fusion in FUSIONS,
                 f"unknown fusion {self.fusion!r} (choose from {FUSIONS})")
        _require(self.fusion == "none" or self.algorithm == "mtgc",
                 "fusion='fused' fuses exactly g + z + y: mtgc only")
        _require(self.fusion == "none" or self.backend != "multilevel",
                 "the multilevel backend has no fused-kernel path")
        _require(self.fused_mode is None or self.backend == "sharded",
                 "fused_mode overrides the sharded backend's kernel dispatch")
        _require(self.correction_dtype is None
                 or (self.backend == "sharded" and self.state_layout == "tree"),
                 "correction_dtype (narrow z/y storage) exists only on the "
                 "sharded backend's tree layout")

        _require(self.correction_init in ("zero", "gradient"),
                 f"correction_init must be 'zero' or 'gradient', "
                 f"got {self.correction_init!r}")
        _require(self.correction_init == "zero" or self.backend == "simulator",
                 "correction_init='gradient' is a simulator-engine feature")
        for name in ("prox_mu", "feddyn_alpha"):
            _require(getattr(self, name) == 0.0 or self.backend == "simulator",
                     f"{name} only affects the simulator engine's "
                     "fedprox/feddyn algorithms")
        _require(self.server_lr == 1.0 or self.backend == "simulator",
                 "server_lr is a simulator-engine knob")

        for name in ("client_participation", "group_participation"):
            frac = getattr(self, name)
            _require(0.0 < frac <= 1.0,
                     f"{name} must be in (0, 1], got {frac}")
        _require(self.participation_mode in ("uniform", "fixed"),
                 f"participation_mode must be 'uniform' or 'fixed', "
                 f"got {self.participation_mode!r}")
        _require(self.participation_weighting in ("none", "inverse_prob"),
                 f"participation_weighting must be 'none' or 'inverse_prob', "
                 f"got {self.participation_weighting!r}")
        if self.level_participation is not None:
            _require(self.backend == "multilevel",
                     "level_participation is a multilevel-backend knob; "
                     "two-level backends use client_/group_participation")
            _require(len(self.level_participation) == len(self.levels),
                     "one participation fraction per level: "
                     f"{len(self.level_participation)} for "
                     f"{len(self.levels)} levels")
            _require(all(0.0 < p <= 1.0 for p in self.level_participation),
                     f"participation fractions must be in (0, 1]: "
                     f"{self.level_participation}")

        # Virtual population: contradictory combos are rejected up front.
        _require(self.client_state in CLIENT_STATES,
                 f"unknown client_state {self.client_state!r} "
                 f"(choose from {CLIENT_STATES})")
        _require(self.cohort_size is None or self.population is not None,
                 "cohort_size describes the sampled cohort of a virtual "
                 "population; set population too")
        _require(self.client_state == "stateful" or self.population is not None,
                 "client_state='stateless' is a virtual-population contract; "
                 "set population (the materialized engines are stateful by "
                 "construction)")
        if self.population is not None:
            _require(self.population >= 1,
                     f"population must be >= 1, got {self.population}")
            _require(len(self.levels) == 2,
                     "a virtual population is two-level (groups x clients); "
                     f"got levels={self.levels}")
            _require(self.backend != "multilevel",
                     "the multilevel backend has no cohort gather/scatter "
                     "path; use the simulator or sharded backend")
            _require(self.cohort_size is None
                     or self.cohort_size == self.levels[1],
                     f"cohort_size ({self.cohort_size}) must equal levels[1] "
                     f"({self.levels[1]}), the compiled cohort shape -- "
                     "levels stays the single authoritative topology")
            _require(self.population >= self.levels[1],
                     f"population ({self.population}) must be >= the cohort "
                     f"levels[1] ({self.levels[1]}): a cohort larger than "
                     "the population cannot be sampled without replacement")
        if self.virtual_population:
            _require(self.full_participation,
                     "a virtual population (population > levels[1]) samples "
                     "its cohort from the store -- that *is* the "
                     "participation mechanism; in-round partial "
                     "participation would freeze slots whose occupants "
                     "change between chunks. Keep client_/group_"
                     "participation at 1.0")
            _require(self.schedule.is_uniform and self.staleness == "sync",
                     "virtual populations require a uniform sync schedule: "
                     "async per-group cadences assume slot occupants "
                     "persist across windows (follow-up work)")

        # Fault tolerance: contradictory combos are rejected up front.
        if self.faults is not None:
            self.faults.validate()
        if self.defense is not None:
            self.defense.validate()
        if self.fault_mode or self.defended:
            _require(self.backend != "multilevel",
                     "fault injection / screened aggregation are two-level "
                     "features (simulator and sharded backends); the "
                     "multilevel backend is follow-up work")
            _require(self.population is None,
                     "fault injection with a virtual population is follow-up "
                     "work: screened slots would need store-side healing")
            _require(self.correction_init == "zero",
                     "fault injection / screened aggregation require "
                     "correction_init='zero' (the gradient init has no "
                     "crash-consistent analogue)")
            _require(self.server_lr == 1.0,
                     "fault injection / screened aggregation require "
                     "server_lr=1.0")

        # Compressed uploads: contradictory combos are rejected up front.
        if self.compression is not None:
            self.compression.validate()
        if self.compressed:
            _require(self.backend != "multilevel",
                     "compressed uploads are a two-level feature (simulator "
                     "and sharded backends); per-level plans for the "
                     "multilevel backend are follow-up work")
            _require(self.staleness == "sync" and self.schedule.is_uniform,
                     "compressed uploads under an async schedule are not "
                     "supported yet: stale reports would need their own "
                     "residual timeline (see ROADMAP)")
            _require(self.correction_init == "zero",
                     "compressed uploads require correction_init='zero' "
                     "(the gradient init predates the upload seam)")
            _require(self.server_lr == 1.0,
                     "compressed uploads require server_lr=1.0")
            if self.compression.error_feedback:
                _require(self.client_state == "stateful",
                         "error feedback is per-client persistent state; "
                         "client_state='stateless' contradicts it -- set "
                         "CompressionPlan(error_feedback=False)")
                _require(self.population is None,
                         "error feedback with a virtual population is "
                         "follow-up work: per-client residuals would need "
                         "store-side gather/scatter like z; set "
                         "CompressionPlan(error_feedback=False)")
            else:
                _require(self.population is None
                         or self.compression.client_mode == "none",
                         "client-link compression with a virtual population "
                         "is follow-up work (the cohort seam predates the "
                         "upload seam)")
        return self

    # ------------------------------------------------- config conversion

    @property
    def full_participation(self) -> bool:
        if self.level_participation is not None:
            return all(p >= 1.0 for p in self.level_participation)
        return (self.client_participation >= 1.0
                and self.group_participation >= 1.0)

    @property
    def fault_mode(self) -> bool:
        """True when the spec injects any faults."""
        return self.faults is not None and self.faults.enabled

    @property
    def defended(self) -> bool:
        """True when screened aggregation is active."""
        return self.defense is not None and self.defense.enabled

    @property
    def compressed(self) -> bool:
        """True when any upload link carries a non-trivial compressor."""
        return self.compression is not None and self.compression.enabled

    @property
    def virtual_population(self) -> bool:
        """True when the population exceeds the materialized cohort --
        cohort draws then actually sample (``population == levels[1]`` is
        the degenerate everyone-materialized case)."""
        return self.population is not None and self.population > self.levels[1]

    def participation_by_level(self) -> tuple[float, ...]:
        """Per-level live-uplink fractions for the multilevel engine."""
        if self.level_participation is not None:
            return self.level_participation
        # Two-level semantics: level 0 = group uplinks, deepest = clients.
        return ((self.group_participation,)
                + (1.0,) * (len(self.levels) - 2)
                + (self.client_participation,))

    def staleness_plan(self):
        """The :class:`~repro.core.staleness.StalenessPlan` this spec's
        schedule implies, or None for the uniform sync schedule (the
        engines then take their legacy code path untouched)."""
        from repro.core.staleness import make_plan

        return make_plan(self.schedule.group_rounds, self.levels[0],
                         self.staleness, self.max_staleness)

    def to_hfl_config(self) -> HFLConfig:
        """The equivalent two-level ``HFLConfig`` (simulator engine).

        ``group_rounds`` is the padded loop length ``max(E_g)`` -- exactly
        E for uniform schedules; per-group counts live in the staleness
        plan, not the legacy config.
        """
        _require(len(self.levels) == 2,
                 f"HFLConfig is two-level; spec has levels={self.levels}")
        return HFLConfig(
            num_groups=self.levels[0],
            clients_per_group=self.levels[1],
            local_steps=self.schedule.local_steps,
            group_rounds=self.schedule.max_group_rounds,
            lr=self.lr,
            algorithm=self.algorithm,
            correction_init=self.correction_init,
            prox_mu=self.prox_mu,
            feddyn_alpha=self.feddyn_alpha,
            server_lr=self.server_lr,
            client_participation=self.client_participation,
            group_participation=self.group_participation,
            participation_mode=self.participation_mode,
            participation_weighting=self.participation_weighting,
            use_fused_update=self.fusion == "fused",
            use_flat_state=self.state_layout == "flat",
        )

    @classmethod
    def from_hfl_config(cls, cfg: HFLConfig,
                        backend: str = "simulator") -> "ExperimentSpec":
        return cls(
            levels=(cfg.num_groups, cfg.clients_per_group),
            schedule=RoundSchedule(group_rounds=cfg.group_rounds,
                                   local_steps=cfg.local_steps),
            algorithm=cfg.algorithm,
            lr=cfg.lr,
            backend=backend,
            state_layout="flat" if cfg.use_flat_state else "tree",
            fusion="fused" if cfg.use_fused_update else "none",
            correction_init=cfg.correction_init,
            prox_mu=cfg.prox_mu,
            feddyn_alpha=cfg.feddyn_alpha,
            server_lr=cfg.server_lr,
            client_participation=cfg.client_participation,
            group_participation=cfg.group_participation,
            participation_mode=cfg.participation_mode,
            participation_weighting=cfg.participation_weighting,
        )


# ------------------------------------------------------------------ engine


LossFn = Callable[[PyTree, PyTree], jax.Array]


@runtime_checkable
class Engine(Protocol):
    """What every backend looks like behind :func:`build`.

    spec: the validated :class:`ExperimentSpec` this engine realizes.
    round_fn: ``(state, batches) -> (state, metrics)`` consuming the
        driver batch layout (what ``select_round`` emits for this spec);
        jit-friendly and driver-ready.
    metric_fields: names of the metrics NamedTuple fields ``round_fn``
        returns -- always includes ``"loss"``.
    """

    spec: ExperimentSpec
    round_fn: Callable[[PyTree, PyTree], tuple[PyTree, Any]]
    metric_fields: tuple[str, ...]

    def init(self, params: PyTree, rng: jax.Array | None = None) -> PyTree:
        """Broadcast one model into this backend's round state."""
        ...

    def global_model(self, state: PyTree) -> PyTree:
        """The current global model as a plain model pytree."""
        ...


class _EngineBase:
    """Shared packing plumbing; subclasses adapt one legacy engine each."""

    def __init__(self, spec: ExperimentSpec, loss_fn: LossFn):
        self.spec = spec
        self.loss_fn = loss_fn
        self.round_fn = self._build_round_fn()
        if spec.client_state == "stateless":
            # Wrap once at build time: the driver's chunk-runner cache
            # keys on the round function's identity.
            self.round_fn = stateless_round(self.round_fn, ("z", "dyn"))

    @property
    def population_fields(self) -> tuple[str, ...]:
        """State fields the population store persists for this spec."""
        return population_fields(self.spec.algorithm)

    def init_population(self, state: PyTree) -> PopulationStore:
        """A zeroed host store for ``spec.population`` virtual clients,
        seeded from ``state``'s current correction rows (identity mapping
        into rows ``[0, K)``)."""
        _require(self.spec.population is not None,
                 "init_population needs spec.population set")
        _require(self.spec.client_state == "stateful",
                 "stateless clients keep no per-client state; no store "
                 "exists to initialize")
        return PopulationStore.from_state(
            state, self.spec.population, self.population_fields)

    # Subclasses set these to the driver-layout (E, H) of one round.
    # Async schedules pack the padded max(E_g) axis: stragglers' dead
    # iterations draw shards that the iteration mask then gates out.
    @property
    def _pack_rounds(self) -> int:
        return self.spec.schedule.max_group_rounds

    @property
    def _pack_steps(self) -> int:
        return self.spec.schedule.local_steps

    @property
    def _pack_microbatches(self) -> int | None:
        return None

    def pack_arrays(self, data_arrays: dict[str, np.ndarray], indices: list,
                    *, batch_size: int, shards: int = 16,
                    rng: np.random.Generator, key: jax.Array) -> PackedBatches:
        """Pack a partitioned array dataset for :func:`fit` (uploads once)."""
        _require(_index_depth(indices) == len(self.spec.levels),
                 f"index nesting depth {_index_depth(indices)} does not "
                 f"match levels={self.spec.levels}")
        return pack_client_shards(
            data_arrays, indices, group_rounds=self._pack_rounds,
            local_steps=self._pack_steps, batch_size=batch_size,
            shards=shards, microbatches=self._pack_microbatches,
            rng=rng, key=key)

    def pack_tokens(self, tokens: np.ndarray, *, batch_size: int,
                    seq_len: int, shards: int = 8,
                    rng: np.random.Generator, key: jax.Array) -> PackedBatches:
        """Pack an LM token stream for :func:`fit` (two-level backends)."""
        _require(len(self.spec.levels) == 2,
                 "token packing is two-level; use pack_arrays with nested "
                 "index pools for deeper trees")
        G, K = self.spec.levels
        return pack_lm_shards(
            tokens, num_groups=G, clients_per_group=K,
            group_rounds=self._pack_rounds, local_steps=self._pack_steps,
            batch_size=batch_size, seq_len=seq_len, shards=shards,
            microbatches=self._pack_microbatches, rng=rng, key=key)

    def abstract_state(self, params: PyTree) -> PyTree:
        """ShapeDtypeStructs of this engine's round state, zero allocation.

        ``params`` may itself be abstract -- ``init`` (and the flat-layout
        packer behind it) is traced with ``jax.eval_shape``, so nothing is
        materialized. The rng passed to ``init`` is a concrete throwaway
        key: only its shape/dtype survive into the abstract state.
        """
        return jax.eval_shape(
            lambda p: self.init(p, jax.random.PRNGKey(0)), params)

    def lower_chunk(
        self,
        data: PackedBatches,
        *,
        params: PyTree | None = None,
        state: PyTree | None = None,
        chunk: int = 2,
        eval_fn=None,
        donate: bool = True,
        compile: bool = True,
    ) -> LoweredChunk:
        """Trace + lower (+ compile) this engine's driver chunk, no execution.

        The static-analysis front door (``repro.analysis`` and ``python -m
        repro.launch.audit`` audit the lowered artifacts this returns).
        ``data`` leaves may be ``jax.ShapeDtypeStruct``s with the packed
        driver layout (``[*levels, S, steps, ...]`` plus the microbatch
        axis on the sharded backend); pass either an abstract ``state`` or
        the ``params`` to derive one from via :meth:`abstract_state`.
        """
        if state is None:
            _require(params is not None,
                     "lower_chunk needs `state` or `params` to trace over")
            state = self.abstract_state(params)
        return trace_chunk(self.round_fn, state, data, chunk,
                           eval_fn=eval_fn, donate=donate, compile=compile)

    def retry_round_fn(self, retry: int):
        """Round function for guarded-horizon retry ``retry`` (>= 1).

        When the spec has a norm screen, each retry rebuilds the round
        with ``screen_norm * retry_widen ** retry`` -- the screen catches
        exponentially more on every retry, so a chunk that diverged
        because a corrupted-but-finite delta slipped under the threshold
        converges on replay. Otherwise the original round is retried
        as-is (the re-split rng alone changes the fault draw). Rebuilt
        rounds are cached per retry level so the driver's chunk-runner
        cache (keyed on function identity) is not thrashed.
        """
        spec = self.spec
        if (retry <= 0 or spec.defense is None
                or spec.defense.screen_norm is None):
            return self.round_fn
        cache = getattr(self, "_retry_round_fns", None)
        if cache is None:
            cache = self._retry_round_fns = {}
        if retry not in cache:
            widened = dataclasses.replace(
                spec.defense,
                screen_norm=(spec.defense.screen_norm
                             * spec.defense.retry_widen ** retry))
            rebuilt = build(dataclasses.replace(spec, defense=widened),
                            self.loss_fn)
            cache[retry] = rebuilt.round_fn
        return cache[retry]

    def participation_masks(self, rng: jax.Array):
        """(masks, next_rng) the round derives from a pre-round state rng.

        Exactly the draw the two-level round functions make internally
        (``core.participation.round_masks``' key schedule), so eval
        closures can pick an active replica without rebuilding a legacy
        ``HFLConfig`` from the spec.
        """
        from repro.core.participation import sample_hfl_masks

        _require(len(self.spec.levels) == 2,
                 "participation_masks is two-level; the multilevel backend "
                 "draws hierarchical chain masks internally")
        mkey, next_rng = jax.random.split(rng)
        masks = sample_hfl_masks(
            mkey, *self.spec.levels, self.spec.client_participation,
            self.spec.group_participation, self.spec.participation_mode)
        return masks, next_rng


def _index_depth(indices) -> int:
    depth = 0
    node = indices
    while isinstance(node, (list, tuple)):
        depth += 1
        node = node[0]
    return depth


class SimulatorEngine(_EngineBase):
    """The paper engine (``core.engine``) behind the uniform surface."""

    def _build_round_fn(self):
        from repro.core import engine as _engine
        self._cfg = self.spec.to_hfl_config().validate()
        self._plan = self.spec.staleness_plan()
        from repro.core.engine import RoundMetrics
        self.metric_fields = RoundMetrics._fields
        return _engine._build_global_round(self.loss_fn, self._cfg,
                                           plan=self._plan,
                                           faults=self.spec.faults,
                                           defense=self.spec.defense,
                                           compression=self.spec.compression)

    def init(self, params: PyTree, rng: jax.Array | None = None) -> PyTree:
        from repro.core.engine import hfl_init
        spec = self.spec
        comp = spec.compression if spec.compressed else None
        if rng is None and (spec.fault_mode
                            or (comp is not None and comp.stochastic)):
            # Fault masks -- and stochastic rounding noise -- draw from
            # the state rng stream.
            rng = jax.random.PRNGKey(0)
        snaps = self._plan is not None and self._plan.needs_snapshots
        # The download-freshness carry only exists where it is consumed:
        # async schedules with timeout faults.
        dl = (spec.fault_mode and spec.faults.timeout_rate > 0
              and self._plan is not None)
        return hfl_init(params, self._cfg, rng, staleness_snapshots=snaps,
                        fault_download=dl,
                        ef_client=comp is not None and comp.ef_client,
                        ef_group=comp is not None and comp.ef_group)

    def global_model(self, state: PyTree) -> PyTree:
        from repro.core.engine import global_model
        if self._plan is not None:
            # Only a cadence-1 group's replicas are guaranteed fresh
            # between async windows; the legacy reader takes [0, 0].
            g = self._plan.fastest_group
            return as_tree(jax.tree.map(lambda x: x[g, 0], state.params))
        return global_model(state)


class MultiLevelMetrics(NamedTuple):
    """Metrics contract of the multilevel backend (losses only)."""

    loss: jax.Array  # [P_1] mean training loss per local step


class MultiLevelEngine(_EngineBase):
    """Appendix E's M-level engine (``core.multilevel``) as an Engine.

    ``round_fn`` consumes the driver layout ``[E, H, *dims, ...]`` (with
    ``E * H = P_1``) and merges the two leading axes into the legacy
    ``[P_1, *dims, ...]`` contract; the raw legacy-layout function stays
    available as ``legacy_round_fn`` for the delegating shim.
    """

    def _build_round_fn(self):
        from repro.core import multilevel as _ml
        spec = self.spec
        dims = spec.levels
        periods = spec.schedule.level_periods(len(dims))
        participation = (None if spec.full_participation
                         else spec.participation_by_level())
        self.legacy_round_fn = _ml._build_multilevel_round(
            self.loss_fn, dims, periods, spec.lr,
            participation=participation,
            participation_mode=spec.participation_mode,
            participation_weighting=spec.participation_weighting)
        self.metric_fields = MultiLevelMetrics._fields
        E, H = self._pack_rounds, self._pack_steps
        raw = self.legacy_round_fn

        def round_fn(state, batches):
            merged = jax.tree.map(
                lambda b: b.reshape((E * H,) + b.shape[2:]), batches)
            state, losses = raw(state, merged)
            return state, MultiLevelMetrics(loss=losses)

        return round_fn

    @property
    def _pack_rounds(self) -> int:
        periods = self.spec.schedule.level_periods(len(self.spec.levels))
        return periods[0] // periods[-1]

    @property
    def _pack_steps(self) -> int:
        return self.spec.schedule.level_periods(len(self.spec.levels))[-1]

    def init(self, params: PyTree, rng: jax.Array | None = None) -> PyTree:
        from repro.core.multilevel import multilevel_init
        return multilevel_init(params, self.spec.levels, rng,
                               use_flat_state=self.spec.state_layout == "flat")

    def global_model(self, state: PyTree) -> PyTree:
        from repro.core.multilevel import multilevel_global_model
        return multilevel_global_model(state)


class ShardedEngine(_EngineBase):
    """The production microbatched round (``launch.train``) as an Engine."""

    def _build_round_fn(self):
        from repro.launch import train as _train
        spec = self.spec
        self._plan = spec.staleness_plan()
        self.metric_fields = _train.ShardedMetrics._fields
        return _train._build_sharded_round(
            self.loss_fn, E=spec.schedule.max_group_rounds,
            H=spec.schedule.local_steps, lr=spec.lr,
            algorithm=spec.algorithm,
            use_fused_update=spec.fusion == "fused",
            fused_mode=spec.fused_mode,
            client_participation=spec.client_participation,
            group_participation=spec.group_participation,
            participation_mode=spec.participation_mode,
            participation_weighting=spec.participation_weighting,
            plan=self._plan, faults=spec.faults, defense=spec.defense,
            compression=spec.compression)

    @property
    def _pack_microbatches(self) -> int:
        return self.spec.schedule.microbatches or 1

    def init(self, params: PyTree, rng: jax.Array | None = None) -> PyTree:
        from repro.launch.train import sharded_init
        G, K = self.spec.levels
        comp = self.spec.compression if self.spec.compressed else None
        if rng is None and (not self.spec.full_participation
                            or self.spec.virtual_population
                            or self.spec.fault_mode
                            or (comp is not None and comp.stochastic)):
            # Virtual populations draw their cohorts -- fault plans their
            # masks, stochastic compressors their rounding noise -- from
            # the state rng even under (mandatory) full in-round
            # participation.
            rng = jax.random.PRNGKey(0)
        dtype = (None if self.spec.correction_dtype is None
                 else jnp.dtype(self.spec.correction_dtype))
        plan = self._plan
        dl = (self.spec.fault_mode and self.spec.faults.timeout_rate > 0
              and plan is not None)
        return sharded_init(
            params, G, K,
            use_flat_state=self.spec.state_layout == "flat",
            correction_dtype=dtype, rng=rng,
            round_counter=plan is not None and plan.needs_round_counter,
            staleness_snapshots=plan is not None and plan.needs_snapshots,
            fault_download=dl,
            ef_client=comp is not None and comp.ef_client,
            ef_group=comp is not None and comp.ef_group)

    def global_model(self, state: PyTree) -> PyTree:
        # Under async schedules only a cadence-1 group holds the fresh
        # global model between windows.
        g = 0 if self._plan is None else self._plan.fastest_group
        return as_tree(jax.tree.map(lambda x: x[g, 0], state.params))


_ENGINES = {
    "simulator": SimulatorEngine,
    "multilevel": MultiLevelEngine,
    "sharded": ShardedEngine,
}


def build(spec: ExperimentSpec, loss_fn: LossFn) -> Engine:
    """Validate ``spec`` and construct its backend :class:`Engine`.

    ``loss_fn(params, batch) -> scalar`` is the single-client loss; every
    backend vmaps it over its topology axes exactly as the legacy
    constructors did.
    """
    spec = spec.validate()
    return _ENGINES[spec.backend](spec, loss_fn)


def fit(
    engine: Engine,
    data: PackedBatches,
    T: int,
    *,
    state: PyTree | None = None,
    params: PyTree | None = None,
    rng: jax.Array | None = None,
    chunk: int | None = None,
    eval_every: int = 1,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None = None,
    donate: bool = True,
    population_store: PopulationStore | None = None,
    overlap: bool = True,
    guard: GuardSpec | bool | None = None,
    checkpoint_every: int | None = None,
    checkpoint_path: str | None = None,
    resume: bool = False,
) -> tuple[PyTree, Horizon]:
    """Train ``T`` global rounds through the compiled horizon driver.

    Pass either a ready ``state`` (to continue a run) or the initial model
    ``params`` (plus an optional ``rng`` for participation sampling) --
    :func:`fit` then composes ``engine.init`` + ``core.driver.run_rounds``
    (donated chunked scans, on-device batch selection, in-scan eval at the
    ``eval_every`` cadence) and returns the final state with the stacked
    :class:`Horizon`. ``data`` comes from ``engine.pack_arrays`` /
    ``engine.pack_tokens``; callers never touch packing internals.

    To continue a horizon, pass the previous call's ``horizon.data`` (the
    packed dataset with its selection rng advanced) together with
    ``state=...`` -- reusing the original ``data`` object would replay the
    finished horizon's shard draws::

        state, hz = fit(engine, data, 10, params=params)
        state, hz = fit(engine, hz.data, 10, state=state)   # rounds 11-20

    With ``spec.population`` set and stateful clients, :func:`fit` routes
    through ``core.population.run_population_rounds`` instead: each chunk
    gathers the sampled cohort's corrections from a host-side
    :class:`PopulationStore` (auto-created via ``engine.init_population``
    unless ``population_store`` is passed -- pass ``horizon.population``
    to continue a run) and scatters them back, with the transfers
    overlapped against device compute unless ``overlap=False``. The store
    rides back on ``horizon.population``.

    ``guard`` (a :class:`GuardSpec`, or ``True`` for the defaults) makes
    the horizon self-heal: each driver chunk is snapshotted, checked for
    divergence and rolled back + retried with a re-split rng (see
    ``core.driver.GuardSpec``). Unless the spec overrides it, retries run
    ``engine.retry_round_fn`` -- the defense norm screen tightens by
    ``retry_widen ** retry`` on each attempt. ``horizon.guard`` reports
    the rollbacks/retries taken.

    ``checkpoint_every=N`` with ``checkpoint_path=dir`` autosaves the
    state (and the data selection rng) at every driver chunk boundary
    that is a multiple of N rounds (``chunk`` defaults to N so boundaries
    align), via ``repro.checkpoint``. ``resume=True`` restores the latest
    checkpoint in ``checkpoint_path`` (if any) and runs only the
    remaining ``T - step`` rounds -- bit-exact with the uninterrupted run
    (tests/test_checkpoint.py).
    """
    if state is None:
        _require(params is not None,
                 "fit() needs either state=... or params=... to start from")
        state = engine.init(params, rng)
    if checkpoint_every is not None or resume:
        _require(checkpoint_path is not None,
                 "checkpoint autosave/resume needs checkpoint_path=")
    if checkpoint_every is not None:
        _require(checkpoint_every >= 1,
                 f"checkpoint_every must be >= 1, got {checkpoint_every}")
        if chunk is None:
            chunk = checkpoint_every
    if guard is True:
        guard = GuardSpec()
    if guard is not None and guard.round_fn_for_retry is None \
            and hasattr(engine, "retry_round_fn"):
        guard = guard._replace(round_fn_for_retry=engine.retry_round_fn)

    spec = getattr(engine, "spec", None)
    if (spec is not None and spec.population is not None
            and spec.client_state == "stateful"):
        _require(guard is None and checkpoint_every is None and not resume,
                 "guarded horizons and checkpoint autosave are "
                 "materialized-path features; the population "
                 "gather/scatter loop is follow-up work")
        store = (population_store if population_store is not None
                 else engine.init_population(state))
        state, _, horizon = run_population_rounds(
            engine.round_fn, state, store, data, T, chunk=chunk,
            eval_every=eval_every, eval_fn=eval_fn, donate=donate,
            overlap=overlap)
        return state, horizon

    from repro import checkpoint as _ckpt

    start = 0
    if resume:
        step = _ckpt.latest_step(checkpoint_path)
        if step is not None:
            like = {"state": state, "data_rng": np.asarray(data.rng)}
            restored = _ckpt.restore(checkpoint_path, step, like)
            state = restored["state"]
            data = data.replace_rng(jnp.asarray(restored["data_rng"]))
            start = step
            _require(start < T,
                     f"checkpoint at round {start} >= T={T}: nothing left "
                     "to resume")

    on_chunk = None
    if checkpoint_every is not None:
        def on_chunk(done, st, da):
            rounds = start + done
            if rounds % checkpoint_every == 0 or rounds == T:
                _ckpt.save(checkpoint_path, rounds,
                           {"state": st, "data_rng": np.asarray(da.rng)})

    state, _, horizon = run_rounds(
        engine.round_fn, state, data, T - start, chunk=chunk,
        eval_every=eval_every, eval_fn=eval_fn, donate=donate,
        guard=guard, on_chunk=on_chunk)
    return state, horizon


# ------------------------------------------------------------------- CLI


@dataclasses.dataclass(frozen=True)
class CliFlag:
    """One row of the declarative spec<->argparse table.

    ``optional`` rows default to None on the parser and are skipped by
    :func:`spec_from_args` when unset -- for flags that *override* another
    row's field only when given (``--group-rounds`` over ``--E``) or whose
    spec default is genuinely None (``--max-staleness``).
    """

    field: str                     # ExperimentSpec field ("schedule.x" ok)
    flag: str                      # e.g. "--client-participation"
    help: str
    type: Callable = str
    choices: tuple | None = None
    nargs: str | None = None
    optional: bool = False

    @property
    def dest(self) -> str:
        return self.flag.lstrip("-").replace("-", "_")


def _parse_group_rounds(s: str) -> tuple[int, ...]:
    """'4,2,1' -> (4, 2, 1) -- the --group-rounds argparse type."""
    return tuple(int(part) for part in s.split(","))


#: The one table the CLIs are generated from: every entry maps one
#: ExperimentSpec (or RoundSchedule) field to one argparse flag. Adding a
#: spec knob here surfaces it on every entry point at once.
CLI_FLAGS: tuple[CliFlag, ...] = (
    CliFlag("levels", "--levels", "topology dims, e.g. --levels 2 2 (G K)",
            type=int, nargs="+"),
    CliFlag("schedule.group_rounds", "--E",
            "group aggregations per global round", type=int),
    CliFlag("schedule.group_rounds", "--group-rounds",
            "per-group async round counts, comma-separated (e.g. 4,2,1); "
            "overrides --E", type=_parse_group_rounds, optional=True),
    CliFlag("schedule.local_steps", "--H",
            "local SGD steps per group round", type=int),
    CliFlag("algorithm", "--algorithm", "HFL algorithm",
            choices=ALGORITHMS),
    CliFlag("lr", "--lr", "client learning rate", type=float),
    CliFlag("backend", "--backend", "round engine implementation",
            choices=BACKENDS),
    CliFlag("state_layout", "--state-layout",
            "state storage: contiguous flat buffers or model pytrees",
            choices=LAYOUTS),
    CliFlag("fusion", "--fusion",
            "route the MTGC local step through the fused Pallas kernel",
            choices=FUSIONS),
    CliFlag("client_participation", "--client-participation",
            "fraction of each group's clients sampled per round",
            type=float),
    CliFlag("group_participation", "--group-participation",
            "fraction of groups reachable per round", type=float),
    CliFlag("participation_mode", "--participation-mode",
            "Bernoulli draws or exact counts", choices=("uniform", "fixed")),
    CliFlag("participation_weighting", "--weighting",
            "masked-aggregation weighting: realized count or inverse "
            "inclusion probability (Horvitz-Thompson)",
            choices=("none", "inverse_prob")),
    CliFlag("staleness", "--staleness-policy",
            "stale-report policy for async (non-uniform) group rounds",
            choices=STALENESS_POLICIES),
    CliFlag("max_staleness", "--max-staleness",
            "bound on report staleness; groups beyond it are force-synced",
            type=int, optional=True),
    CliFlag("population", "--population",
            "virtual clients per group, backed by the host-side population "
            "store; device state stays cohort-shaped", type=int,
            optional=True),
    CliFlag("cohort_size", "--cohort-size",
            "sampled cohort per group -- must equal levels[1], the compiled "
            "shape (declarative alias; requires --population)", type=int,
            optional=True),
    CliFlag("client_state", "--client-state",
            "stateful persists per-client corrections in the population "
            "store; stateless zero-inits them every round (no store)",
            choices=CLIENT_STATES),
    CliFlag("faults.crash_rate", "--fault-crash",
            "per-(round, client) crash probability -- a crashed client "
            "does no local work and uploads nothing", type=float,
            optional=True),
    CliFlag("faults.timeout_rate", "--fault-timeout",
            "per-(round, group) timeout probability -- the group misses "
            "the global exchange", type=float, optional=True),
    CliFlag("faults.corrupt_rate", "--fault-corrupt",
            "per-(round, client) corrupted-upload probability", type=float,
            optional=True),
    CliFlag("faults.corrupt_kind", "--fault-kind",
            "corrupted-upload payload: nan/inf poison or a norm-exploded "
            "delta", choices=FAULT_KINDS, optional=True),
    CliFlag("defense.screen_norm", "--screen-norm",
            "screen out client deltas whose L2 norm exceeds this",
            type=float, optional=True),
    CliFlag("defense.clip_norm", "--clip-norm",
            "clip surviving client deltas to this L2 norm", type=float,
            optional=True),
    CliFlag("defense.screen_nonfinite", "--screen-nonfinite",
            "screen out non-finite client uploads (1, the plan default; "
            "0 disables)", type=int, optional=True),
    CliFlag("compression.client_mode", "--compress-client",
            "client->group upload compressor",
            choices=COMPRESSION_MODES, optional=True),
    CliFlag("compression.group_mode", "--compress-group",
            "group->global upload compressor",
            choices=COMPRESSION_MODES, optional=True),
    CliFlag("compression.error_feedback", "--error-feedback",
            "carry per-link error-feedback residuals (1, the plan "
            "default; 0 disables)", type=int, optional=True),
    CliFlag("compression.topk_frac", "--topk-frac",
            "fraction of entries a topk link keeps per upload",
            type=float, optional=True),
)

#: Constructors for the nested spec fields CLI rows may target with a
#: dotted ``field`` -- used when the spec default for that field is None.
_NESTED_FIELDS = {"schedule": RoundSchedule, "faults": FaultPlan,
                  "defense": DefensePlan, "compression": CompressionPlan}


def _spec_get(spec: ExperimentSpec, field: str):
    obj = spec
    for part in field.split("."):
        obj = getattr(obj, part)
    return obj


def add_spec_args(parser, *, defaults: ExperimentSpec | None = None,
                  exclude: tuple[str, ...] = ()) -> None:
    """Generate argparse flags for :class:`ExperimentSpec` from the table.

    ``defaults`` seeds each flag's default (so entry points can ship their
    own baseline spec); ``exclude`` drops fields an entry point pins
    (e.g. ``launch.train`` pins ``backend='sharded'``).
    """
    defaults = defaults or ExperimentSpec()
    for row in CLI_FLAGS:
        if row.field in exclude or row.flag in exclude:
            continue
        if row.optional:
            default, kwargs = None, dict(help=row.help)
        else:
            default = _spec_get(defaults, row.field)
            kwargs = dict(help=f"{row.help} (default: {default})")
        if row.choices is not None:
            kwargs["choices"] = row.choices
        else:
            kwargs["type"] = row.type
        if row.nargs is not None:
            kwargs["nargs"] = row.nargs
            kwargs["type"] = row.type
        parser.add_argument(row.flag, default=default, dest=row.dest, **kwargs)


def spec_from_args(args, *, defaults: ExperimentSpec | None = None,
                   **overrides) -> ExperimentSpec:
    """Build the :class:`ExperimentSpec` an argparse namespace describes.

    ``overrides`` (field=value, including ``schedule_*`` shortcuts like
    ``microbatches=1``) win over CLI values -- entry points use them to pin
    backend-specific fields that are not exposed as flags.

    Dotted rows (``schedule.x``, ``faults.x``, ``defense.x``) update the
    nested dataclass via ``dataclasses.replace``; a nested field whose
    spec default is None (no fault plan configured) is constructed from
    its defaults the first time one of its flags is given, so
    ``--fault-crash 0.05`` alone yields a full :class:`FaultPlan`.
    """
    defaults = defaults or ExperimentSpec()
    spec_kw: dict[str, Any] = {}
    nested_kw: dict[str, dict[str, Any]] = {}
    for row in CLI_FLAGS:
        if not hasattr(args, row.dest):
            continue
        value = getattr(args, row.dest)
        if row.optional and value is None:
            continue
        target, _, sub = row.field.partition(".")
        if sub:
            nested_kw.setdefault(target, {})[sub] = value
        else:
            spec_kw[target] = value
    for name, value in overrides.items():
        if name in ("group_rounds", "local_steps", "microbatches", "periods"):
            nested_kw.setdefault("schedule", {})[name] = value
        else:
            spec_kw[name] = value
    for target, kw in nested_kw.items():
        base = getattr(defaults, target)
        if base is None:
            base = _NESTED_FIELDS[target]()
        spec_kw[target] = dataclasses.replace(base, **kw)
    return dataclasses.replace(defaults, **spec_kw)


__all__ = [
    "ALGORITHMS",
    "BACKENDS",
    "BACKEND_ALGORITHMS",
    "CLIENT_STATES",
    "CLI_FLAGS",
    "COMPRESSION_MODES",
    "CliFlag",
    "CompressionPlan",
    "DefensePlan",
    "Engine",
    "ExperimentSpec",
    "FAULT_KINDS",
    "FUSIONS",
    "FaultPlan",
    "GuardReport",
    "GuardSpec",
    "Horizon",
    "LAYOUTS",
    "LoweredChunk",
    "MultiLevelEngine",
    "MultiLevelMetrics",
    "PackedBatches",
    "PopulationStore",
    "RoundSchedule",
    "STALENESS_POLICIES",
    "ShardedEngine",
    "SimulatorEngine",
    "add_spec_args",
    "build",
    "fit",
    "run_population_rounds",
    "spec_from_args",
]
