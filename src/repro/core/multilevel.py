"""MTGC for an arbitrary number of levels (paper Appendix E, Algorithm 2).

The M-level tree is described by ``dims = (N_1, ..., N_M)``: the global
server (level-1 aggregator) has N_1 children, each of those N_2 children,
..., and the leaves (clients) are indexed by (k_1, ..., k_M). Client models
are stacked with leading shape ``dims``; the level-m correction nu_m (one per
edge between a level-m aggregator and its child) has leading shape
``dims[:m]``.

Periods ``P_1 > P_2 > ... > P_M`` with ``P_{m+1} | P_m``: the level-m
aggregation fires every P_m local iterations. We implement the nested form
(deepest aggregation first), which is Algorithm 1 verbatim for M=2 and is
equivalent to Algorithm 2's break-semantics up to correction values that are
immediately re-initialized. Corrections are zero-initialized (the paper's
experimental setting, footnote 2).

Local update (Alg. 2 line 5):  x <- x - lr * (g + sum_m nu_{k_1..k_m}).
Level-m update (line 9):       nu_n += (subtree_mean(n) - parent_mean) / (lr * P_m).
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import tree as tu

PyTree = Any


class MultiLevelState(NamedTuple):
    params: PyTree           # [*dims, ...]
    nus: tuple               # nus[m-1] has leading shape dims[:m], m = 1..M


def multilevel_init(params0: PyTree, dims: Sequence[int]) -> MultiLevelState:
    dims = tuple(dims)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, dims + x.shape), params0
    )
    nus = tuple(
        jax.tree.map(lambda x: jnp.zeros(dims[: m + 1] + x.shape, x.dtype), params0)
        for m in range(len(dims))
    )
    return MultiLevelState(params=stacked, nus=nus)


def _subtree_mean(x: PyTree, level: int, M: int) -> PyTree:
    """Mean over all axes below ``level`` (axes level..M-1). level=0 => global."""
    axes = tuple(range(level, M))
    return tu.tree_mean(x, axis=axes) if axes else x


def _broadcast_back(a: PyTree, dims: tuple, level: int) -> PyTree:
    """Broadcast a [dims[:level], ...] tree back to full [*dims, ...]."""
    M = len(dims)

    def _b(x):
        x = jnp.expand_dims(x, tuple(range(level, M)))
        return jnp.broadcast_to(x, dims + x.shape[M:])

    return jax.tree.map(_b, a)


def make_multilevel_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    dims: Sequence[int],
    periods: Sequence[int],
    lr: float,
) -> Callable[[MultiLevelState, PyTree], tuple[MultiLevelState, jax.Array]]:
    """Build one *global round* (= P_1 local iterations) as a jittable fn.

    batches leaves: [P_1, *dims, ...] -- one batch per local step per client.
    Returns (state, losses[P_1]).
    """
    dims = tuple(dims)
    periods = tuple(periods)
    M = len(dims)
    assert len(periods) == M, "one period per level"
    for a, b in zip(periods, periods[1:]):
        assert a > b and a % b == 0, f"periods must nest: {periods}"

    # Block ratios: level-m block = ratios[m-1] repetitions of level-(m+1)
    # block; the innermost block is P_M local steps.
    ratios = [periods[m] // periods[m + 1] for m in range(M - 1)] + [periods[M - 1]]

    # vmap the per-client grad over every topology axis.
    vg = jax.value_and_grad(loss_fn)
    for _ in range(M):
        vg = jax.vmap(vg)

    def local_step(carry, batch):
        x, nus = carry
        loss, g = vg(x, batch)
        d = g
        for m in range(M):
            d = tu.tree_add(d, _broadcast_back(nus[m], dims, m + 1))
        x = jax.tree.map(lambda xi, di: xi - lr * di, x, d)
        return (x, nus), jnp.mean(loss)

    def make_block(level: int):
        """Block of P_level steps followed by the level-``level`` aggregation."""
        if level == M:
            inner = local_step
        else:
            inner = make_block(level + 1)

        def block(carry, batches_block):
            carry, losses = jax.lax.scan(inner, carry, batches_block)
            x, nus = carry
            # Aggregation at this level (over axes level-1 .. M-1):
            s = _subtree_mean(x, level, M)          # child subtree means
            a = _subtree_mean(x, level - 1, M)      # parent means
            a_to_s = _broadcast_back(a, dims[:level], level - 1) if level >= 1 else a
            nus = list(nus)
            nus[level - 1] = jax.tree.map(
                lambda nu, si, ai: nu + (si - ai) / (lr * periods[level - 1]),
                nus[level - 1], s, a_to_s,
            )
            # Re-initialize deeper corrections (Alg. 2 line 11).
            for m in range(level, M):
                nus[m] = tu.tree_zeros_like(nus[m])
            # Dissemination: every client under a parent restarts from it.
            x = _broadcast_back(a, dims, level - 1)
            return (x, tuple(nus)), losses

        return block

    top = make_block(1)

    def round_fn(state: MultiLevelState, batches: PyTree):
        # Reshape flat [P_1, ...] leading axis into the nested block shape.
        lead = tuple(ratios)

        def _reshape(b):
            return b.reshape(lead + b.shape[1:])

        nested = jax.tree.map(_reshape, batches)
        # The top block's scan consumes axis 0 (ratio r_1); feed it whole.
        (carry, losses) = top((state.params, state.nus), nested)
        x, nus = carry
        return MultiLevelState(params=x, nus=nus), losses.reshape(-1)

    return round_fn


def multilevel_global_model(state: MultiLevelState) -> PyTree:
    # All clients are equal between rounds; index the first leaf client.
    ndim_lead = len(state.nus)
    return jax.tree.map(lambda a: a[(0,) * ndim_lead], state.params)
