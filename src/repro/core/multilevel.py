"""MTGC for an arbitrary number of levels (paper Appendix E, Algorithm 2).

The M-level tree is described by ``dims = (N_1, ..., N_M)``: the global
server (level-1 aggregator) has N_1 children, each of those N_2 children,
..., and the leaves (clients) are indexed by (k_1, ..., k_M). Client models
are stacked with leading shape ``dims``; the level-m correction nu_m (one per
edge between a level-m aggregator and its child) has leading shape
``dims[:m]``.

Periods ``P_1 > P_2 > ... > P_M`` with ``P_{m+1} | P_m``: the level-m
aggregation fires every P_m local iterations. We implement the nested form
(deepest aggregation first), which is Algorithm 1 verbatim for M=2 and is
equivalent to Algorithm 2's break-semantics up to correction values that are
immediately re-initialized. Corrections are zero-initialized (the paper's
experimental setting, footnote 2).

Local update (Alg. 2 line 5):  x <- x - lr * (g + sum_m nu_{k_1..k_m}).
Level-m update (line 9):       nu_n += (subtree_mean(n) - parent_mean) / (lr * P_m).

Partial participation (beyond the paper): ``participation[m]`` is the
fraction of level-(m+1) nodes whose uplink is live each global round; a
node is *active* iff its whole ancestor chain is live. Aggregations become
hierarchical masked means over active subtrees (child-equal-weighted, the
M-level generalization of the two-level engine's group-then-global masked
means), frozen subtrees keep their params and nus, and nu updates /
re-initializations fire only where an active leaf exists. Masks are data --
the nested scans are unchanged, and with full participation the masked
machinery is compiled out.

``participation_weighting="inverse_prob"`` swaps every level's
realized-count mean for the Horvitz-Thompson estimator: the level-m
aggregation divides the chain-masked sum over its children by the
*expected* live-child count ``inclusion_prob(participation[m]) * dims[m]``
(a chain-live node whose subtree came up empty contributing a legitimate
zero), mirroring the two-level engine's ``cfg.participation_weighting``.
State gating (frozen subtrees, nu updates only where an active leaf
exists) is weighting-independent.

Flat state (``multilevel_init(..., use_flat_state=True)``): params and
every nu level are packed into contiguous ``[*lead, N]`` buffers
(core/packer.py) and the round adapts at trace time, mirroring the
two-level engine: the nu-sum is constant across the innermost P_M-step
block, so it collapses into one precomputed correction tensor, tree views
are produced once per innermost block (the gradient loop pays no repack
traffic), and every level's aggregation / nu update / dissemination runs as
whole-model ops. Parity with the pytree path is covered by
tests/test_flat_state.py.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import tree as tu
from repro.core.packer import FlatBuffers, as_tree, is_flat, make_packer
from repro.core.participation import inclusion_prob, sample_axis_mask

PyTree = Any


class MultiLevelState(NamedTuple):
    params: PyTree           # [*dims, ...]
    nus: tuple               # nus[m-1] has leading shape dims[:m], m = 1..M
    rng: jax.Array | None = None  # participation sampling key


def multilevel_init(
    params0: PyTree, dims: Sequence[int], rng: jax.Array | None = None,
    *, use_flat_state: bool = False,
) -> MultiLevelState:
    dims = tuple(dims)
    rng = jax.random.PRNGKey(0) if rng is None else rng
    if use_flat_state:
        packer = make_packer(params0)
        flat0 = packer.flatten(params0)
        stacked = FlatBuffers(
            {k: jnp.broadcast_to(b, dims + b.shape) for k, b in flat0.bufs.items()},
            packer,
        )
        nus = tuple(packer.zeros(dims[: m + 1]) for m in range(len(dims)))
        return MultiLevelState(params=stacked, nus=nus, rng=rng)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, dims + x.shape), params0
    )
    nus = tuple(
        jax.tree.map(lambda x: jnp.zeros(dims[: m + 1] + x.shape, x.dtype), params0)
        for m in range(len(dims))
    )
    return MultiLevelState(params=stacked, nus=nus, rng=rng)


def _subtree_mean(x: PyTree, level: int, M: int) -> PyTree:
    """Mean over all axes below ``level`` (axes level..M-1). level=0 => global."""
    axes = tuple(range(level, M))
    return tu.tree_mean(x, axis=axes) if axes else x


def _broadcast_back(a: PyTree, dims: tuple, level: int) -> PyTree:
    """Broadcast a [dims[:level], ...] tree back to full [*dims, ...]."""
    M = len(dims)

    def _b(x):
        x = jnp.expand_dims(x, tuple(range(level, M)))
        return jnp.broadcast_to(x, dims + x.shape[M:])

    return jax.tree.map(_b, a)


def _masked_levels(x: PyTree, leaf_act: jax.Array, to_level: int, dims: tuple):
    """Hierarchical masked means from the leaves down to ``to_level``.

    Child-equal-weighted: a level-a node's value is the plain mean of its
    *active* children's values, where a child is active iff some leaf in its
    subtree is active. Returns (vals, acts) with vals[l] = mean tree with
    leading shape dims[:l] and acts[l] = 0/1 activity of level-l nodes, for
    l in [to_level, M]. Inactive slices fall back to unmasked means; their
    activity bit is 0 so downstream updates never read them.
    """
    M = len(dims)
    vals = {M: x}
    acts = {M: leaf_act}
    val, w = x, leaf_act
    for a in range(M - 1, to_level - 1, -1):
        has = jnp.sum(w, axis=a) > 0
        val = tu.tree_masked_mean(val, w, axis=a)
        w = has.astype(jnp.float32)
        vals[a] = val
        acts[a] = w
    return vals, acts


def _masked_levels_ht(x: PyTree, chains: tuple, leaf_act: jax.Array,
                      to_level: int, dims: tuple, denoms: tuple):
    """Horvitz-Thompson variant of :func:`_masked_levels`.

    Only the *outermost* step (axis ``to_level``) of an aggregation event
    is estimation: the level-(to_level+1) node values diverged since their
    own deeper aggregations, so their chain-masked sum (``chains[m]``
    marks nodes whose whole uplink chain to the root is live) divides by
    the fixed expected live-child count ``denoms[to_level]``, a node with
    no active leaf contributing an exact zero. Every deeper axis is
    *recovery*: all active leaves below a level-(to_level+1) node hold
    that node's identical disseminated value -- whose own weighting was
    already applied when it was produced -- so realized-count means read
    it back exactly; re-applying the fixed denominator there would rescale
    the recovered value by realized/expected count (the same
    recovery-vs-estimation split as the two-level engine's global step).
    For the deepest block (``to_level == M-1``) the single step aggregates
    leaves fresh out of a local phase -- pure estimation.

    Activity gating (``acts``) is identical to the realized-count variant
    so state updates freeze the same replicas under either weighting.
    """
    vals, acts = _masked_levels(x, leaf_act, to_level + 1, dims)
    top, act_top = vals[to_level + 1], acts[to_level + 1]
    # Subtrees with no active leaf contribute an exact zero to the HT sum
    # (where, not multiplication, so frozen non-finite replicas can't
    # leak through the recovered value).
    top0 = jax.tree.map(
        lambda v: jnp.where(tu.expand_mask(act_top, v) != 0, v, 0), top)
    vals[to_level] = tu.tree_masked_mean(
        top0, chains[to_level], axis=to_level, denom=denoms[to_level])
    acts[to_level] = (jnp.sum(act_top, axis=to_level) > 0).astype(jnp.float32)
    return vals, acts


def make_multilevel_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    dims: Sequence[int],
    periods: Sequence[int],
    lr: float,
    *,
    participation: Sequence[float] | None = None,
    participation_mode: str = "uniform",
    participation_weighting: str = "none",
) -> Callable[[MultiLevelState, PyTree], tuple[MultiLevelState, jax.Array]]:
    """Build one *global round* (= P_1 local iterations) as a jittable fn.

    .. deprecated::
        ``make_multilevel_round`` is the legacy constructor; new code
        should declare an ``ExperimentSpec(backend="multilevel",
        schedule=RoundSchedule(periods=...))`` and use
        ``repro.api.build(spec, loss_fn)`` -- this shim delegates to that
        adapter (its ``legacy_round_fn``, which keeps this function's
        ``[P_1, *dims, ...]`` batch contract; the adapter's own
        ``round_fn`` speaks the driver layout ``[E, H, *dims, ...]``).

    batches leaves: [P_1, *dims, ...] -- one batch per local step per client.
    ``participation[m]`` (optional, one per level) is the per-round fraction
    of live level-(m+1) uplinks; ``participation_weighting`` selects the
    realized-count ('none') or Horvitz-Thompson ('inverse_prob') masked
    aggregation (see module docstring). Returns (state, losses[P_1]).
    """
    import warnings

    from repro.core.api import ExperimentSpec, RoundSchedule, build

    warnings.warn(
        "make_multilevel_round is deprecated: declare an "
        "ExperimentSpec(backend='multilevel', "
        "schedule=RoundSchedule(periods=...)) and use "
        "repro.api.build(spec, loss_fn)",
        DeprecationWarning, stacklevel=2)

    dims = tuple(int(n) for n in dims)
    periods = tuple(int(p) for p in periods)
    spec = ExperimentSpec(
        levels=dims,
        schedule=RoundSchedule(group_rounds=max(periods[0] // periods[-1], 1),
                               local_steps=periods[-1], periods=periods),
        algorithm="mtgc",
        lr=lr,
        backend="multilevel",
        state_layout="tree",  # the round adapts to the state at trace time
        level_participation=(None if participation is None
                             else tuple(float(p) for p in participation)),
        participation_mode=participation_mode,
        participation_weighting=participation_weighting,
    )
    return build(spec, loss_fn).legacy_round_fn


def _build_multilevel_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    dims: Sequence[int],
    periods: Sequence[int],
    lr: float,
    *,
    participation: Sequence[float] | None = None,
    participation_mode: str = "uniform",
    participation_weighting: str = "none",
) -> Callable[[MultiLevelState, PyTree], tuple[MultiLevelState, jax.Array]]:
    """The real M-level round builder behind ``repro.api``'s adapter."""
    dims = tuple(dims)
    periods = tuple(periods)
    M = len(dims)
    if len(periods) != M:
        raise ValueError(f"one period per level: {periods} for {M} levels")
    for a, b in zip(periods, periods[1:]):
        if not (a > b and a % b == 0):
            raise ValueError(f"periods must nest: {periods}")
    if participation_weighting not in ("none", "inverse_prob"):
        raise ValueError(
            f"unknown participation_weighting {participation_weighting!r}")
    if participation is not None:
        participation = tuple(float(p) for p in participation)
        if len(participation) != M:
            raise ValueError("one participation fraction per level: "
                             f"{participation} for {M} levels")
        if not all(0.0 < p <= 1.0 for p in participation):
            raise ValueError(
                f"participation fractions must be in (0, 1]: {participation}")
    partial = participation is not None and any(p < 1.0 for p in participation)
    ht = partial and participation_weighting == "inverse_prob"
    denoms = (tuple(
        inclusion_prob(participation[m], dims[m], participation_mode) * dims[m]
        for m in range(M)) if ht else None)

    # Block ratios: level-m block = ratios[m-1] repetitions of level-(m+1)
    # block; the innermost block is P_M local steps.
    ratios = [periods[m] // periods[m + 1] for m in range(M - 1)] + [periods[M - 1]]

    # vmap the per-client grad over every topology axis.
    vg = jax.value_and_grad(loss_fn)
    for _ in range(M):
        vg = jax.vmap(vg)

    def local_step(carry, batch):
        x, nus, act, chains = carry
        loss, g = vg(x, batch)
        d = g
        for m in range(M):
            d = tu.tree_add(d, _broadcast_back(nus[m], dims, m + 1))
        x_new = jax.tree.map(lambda xi, di: xi - lr * di, x, d)
        if partial:
            x = tu.tree_select(act, x_new, x)
            lmean = jnp.sum(jnp.where(act != 0, loss, 0)) / jnp.maximum(
                jnp.sum(act), 1.0)
        else:
            x = x_new
            lmean = jnp.mean(loss)
        return (x, nus, act, chains), lmean

    def _flat_local_phase(x, nus, act, batches_block):
        """Innermost P_M steps on a flat state: repack at the block boundary.

        The nu-sum is constant across the block, so it is materialized once
        as a single flat add per level and unpacked alongside the params;
        the participation gate folds into the fused update expression.
        """
        packer = x.packer
        corr = None
        for m in range(M):
            bb = _broadcast_back(nus[m], dims, m + 1)
            corr = bb if corr is None else tu.tree_add(corr, bb)
        corr_t = packer.unflatten(corr)

        def step(x_t, batch):
            loss, g = vg(x_t, batch)

            def upd(xi, gi, ci):
                x_new = xi - lr * (gi + ci)
                if partial:
                    return jnp.where(tu.expand_mask(act, x_new) != 0, x_new, xi)
                return x_new

            x_t = jax.tree.map(upd, x_t, g, corr_t)
            if partial:
                lmean = jnp.sum(jnp.where(act != 0, loss, 0)) / jnp.maximum(
                    jnp.sum(act), 1.0)
            else:
                lmean = jnp.mean(loss)
            return x_t, lmean

        x_t, losses = jax.lax.scan(step, packer.unflatten(x), batches_block)
        return packer.flatten(x_t), losses

    def make_block(level: int):
        """Block of P_level steps followed by the level-``level`` aggregation."""
        if level == M:
            def run_inner(carry, batches_block):
                x, nus, act, chains = carry
                if is_flat(x):
                    x, losses = _flat_local_phase(x, nus, act, batches_block)
                    return (x, nus, act, chains), losses
                return jax.lax.scan(local_step, carry, batches_block)
        else:
            inner = make_block(level + 1)

            def run_inner(carry, batches_block):
                return jax.lax.scan(inner, carry, batches_block)

        def block(carry, batches_block):
            carry, losses = run_inner(carry, batches_block)
            x, nus, act, chains = carry
            nus = list(nus)
            if partial:
                # Masked aggregation: child means at ``level`` and parent
                # means at ``level - 1`` -- over active subtrees only
                # (realized count) or chain-masked Horvitz-Thompson sums
                # over expected counts (inverse_prob).
                if ht:
                    vals, acts = _masked_levels_ht(
                        x, chains, act, level - 1, dims, denoms)
                else:
                    vals, acts = _masked_levels(x, act, level - 1, dims)
                s, a_val = vals[level], vals[level - 1]
                a_to_s = (_broadcast_back(a_val, dims[:level], level - 1)
                          if level >= 1 else a_val)
                nu_new = jax.tree.map(
                    lambda nu, si, ai: nu + (si - ai) / (lr * periods[level - 1]),
                    nus[level - 1], s, a_to_s,
                )
                nus[level - 1] = tu.tree_select(acts[level], nu_new, nus[level - 1])
                # Re-initialize deeper corrections (Alg. 2 line 11) only
                # where the subtree took part in this block.
                for m in range(level, M):
                    nus[m] = tu.tree_select(
                        acts[m + 1], tu.tree_zeros_like(nus[m]), nus[m])
                # Dissemination: active leaves restart from their
                # level-(level-1) ancestor; frozen leaves keep their params.
                x = tu.tree_select(act, _broadcast_back(a_val, dims, level - 1), x)
            else:
                # Aggregation at this level (over axes level-1 .. M-1):
                s = _subtree_mean(x, level, M)          # child subtree means
                a = _subtree_mean(x, level - 1, M)      # parent means
                a_to_s = _broadcast_back(a, dims[:level], level - 1) if level >= 1 else a
                nus[level - 1] = jax.tree.map(
                    lambda nu, si, ai: nu + (si - ai) / (lr * periods[level - 1]),
                    nus[level - 1], s, a_to_s,
                )
                # Re-initialize deeper corrections (Alg. 2 line 11).
                for m in range(level, M):
                    nus[m] = tu.tree_zeros_like(nus[m])
                # Dissemination: every client under a parent restarts from it.
                x = _broadcast_back(a, dims, level - 1)
            return (x, tuple(nus), act, chains), losses

        return block

    top = make_block(1)

    def round_fn(state: MultiLevelState, batches: PyTree):
        if partial:
            mkey, rng = jax.random.split(state.rng)
            keys = jax.random.split(mkey, M)
            leaf_act, chains = None, []
            for m in range(M):
                mask = sample_axis_mask(
                    keys[m], dims[: m + 1], participation[m], participation_mode)
                leaf_act = mask if leaf_act is None else (
                    leaf_act.reshape(leaf_act.shape + (1,)) * mask)
                # chains[m]: level-(m+1) node's whole uplink chain is live.
                chains.append(leaf_act)
            chains = tuple(chains)
        else:
            leaf_act, chains = None, ()
            rng = state.rng

        # Reshape flat [P_1, ...] leading axis into the nested block shape.
        lead = tuple(ratios)

        def _reshape(b):
            return b.reshape(lead + b.shape[1:])

        nested = jax.tree.map(_reshape, batches)
        # The top block's scan consumes axis 0 (ratio r_1); feed it whole.
        (carry, losses) = top(
            (state.params, state.nus, leaf_act, chains), nested)
        x, nus, _, _ = carry
        return MultiLevelState(params=x, nus=nus, rng=rng), losses.reshape(-1)

    return round_fn


def multilevel_global_model(state: MultiLevelState) -> PyTree:
    # All clients are equal between full-participation rounds; index the
    # first leaf client (flat states unpack back into the model tree).
    ndim_lead = len(state.nus)
    return as_tree(jax.tree.map(lambda a: a[(0,) * ndim_lead], state.params))
