"""Flat (star-topology) SCAFFOLD [Karimireddy et al., 2020].

Used for the paper's Sec. 3.3 claim: MTGC with N=1 groups and E=1 group
rounds *is* SCAFFOLD. We implement both control-variate options:

* option I  (fresh gradient): c_i = grad F_i(x^t, xi) at round start --
  this is what MTGC's theoretical correction init (Alg. 1 line 3) reduces to,
  so the reduction test uses option='I'.
* option II (model difference): c_i <- c_i - c + (x^t - x_{i,H}) / (H lr).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree as tu

PyTree = Any


class ScaffoldState(NamedTuple):
    params: PyTree  # [K, ...] per-client models
    c_i: PyTree     # [K, ...] client control variates
    c: PyTree       # [...]    server control variate


def scaffold_init(params0: PyTree, num_clients: int) -> ScaffoldState:
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_clients,) + x.shape), params0
    )
    return ScaffoldState(
        params=stacked,
        c_i=tu.tree_zeros_like(stacked),
        c=tu.tree_zeros_like(params0),
    )


def make_scaffold_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    num_clients: int,
    local_steps: int,
    lr: float,
    option: str = "I",
) -> Callable[[ScaffoldState, PyTree], tuple[ScaffoldState, jax.Array]]:
    """batches leaves: [H, K, ...]."""
    K, H = num_clients, local_steps
    vg = jax.vmap(jax.value_and_grad(loss_fn))

    def round_fn(state: ScaffoldState, batches: PyTree):
        x0 = state.params
        if option == "I":
            # Fresh-gradient control variates, evaluated at the round-start
            # model with the first local batch (matches MTGC Alg. 1 line 3).
            b0 = jax.tree.map(lambda b: b[0], batches)
            _, c_i = vg(x0, b0)
            c_cur = tu.tree_mean(c_i, axis=0)
        else:
            c_i = state.c_i
            c_cur = state.c
        c_b = tu.tree_broadcast_to_axis(c_cur, 0, K)

        def step(x, batch):
            loss, g = vg(x, batch)
            x = jax.tree.map(
                lambda xi, gi, cii, ci: xi - lr * (gi - cii + ci), x, g, c_i, c_b
            )
            return x, jnp.mean(loss)

        x_end, losses = jax.lax.scan(step, x0, batches)

        if option == "II":
            c_i = jax.tree.map(
                lambda cii, ci, x0i, xe: cii - ci + (x0i - xe) / (H * lr),
                c_i, c_b, x0, x_end,
            )
        xbar = tu.tree_mean(x_end, axis=0)
        c = tu.tree_mean(c_i, axis=0)
        params = jax.tree.map(
            lambda xg: jnp.broadcast_to(xg, (K,) + xg.shape), xbar
        )
        return ScaffoldState(params=params, c_i=c_i, c=c), losses

    return round_fn
