"""Flat parameter buffers: pack per-client pytrees into contiguous arrays.

The HFL engines stack every state tree with leading topology axes
(``[G, K, ...]`` per-client, ``[G, ...]`` per-group). Stored as pytrees,
each round executes its algebra *per leaf*: one XLA op (or one Pallas
dispatch plus one lane-padding) per parameter tensor per operation, and the
trace/compile cost scales with ``leaves x steps``. This module packs all
model leaves into **one contiguous buffer per dtype** -- leading topology
axes preserved, trailing axis the concatenation of every raveled leaf -- so
the round's element-wise algebra and reductions become a handful of
whole-model ops.

Layout::

    FlatBuffers(bufs={"float32": f32_buf, ...}, packer=<static Packer>)
      f32_buf: [*lead, N_f32]   N_f32 = sum of sizes of all f32 leaves

``Packer`` is the static segment table: for every template leaf it records
which dtype-buffer it lives in, its offset/size and its shape, plus the
treedef to rebuild the tree. It is hashable and comparable, so it rides
along as pytree aux data: a ``FlatBuffers`` is itself a registered pytree
(children = the per-dtype buffers) and moves through ``jit`` / ``scan`` /
``vmap`` / ``jax.grad`` like any other state, while every consumer can
recover tree views via :meth:`FlatBuffers.to_tree` without a side channel.

The repack boundary is chosen by the engines, not forced per step: packing
and unpacking are plain slice/reshape/concat ops (no autodiff through the
segment table -- gradients are taken per leaf and repacked), so the engines
unpack once per local phase, keep the gradient hot loop on tree views, and
run every aggregation / correction / dissemination on the flat buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Segment:
    """Where one template leaf lives inside its dtype buffer."""

    buffer: str            # dtype key, e.g. "float32"
    offset: int            # start (in elements) inside the buffer
    size: int              # number of elements
    shape: tuple[int, ...]  # original leaf shape (without leading axes)


@dataclasses.dataclass(frozen=True)
class Packer:
    """Static pack/unpack table built from a template pytree.

    The template is the *single-model* tree (no topology axes); ``flatten``
    and ``unflatten`` then accept any number of leading axes, inferred per
    call from the difference between actual and template leaf ranks.
    """

    treedef: Any                      # jax treedef (hashable)
    segments: tuple[Segment, ...]     # one per template leaf, in leaf order
    buffer_sizes: tuple[tuple[str, int], ...]  # (dtype key, total elements)

    @property
    def num_params(self) -> int:
        return sum(n for _, n in self.buffer_sizes)

    def flatten(self, tree: PyTree) -> "FlatBuffers":
        """Pack ``tree`` (template structure + arbitrary leading axes)."""
        leaves = self.treedef.flatten_up_to(tree)
        lead = None
        parts: dict[str, list[jax.Array]] = {key: [] for key, _ in self.buffer_sizes}
        for seg, leaf in zip(self.segments, leaves):
            nlead = leaf.ndim - len(seg.shape)
            if lead is None:
                lead = leaf.shape[:nlead]
            parts[seg.buffer].append(leaf.reshape(lead + (seg.size,)))
        bufs = {
            key: (chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks, axis=-1))
            for key, chunks in parts.items()
        }
        return FlatBuffers(bufs, self)

    def unflatten(self, flat: "FlatBuffers | dict[str, jax.Array]") -> PyTree:
        """Rebuild the template-structured tree (leading axes preserved)."""
        bufs = flat.bufs if isinstance(flat, FlatBuffers) else flat
        leaves = []
        for seg in self.segments:
            buf = bufs[seg.buffer]
            lead = buf.shape[:-1]
            leaves.append(
                buf[..., seg.offset:seg.offset + seg.size].reshape(lead + seg.shape)
            )
        return self.treedef.unflatten(leaves)

    def zeros(self, lead: tuple[int, ...] = ()) -> "FlatBuffers":
        """Zero-filled flat buffers with the given leading axes."""
        bufs = {
            key: jnp.zeros(tuple(lead) + (n,), jnp.dtype(key))
            for key, n in self.buffer_sizes
        }
        return FlatBuffers(bufs, self)

    def state_bytes(self, lead: tuple[int, ...] = ()) -> int:
        """Total bytes of the flat buffers under the given leading axes.

        Computed from the static segment table -- no arrays are built -- so
        memory claims (e.g. cohort-vs-population device footprints in
        ``benchmarks/bench_population.py``) derive from the same table that
        drives pack/unpack rather than from sampled process RSS.
        """
        mult = int(np.prod(lead)) if lead else 1
        return sum(
            mult * n * np.dtype(key).itemsize for key, n in self.buffer_sizes
        )

    def size_report(self, lead: tuple[int, ...] = ()) -> dict[str, Any]:
        """Per-dtype-buffer size breakdown under the given leading axes.

        Returns ``{"lead": lead, "total_bytes": ..., "buffers": {dtype:
        {"elements", "bytes", "leaves"}}}`` -- the machine-readable form the
        benchmarks embed in their ``BENCH_*.json`` artifacts.
        """
        mult = int(np.prod(lead)) if lead else 1
        leaves_per = {key: 0 for key, _ in self.buffer_sizes}
        for seg in self.segments:
            leaves_per[seg.buffer] += 1
        buffers = {
            key: {
                "elements": mult * n,
                "bytes": mult * n * np.dtype(key).itemsize,
                "leaves": leaves_per[key],
            }
            for key, n in self.buffer_sizes
        }
        return {
            "lead": tuple(lead),
            "total_bytes": self.state_bytes(lead),
            "buffers": buffers,
        }


def make_packer(template: PyTree) -> Packer:
    """Build the static segment table from a single-model template tree."""
    leaves, treedef = jax.tree.flatten(template)
    offsets: dict[str, int] = {}
    segments = []
    for leaf in leaves:
        key = jnp.asarray(leaf).dtype.name
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        off = offsets.get(key, 0)
        segments.append(Segment(key, off, size, tuple(leaf.shape)))
        offsets[key] = off + size
    return Packer(
        treedef=treedef,
        segments=tuple(segments),
        buffer_sizes=tuple(sorted(offsets.items())),
    )


class FlatBuffers:
    """A pytree of contiguous per-dtype buffers + the packer that made them.

    Children are the buffers (stable, key-sorted order); the ``(keys,
    packer)`` pair is static aux data, so two FlatBuffers from the same
    packer are tree-compatible and flow through ``jax.tree.map`` together.
    """

    __slots__ = ("bufs", "packer")

    def __init__(self, bufs: dict[str, jax.Array], packer: Packer):
        self.bufs = dict(bufs)
        self.packer = packer

    def to_tree(self) -> PyTree:
        """Unpack back into the template-structured tree."""
        return self.packer.unflatten(self)

    @property
    def lead_shape(self) -> tuple[int, ...]:
        return next(iter(self.bufs.values())).shape[:-1]

    def __repr__(self) -> str:
        shapes = {k: tuple(v.shape) for k, v in self.bufs.items()}
        return f"FlatBuffers({shapes})"


def _flat_buffers_flatten_with_keys(fb: FlatBuffers):
    keys = tuple(sorted(fb.bufs))
    children = tuple(
        (jax.tree_util.DictKey(k), fb.bufs[k]) for k in keys
    )
    return children, (keys, fb.packer)


def _flat_buffers_flatten(fb: FlatBuffers):
    keys = tuple(sorted(fb.bufs))
    return tuple(fb.bufs[k] for k in keys), (keys, fb.packer)


def _flat_buffers_unflatten(aux, children) -> FlatBuffers:
    keys, packer = aux
    return FlatBuffers(dict(zip(keys, children)), packer)


jax.tree_util.register_pytree_with_keys(
    FlatBuffers, _flat_buffers_flatten_with_keys, _flat_buffers_unflatten,
    _flat_buffers_flatten,
)


def is_flat(tree: PyTree) -> bool:
    return isinstance(tree, FlatBuffers)


def as_tree(tree: PyTree) -> PyTree:
    """Unpack FlatBuffers into its template tree; identity on plain trees.

    Callers unpack the exact object they index (e.g. ``as_tree(state.z)["w"]``);
    nested containers of FlatBuffers are not searched.
    """
    return tree.to_tree() if isinstance(tree, FlatBuffers) else tree
