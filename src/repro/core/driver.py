"""Whole-horizon compiled training: scan-over-rounds with donated buffers.

``core.engine`` (and ``launch.train``) compile *one global round* into a
single XLA program; every entry point then drives it from a Python host
loop -- one dispatch per round with host-side batch packing in between. At
paper scale (T = 100 global rounds, G*K = 100 clients) that loop pays
per-round dispatch latency, a host->device transfer of every batch, and a
host sync for the metrics of every round; and without donation each round
briefly holds two copies of the parameter-sized state buffers.

This module lifts the loop onto the device:

* **Packed dataset** (:class:`PackedBatches`): for every client, ``shards``
  pre-formed blocks of ``steps = H * max(A, 1)`` step-batches are sampled
  once on the host and uploaded once -- leaves ``[G, K, S, steps, B, ...]``.
  Each round then draws ``[E, G, K]`` shard indices from a dedicated data
  PRNG key and gathers its batches *on device* (:func:`select_round`); the
  host never packs or transfers batches again.
* **Compiled horizon** (:func:`run_rounds`): ``chunk`` global rounds run as
  one ``jax.lax.scan`` inside a single ``jax.jit`` with the state argument
  donated (``donate_argnums``), so the round-to-round state hand-off reuses
  the input buffers instead of holding two parameter-sized copies, and T
  rounds cost ceil(T / chunk) dispatches. Per-round metrics come back
  stacked, one transfer per chunk. Scan lowers to a while loop, so compile
  time is independent of ``chunk``; chunking exists to bound how much work
  a single dispatch commits to (progress visibility, interruptibility) --
  the remainder chunk triggers at most one extra compile.
* **Per-round fallback** (:func:`make_round_step`): the same select + round
  step as a single donated dispatch, for host loops that need per-round
  control. ``run_rounds`` over the same :class:`PackedBatches` is bit-exact
  against this loop (gated by tests/test_driver.py).

Evaluation stays compiled: ``eval_fn(prev_state, state)`` runs inside the
scan under ``jax.lax.cond``, gated by a per-round boolean mask computed on
the host from ``eval_every`` (plus the final round), so eval work is only
spent on the rounds that report. ``prev_state`` is the pre-round state --
under partial participation its ``rng`` re-derives the round's masks (see
``core.participation``), e.g. to pick an active replica to evaluate.

The driver is layout- and engine-agnostic: ``round_fn`` may be any
``(state, batches) -> (state, metrics)`` function (simulator engine, tree
or flat state, or the sharded production round -- set ``microbatches`` for
its ``[E, H, A, G, K, ...]`` batch layout), and the participation RNG stays
where it always was, inside the engine state.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class PackedBatches:
    """A once-uploaded, device-resident training dataset for the driver.

    arrays: pytree whose leaves are ``[G, K, S, steps, ...]`` -- ``S``
        pre-sampled blocks per client, each holding ``steps`` step-batches
        (``steps = local_steps * max(microbatches, 1)``). Deeper topologies
        (the M-level engine) carry all their client axes up front:
        ``[*dims, S, steps, ...]`` with ``topo_ndim = len(dims)``.
    rng: PRNG key advanced one split per round for shard selection.
    group_rounds / local_steps / microbatches: static layout of one round.
        ``microbatches=None`` emits engine-layout batches ``[E, H, G, K,
        ...]``; an integer emits the sharded microbatched layout
        ``[E, H, A, G, K, ...]``. A per-group ``group_rounds`` tuple
        (async schedules) packs its padded maximum.
    topo_ndim: how many leading leaf axes index the client topology
        (2 for the two-level engines; M for an M-level tree, where the
        selected batches come back ``[E, H, *dims, ...]``).

    Registered as a pytree (children: arrays + rng; the layout is static
    aux data), so it can cross ``jit`` boundaries whole.
    """

    __slots__ = ("arrays", "rng", "group_rounds", "local_steps",
                 "microbatches", "topo_ndim")

    def __init__(self, arrays: PyTree, rng: jax.Array,
                 group_rounds: int | tuple[int, ...],
                 local_steps: int, microbatches: int | None = None,
                 topo_ndim: int = 2):
        self.arrays = arrays
        self.rng = rng
        if isinstance(group_rounds, (list, tuple)):
            # Async per-group schedules pack the padded max(E_g) axis;
            # stragglers' dead iterations draw shards that the engines'
            # iteration mask then gates out of every aggregate.
            group_rounds = max(int(e) for e in group_rounds)
        self.group_rounds = int(group_rounds)
        self.local_steps = int(local_steps)
        self.microbatches = None if microbatches is None else int(microbatches)
        self.topo_ndim = int(topo_ndim)

    @property
    def num_shards(self) -> int:
        return jax.tree.leaves(self.arrays)[0].shape[self.topo_ndim]

    def replace_rng(self, rng: jax.Array) -> "PackedBatches":
        return PackedBatches(self.arrays, rng, self.group_rounds,
                             self.local_steps, self.microbatches,
                             self.topo_ndim)

    def __repr__(self) -> str:
        shapes = [tuple(x.shape) for x in jax.tree.leaves(self.arrays)]
        return (f"PackedBatches(E={self.group_rounds}, H={self.local_steps}, "
                f"A={self.microbatches}, leaves={shapes})")


def _packed_flatten(pb: PackedBatches):
    return ((pb.arrays, pb.rng),
            (pb.group_rounds, pb.local_steps, pb.microbatches, pb.topo_ndim))


def _packed_unflatten(aux, children) -> PackedBatches:
    arrays, rng = children
    return PackedBatches(arrays, rng, *aux)


jax.tree_util.register_pytree_node(PackedBatches, _packed_flatten,
                                   _packed_unflatten)


def select_round(data: PackedBatches, key: jax.Array) -> PyTree:
    """Gather one global round of batches from the packed shards, on device.

    Draws one shard index per (group round, client) -- ``[E, *dims]`` -- and
    gathers the corresponding blocks, so a round's batch tensor never exists
    on the host. Returns leaves ``[E, H, *dims, ...]`` (``microbatches is
    None``) or ``[E, H, A, *dims, ...]``; ``dims`` is ``(G, K)`` for the
    two-level engines and the full topology for deeper trees
    (``data.topo_ndim`` leading axes).
    """
    E, H, A = data.group_rounds, data.local_steps, data.microbatches
    lead = jax.tree.leaves(data.arrays)[0].shape[:data.topo_ndim]
    S = jax.tree.leaves(data.arrays)[0].shape[data.topo_ndim]
    P = int(np.prod(lead))
    # One draw per (round, client); the flat reshape leaves the bit stream
    # identical to the historical [E, G, K] draw.
    sid = jax.random.randint(key, (E,) + lead, 0, S).reshape(E, P)

    def gather(leaf):
        flat = leaf.reshape((P,) + leaf.shape[data.topo_ndim:])
        sel = flat[jnp.arange(P)[None, :], sid]      # [E, P, steps, ...]
        sel = jnp.moveaxis(sel, 2, 1)                # [E, steps, P, ...]
        sel = sel.reshape(sel.shape[:2] + lead + sel.shape[3:])
        if A is None:
            return sel                               # steps == H
        return sel.reshape((E, H, A) + sel.shape[2:])

    return jax.tree.map(gather, data.arrays)


def pack_client_shards(
    data_arrays: dict[str, np.ndarray],
    indices: list,
    *,
    group_rounds: int | tuple[int, ...],
    local_steps: int,
    batch_size: int,
    shards: int = 16,
    microbatches: int | None = None,
    rng: np.random.Generator,
    key: jax.Array,
) -> PackedBatches:
    """Pack a partitioned array dataset (``data.partition``) for the driver.

    For every client, pre-samples ``shards`` blocks of ``steps x batch``
    examples (with replacement, like ``sample_round_batches``) from its
    index pool -- once, on the host -- and uploads the gathered features as
    ``[G, K, S, steps, B, ...]`` device arrays. Per-round batch variety then
    comes from on-device shard selection: each group round draws one of the
    ``S`` blocks per client, so ``shards`` bounds how many distinct blocks a
    client can see across the horizon (host memory scales with it; 16 is
    plenty for the paper's schedules).

    ``indices`` is the per-client index-pool nesting: ``[G][K]`` lists of
    arrays for the two-level engines, or ``[N_1][N_2]...[N_M]`` for an
    M-level topology -- the nesting depth becomes ``topo_ndim`` and the
    packed leaves carry all topology axes up front (``[*dims, S, steps,
    B, ...]``). Clients draw in row-major order either way, so the
    two-level case is bit-identical to the historical packing.
    """
    steps = local_steps * (microbatches or 1)

    def draw(node):
        if isinstance(node, (list, tuple)):
            return np.stack([draw(child) for child in node])
        return rng.choice(node, size=(shards, steps, batch_size), replace=True)

    sel = draw(indices)                              # [*dims, S, steps, B]
    topo_ndim = sel.ndim - 3
    arrays = {name: jnp.asarray(arr[sel]) for name, arr in data_arrays.items()}
    return PackedBatches(arrays, key, group_rounds, local_steps, microbatches,
                         topo_ndim)


def pack_lm_shards(
    tokens: np.ndarray | list,
    *,
    num_groups: int,
    clients_per_group: int,
    group_rounds: int | tuple[int, ...],
    local_steps: int,
    batch_size: int,
    seq_len: int,
    shards: int = 8,
    microbatches: int | None = None,
    rng: np.random.Generator,
    key: jax.Array,
) -> PackedBatches:
    """Pack a token stream (``data.lm``) for the driver.

    Samples random ``seq_len`` windows (next-token targets shifted by one,
    exactly like ``lm_batches``) into ``{"tokens", "targets"}`` blocks of
    shape ``[G, K, S, steps, B, seq_len]``, uploaded once.

    ``tokens`` is either one shared stream (every client samples from it,
    the historical behaviour, draw-for-draw identical) or a ``[G][K]``
    nesting of per-client streams (e.g. domain-skewed shards) -- each
    client then samples windows from its own stream.
    """
    G, K = num_groups, clients_per_group
    steps = local_steps * (microbatches or 1)

    def windows(stream, size):
        stream = np.asarray(stream)
        starts = rng.integers(0, len(stream) - seq_len - 1, size=size)
        win = starts[..., None] + np.arange(seq_len)
        return stream[win].astype(np.int32), stream[win + 1].astype(np.int32)

    if isinstance(tokens, np.ndarray):
        toks, targs = windows(tokens, (G, K, shards, steps, batch_size))
    else:
        per_client = [[windows(tokens[g][k], (shards, steps, batch_size))
                       for k in range(K)] for g in range(G)]
        toks = np.stack([[per_client[g][k][0] for k in range(K)]
                         for g in range(G)])
        targs = np.stack([[per_client[g][k][1] for k in range(K)]
                          for g in range(G)])
    arrays = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(targs)}
    return PackedBatches(arrays, key, group_rounds, local_steps, microbatches)


RoundFn = Callable[[PyTree, PyTree], tuple[PyTree, PyTree]]


def make_round_step(round_fn: RoundFn, *, donate: bool = True):
    """One (on-device select + global round) as a single jitted dispatch.

    Returns ``step(state, data) -> (state, data, metrics)``. With ``donate``
    (default) the state argument's buffers are donated to the call, so the
    loop never holds two copies of the ``[G, K, N]`` state -- the caller
    must not reuse the state object it passed in. The per-round driver:
    what ``run_rounds`` compiles into its scan, kept as the host-loop
    building block (and the parity baseline for the compiled horizon).
    """

    @functools.partial(jax.jit, donate_argnums=(0,) if donate else ())
    def _step(state, data: PackedBatches):
        key, rng = jax.random.split(data.rng)
        state, metrics = round_fn(state, select_round(data, key))
        return state, rng, metrics

    def step(state, data: PackedBatches):
        state, rng, metrics = _step(state, data)
        return state, data.replace_rng(rng), metrics

    return step


class Horizon(NamedTuple):
    """Stacked results of a multi-round driver run.

    metrics: the round function's metrics, stacked -- leaves ``[T, ...]``.
    evals: ``eval_fn`` outputs at the evaluated rounds -- leaves
        ``[len(eval_rounds), ...]`` -- or None when no ``eval_fn`` was given.
    eval_rounds: 1-based global round indices that were evaluated
        (multiples of ``eval_every`` plus the final round).
    data: the :class:`PackedBatches` with its selection rng advanced past
        this horizon -- continue training from it (``repro.api.fit`` hands
        it back so a continued run draws fresh shard indices instead of
        replaying the finished horizon's).
    population: the host-side ``PopulationStore`` when the run trained a
        virtual client population (``core.population``), with every cohort's
        corrections scattered back -- None for materialized runs.
    guard: a :class:`GuardReport` when the run was guarded
        (``run_rounds(..., guard=...)``); None otherwise.
    """

    metrics: Any
    evals: Any | None
    eval_rounds: np.ndarray
    data: Any | None = None
    population: Any | None = None
    guard: Any | None = None


class GuardSpec(NamedTuple):
    """Self-healing horizon policy for ``run_rounds(..., guard=...)``.

    Before each chunk dispatch the driver snapshots the state (and the
    data rng) to the host; after the chunk it checks the divergence
    predicate below, and on divergence rolls the chunk back and retries it
    with a re-split rng -- up to ``max_retries`` times, then raises
    ``RuntimeError``. Divergence is:

    * any non-finite value in the chunk's ``metrics.loss``, or
    * (``check_state``) a non-finite value in the state's correction /
      global leaves -- the ``z`` / ``y`` / ``dyn`` / ``glob`` fields when
      the state has them, every leaf otherwise. ``params`` is deliberately
      NOT checked: under fault injection a frozen replica legitimately
      carries non-finite bits until its next download heals it, without
      ever entering an aggregate (see core/faults.py) -- or
    * the chunk's final-round mean loss exceeding ``loss_spike`` times the
      last accepted chunk's (losses assumed nonnegative; the first chunk
      has no reference and only the finiteness checks apply).

    ``round_fn_for_retry(attempt)`` (attempt >= 1) supplies the round
    function for retries -- e.g. one rebuilt with a tighter screen
    threshold (``DefensePlan.retry_widen``; ``repro.api.fit`` wires the
    engine's ``retry_round_fn`` here). None retries the original.

    The per-chunk snapshot + divergence sync serializes the async dispatch
    pipeline once per chunk -- bench_faults.py gates the zero-fault
    overhead under 10% per round.
    """

    max_retries: int = 2
    loss_spike: float = 10.0
    check_state: bool = True
    round_fn_for_retry: Callable[[int], RoundFn] | None = None


class GuardReport(NamedTuple):
    """What the guarded horizon did: how many chunks were rolled back at
    least once, and the total retry attempts across the run."""

    rollbacks: int
    retries: int


_GUARD_FIELDS = ("z", "y", "dyn", "glob")


def _guard_leaves(state: PyTree) -> list:
    """The leaves the guard's state check covers (see GuardSpec)."""
    picked = [getattr(state, f) for f in _GUARD_FIELDS
              if getattr(state, f, None) is not None]
    return jax.tree.leaves(picked if picked else state)


def _finite_chunk(state: PyTree, losses, check_state: bool) -> bool:
    ok = np.isfinite(np.asarray(losses)).all()
    if ok and check_state:
        # Reduce on device: each leaf costs one scalar transfer instead of
        # pulling the whole state to host every chunk.
        for leaf in _guard_leaves(state):
            if (hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)
                    and not bool(jnp.isfinite(leaf).all())):
                return False
    return bool(ok)


def _host_snapshot(tree: PyTree) -> PyTree:
    """Host copies of every leaf (syncs; survives donation of the device
    buffers)."""
    return jax.tree.map(np.asarray, tree)


def _fold_retry(rng, salt: int):
    return jax.random.fold_in(rng, np.uint32(salt))


_RUNNERS_PER_FN = 8


def _build_chunk_runner(round_fn: RoundFn, eval_fn, donate: bool):
    """Build the jitted scan-over-rounds chunk executor."""

    @functools.partial(jax.jit, donate_argnums=(0,) if donate else ())
    def run_chunk(state, data: PackedBatches, eval_mask: jax.Array):
        def body(carry, do_eval):
            state, rng = carry
            key, rng = jax.random.split(rng)
            prev = state
            state, metrics = round_fn(state, select_round(data, key))
            if eval_fn is None:
                return (state, rng), (metrics,)
            shapes = jax.eval_shape(eval_fn, prev, state)
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
            ev = jax.lax.cond(do_eval, eval_fn, lambda p, s: zeros, prev, state)
            return (state, rng), (metrics, ev)

        (state, rng), outs = jax.lax.scan(body, (state, data.rng), eval_mask)
        return (state, rng) + outs

    return run_chunk


def _chunk_runner(round_fn: RoundFn, eval_fn, donate: bool):
    """Fetch (or build) the chunk executor for this (round_fn, eval_fn).

    The runner is cached *on the round function object itself*, so its
    lifetime is exactly the round function's: repeated ``run_rounds`` calls
    with the same functions (chunked horizons, benchmark reps) reuse the
    compiled executable instead of re-tracing, and when the caller drops
    the round function (e.g. a benchmark sweep building one per combo) the
    executable -- and whatever arrays its closures captured -- become
    collectable with it. A global cache keyed on identity (the previous
    ``lru_cache``) instead kept up to ``maxsize`` dead round functions and
    their executables pinned; keying on a semantic config signature would
    alias distinct closures (two round fns with equal configs but different
    captured loss/eval state must not share a runner).

    Within one round function, runners are keyed by ``(id(eval_fn),
    donate)``; the runner strongly references its ``eval_fn``, so the id
    cannot be recycled while the entry lives. The per-fn cache is bounded
    (FIFO eviction at ``_RUNNERS_PER_FN``) so a long-lived round function
    driven with fresh eval closures per call cannot accumulate executables
    without limit. Callables that reject attribute assignment (e.g. bound
    methods) just get a fresh runner per call -- correct, merely uncached.
    """
    try:
        cache = round_fn.__chunk_runners__
    except AttributeError:
        try:
            round_fn.__chunk_runners__ = cache = {}
        except AttributeError:
            return _build_chunk_runner(round_fn, eval_fn, donate)
    key = (None if eval_fn is None else id(eval_fn), bool(donate))
    runner = cache.get(key)
    if runner is None:
        while len(cache) >= _RUNNERS_PER_FN:
            cache.pop(next(iter(cache)))
        cache[key] = runner = _build_chunk_runner(round_fn, eval_fn, donate)
    return runner


class LoweredChunk(NamedTuple):
    """Compiled-but-never-executed artifacts of one driver chunk.

    What the static auditor (``repro.analysis``) inspects: the AOT trace
    of the chunk runner over fully abstract inputs -- jaxpr for
    primitive-level invariants (fusion contract, no host callbacks in
    loop bodies), optimized HLO for donation aliases / f64 / cost
    budgets, and the abstract in/out states for dtype contracts. Nothing
    here ever touched device data beyond compilation.

    traced / lowered / compiled: the ``jax.jit(...).trace -> lower ->
        compile`` chain (``compiled`` is None when ``trace_chunk`` was
        asked not to compile).
    state: the abstract (ShapeDtypeStruct) input state the runner was
        traced over; ``out_state`` is the matching output state.
    data: the abstract :class:`PackedBatches` it was traced over.
    donate: whether the state argument was donated.
    """

    traced: Any
    lowered: Any
    compiled: Any
    state: PyTree
    data: PackedBatches
    donate: bool

    @property
    def jaxpr(self):
        return self.traced.jaxpr

    @property
    def hlo(self) -> str:
        """Optimized (post-layout, post-fusion) HLO text."""
        return self.compiled.as_text()

    @property
    def out_state(self) -> PyTree:
        """Abstract output state (run_chunk returns ``(state, rng, ...)``)."""
        return self.traced.out_info[0]


def trace_chunk(
    round_fn: RoundFn,
    state: PyTree,
    data: PackedBatches,
    chunk: int = 2,
    *,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None = None,
    donate: bool = True,
    compile: bool = True,
) -> LoweredChunk:
    """Trace + lower (+ compile) one ``chunk``-round dispatch, no execution.

    ``state`` and ``data`` leaves may be ``jax.ShapeDtypeStruct``s (build
    them with ``jax.eval_shape``); the AOT path never allocates them. Uses
    the same cached runner as :func:`dispatch_chunk`, so a subsequent
    identical trace must hit the jit tracing cache -- the retrace gate in
    ``repro.analysis`` is built on exactly this property.
    """
    runner = _chunk_runner(round_fn, eval_fn, donate)
    mask = jax.ShapeDtypeStruct((int(chunk),), jnp.bool_)
    traced = runner.trace(state, data, mask)
    lowered = traced.lower()
    compiled = lowered.compile() if compile else None
    return LoweredChunk(traced, lowered, compiled, state, data, bool(donate))


def dispatch_chunk(
    round_fn: RoundFn,
    state: PyTree,
    data: PackedBatches,
    eval_mask: np.ndarray,
    *,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None = None,
    donate: bool = True,
) -> tuple[PyTree, PackedBatches, PyTree, PyTree | None]:
    """Dispatch one compiled ``len(eval_mask)``-round chunk, without syncing.

    The building block ``run_rounds`` (and ``core.population``'s
    gather/scatter loop) iterates: fetches the cached chunk runner for
    ``(round_fn, eval_fn, donate)`` and fires it. JAX dispatch is
    asynchronous, so the returned ``(state, data, metrics, evals)`` are
    futures -- the host is free to do work (e.g. population-store gather /
    scatter) while the device scans the chunk; only touching the results
    with ``np.asarray`` blocks. With ``donate`` the input state's buffers
    are consumed.
    """
    runner = _chunk_runner(round_fn, eval_fn, donate)
    out = runner(state, data, jnp.asarray(eval_mask))
    state, rng = out[0], out[1]
    evals = out[3] if eval_fn is not None else None
    return state, data.replace_rng(rng), out[2], evals


def eval_mask_for_chunk(done: int, n: int, T: int, eval_every: int) -> np.ndarray:
    """Per-round eval booleans for rounds ``done+1 .. done+n`` of ``T``.

    True at multiples of ``eval_every`` plus the final round -- the single
    definition both drivers share so their eval cadences cannot drift.
    """
    return np.array([(done + i + 1) % eval_every == 0 or done + i + 1 == T
                     for i in range(n)])


def run_rounds(
    round_fn: RoundFn,
    state: PyTree,
    data: PackedBatches,
    T: int,
    *,
    chunk: int | None = None,
    eval_every: int = 1,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None = None,
    donate: bool = True,
    guard: GuardSpec | None = None,
    on_chunk: Callable[[int, PyTree, PackedBatches], None] | None = None,
) -> tuple[PyTree, PackedBatches, Horizon]:
    """Run ``T`` global rounds as ceil(T / chunk) compiled dispatches.

    Each dispatch scans ``chunk`` rounds of (on-device batch selection +
    ``round_fn``) with the state buffers donated, and returns that chunk's
    metrics stacked -- one device->host transfer per chunk instead of per
    round. ``chunk=None`` (or 0) compiles the whole horizon into a single
    dispatch; a remainder ``T % chunk`` costs at most one extra compile
    (scan lowers to a while loop, so compile time does not grow with
    ``chunk``).

    ``eval_fn(prev_state, state) -> pytree`` runs inside the scan under
    ``lax.cond`` at rounds ``eval_every, 2*eval_every, ..., T`` --
    ``prev_state`` is the pre-round state, whose ``rng`` re-derives the
    round's participation masks when a caller needs them for evaluation.

    With ``donate`` (default) the caller's ``state`` (and each intermediate
    chunk state) is consumed: its buffers are invalidated and reused for
    the output state, halving driver peak state memory. Pass
    ``donate=False`` to keep the input alive.

    With ``guard`` (a :class:`GuardSpec`) the horizon self-heals: each
    chunk is snapshotted before dispatch and rolled back + retried with a
    re-split rng when it diverges (see GuardSpec for the predicate), and
    the returned Horizon carries a :class:`GuardReport`. ``on_chunk(done,
    state, data)`` fires after every accepted chunk -- ``repro.api.fit``
    hooks checkpoint autosave here.

    Returns ``(state, data, Horizon)`` -- ``data`` carries the advanced
    selection rng so horizons can be continued.
    """
    assert T >= 1 and eval_every >= 1
    if chunk is not None and chunk < 0:
        raise ValueError(f"chunk must be None or >= 0, got {chunk}")
    chunk = T if not chunk else min(int(chunk), T)

    mets, evs, masks = [], [], []
    done = 0
    loss_ref = None
    rollbacks = retries = 0
    while done < T:
        n = min(chunk, T - done)
        mask = eval_mask_for_chunk(done, n, T, eval_every)
        if guard is None:
            state, data, metrics, ev = dispatch_chunk(
                round_fn, state, data, mask, eval_fn=eval_fn, donate=donate)
        else:
            state, data, metrics, ev, loss_ref, rb, rt = _guarded_chunk(
                round_fn, state, data, mask, guard,
                eval_fn=eval_fn, donate=donate, done=done, loss_ref=loss_ref)
            rollbacks += rb
            retries += rt
        mets.append(metrics)
        if eval_fn is not None:
            evs.append(ev)
        masks.append(mask)
        done += n
        if on_chunk is not None:
            on_chunk(done, state, data)

    def _cat(*xs):
        return np.concatenate([np.asarray(x) for x in xs])

    metrics = jax.tree.map(_cat, *mets)
    mask_all = np.concatenate(masks)
    eval_rounds = np.nonzero(mask_all)[0] + 1
    evals = None
    if eval_fn is not None:
        evals = jax.tree.map(lambda *xs: _cat(*xs)[mask_all], *evs)
    report = GuardReport(rollbacks, retries) if guard is not None else None
    return state, data, Horizon(metrics, evals, eval_rounds, data, None, report)


def _guarded_chunk(
    round_fn: RoundFn,
    state: PyTree,
    data: PackedBatches,
    eval_mask: np.ndarray,
    guard: GuardSpec,
    *,
    eval_fn: Callable[[PyTree, PyTree], PyTree] | None,
    donate: bool,
    done: int,
    loss_ref: float | None,
):
    """One snapshot / dispatch / check / maybe-rollback cycle.

    Returns ``(state, data, metrics, evals, new_loss_ref, rolled_back,
    retries_used)``. The snapshot is taken to host memory BEFORE dispatch
    because donation consumes the input buffers; a retry replays the chunk
    from the snapshot with ``attempt`` folded into the state and data rngs
    so a different participation / fault draw is realized.
    """
    snap_state = _host_snapshot(state)
    snap_rng = np.asarray(data.rng)
    attempt = 0
    while True:
        if attempt > 0:
            salt = done * (guard.max_retries + 1) + attempt
            state = jax.tree.map(jnp.asarray, snap_state)
            if getattr(state, "rng", None) is not None and hasattr(state, "_replace"):
                state = state._replace(rng=_fold_retry(jnp.asarray(state.rng), salt))
            data = data.replace_rng(_fold_retry(jnp.asarray(snap_rng), salt))
            rf = (guard.round_fn_for_retry(attempt)
                  if guard.round_fn_for_retry is not None else round_fn)
        else:
            rf = round_fn
        state, data, metrics, ev = dispatch_chunk(
            rf, state, data, eval_mask, eval_fn=eval_fn, donate=donate)

        losses = getattr(metrics, "loss", None)
        if losses is None:
            raise ValueError(
                "guarded run_rounds needs a `loss` field in the round "
                "metrics to detect divergence")
        losses = np.asarray(losses)
        ok = _finite_chunk(state, losses, guard.check_state)
        final = float(np.mean(losses[-1])) if ok else np.inf
        if ok and loss_ref is not None and loss_ref > 0.0:
            ok = final <= guard.loss_spike * loss_ref
        if ok:
            return (state, data, metrics, ev, final,
                    int(attempt > 0), attempt)
        if attempt >= guard.max_retries:
            raise RuntimeError(
                f"guarded horizon diverged at rounds {done + 1}.."
                f"{done + len(eval_mask)} and exhausted "
                f"{guard.max_retries} retries (last final-round loss "
                f"{final}, reference {loss_ref})")
        attempt += 1
