"""Deterministic fault injection and screened-aggregation defense plans.

Real hierarchical deployments lose updates three ways the paper's clean
simulation never sees: clients *crash* mid-round (their update never
uploads), whole groups *time out* (the group misses its report window),
and uploads arrive *corrupted* (non-finite bits, or deltas whose norm
exploded). MTGC is unusually exposed to the last kind -- the correction
variables ``z``/``y`` integrate deltas over time, so one poisoned upload
contaminates the correction state for the rest of the horizon, not just
one aggregate.

This module makes all three failure modes first-class scenario axes:

* :class:`FaultPlan` declares per-round fault *rates*;
  :func:`fault_masks` draws the per-round 0/1 fault masks from the engine
  state rng under exactly the ``round_masks`` key discipline (one split
  off the stream, sub-keys per fault kind), so every fault scenario is
  static-shape, bit-reproducible, and replayable by tests and oracles.
  A disabled plan consumes no keys: the zero-fault rng stream -- and
  therefore the zero-fault trajectory -- is untouched.
* :class:`DefensePlan` declares the screened-aggregation defense the
  round engines apply to uploads *before* any aggregate or correction
  update sees them: non-finite screening, an optional hard norm screen,
  and optional norm clipping. Screened contributions are masked out with
  the same where-gated machinery as participation masks and reweighted by
  the engines' existing realized-count / Horvitz-Thompson estimators, so
  the aggregate stays exact over the survivors.

Fault semantics in the two-level engines (core/engine.py, launch/train.py):

* **crash** (``[G, K]``): folds into the round's activity mask -- a
  crashed client is frozen exactly like an unsampled one (no local work
  observed, no upload, no z reset/update, no download), composing with
  partial participation and riding into the fused Pallas kernel
  in-register.
* **timeout** (``[G]``): the group's clients still run their local
  phases and group aggregations, but the group misses the *global*
  exchange -- no upload into the global mean, no y update, no download.
  Under an async schedule the miss is routed through the staleness
  machinery instead: the group's report mask is cleared for the window
  and the state carries the realized download mask (``dl``), so the
  group simply continues as a straggler and its z does not spuriously
  re-initialize.
* **corrupt** (``[G, K]``): applied to the *upload* at each group
  aggregation -- the client's delta is replaced by the fault payload
  (``nan``/``inf`` injection, or ``explode`` = delta scaled by
  ``explode_factor``). Active corrupted clients re-download the clean
  group model when the defense screens them, so corruption heals at the
  next dissemination instead of persisting.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree as tu

FAULT_KINDS = ("nan", "inf", "explode")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-round fault rates, drawn i.i.d. per round from the state rng.

    crash_rate: P(client crashes this round) -- update never uploads.
    timeout_rate: P(group misses its report this round).
    corrupt_rate: P(an active client's upload is corrupted this round).
    corrupt_kind: payload of a corrupted upload -- ``"nan"`` / ``"inf"``
        add a non-finite constant to the delta; ``"explode"`` scales the
        delta by ``explode_factor`` (finite but norm-exploded).
    explode_factor: the ``"explode"`` scale (> 1).
    """

    crash_rate: float = 0.0
    timeout_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_kind: str = "nan"
    explode_factor: float = 1e4

    @property
    def enabled(self) -> bool:
        """True when any fault kind can actually fire."""
        return (self.crash_rate > 0 or self.timeout_rate > 0
                or self.corrupt_rate > 0)

    def validate(self) -> "FaultPlan":
        for name in ("crash_rate", "timeout_rate", "corrupt_rate"):
            rate = getattr(self, name)
            _require(0.0 <= rate < 1.0,
                     f"{name} must be in [0, 1), got {rate}")
        _require(self.corrupt_kind in FAULT_KINDS,
                 f"unknown corrupt_kind {self.corrupt_kind!r} "
                 f"(choose from {FAULT_KINDS})")
        _require(self.explode_factor > 1.0,
                 f"explode_factor must be > 1, got {self.explode_factor}")
        return self


@dataclasses.dataclass(frozen=True)
class DefensePlan:
    """Screened aggregation applied to uploads before they enter anything.

    screen_nonfinite: mask out per-client deltas with any non-finite
        entry, and (backstop) per-group means that still come back
        non-finite at the global stage.
    screen_norm: mask out any client delta with L2 norm above this
        (non-finite norms compare False, so the norm screen also catches
        them). None = no norm screen.
    clip_norm: clip (not screen) finite client deltas to this L2 norm.
        None = no clipping.
    retry_widen: each guarded-horizon retry (core/driver.py) widens the
        screen by multiplying ``screen_norm`` by this factor (< 1), so
        repeated rollbacks catch progressively smaller explosions.

    Screened contributions are where-masked out of the group/global means
    (reweighted by the engines' realized-count / Horvitz-Thompson
    estimators) and the z/y correction updates are gated on the same
    screen mask, so corrections never integrate a screened contribution.
    Screened-but-active clients still download the clean group/global
    model, which is what heals a corrupted client.
    """

    screen_nonfinite: bool = True
    screen_norm: float | None = None
    clip_norm: float | None = None
    retry_widen: float = 0.5

    @property
    def enabled(self) -> bool:
        return (self.screen_nonfinite or self.screen_norm is not None
                or self.clip_norm is not None)

    def validate(self) -> "DefensePlan":
        _require(self.screen_norm is None or self.screen_norm > 0,
                 f"screen_norm must be None or > 0, got {self.screen_norm}")
        _require(self.clip_norm is None or self.clip_norm > 0,
                 f"clip_norm must be None or > 0, got {self.clip_norm}")
        _require(0.0 < self.retry_widen < 1.0,
                 f"retry_widen must be in (0, 1), got {self.retry_widen}")
        return self


class FaultMasks(NamedTuple):
    """One round's realized faults (0/1 float masks, 1 = faulted)."""

    crash: jax.Array    # [G, K] client crashed: update never uploads
    timeout: jax.Array  # [G]    group missed its report window
    corrupt: jax.Array  # [G, K] client upload corrupted


def fault_masks(rng: jax.Array, plan: FaultPlan, G: int,
                K: int) -> tuple[FaultMasks, jax.Array]:
    """Draw one round's fault masks; returns ``(masks, next_rng)``.

    Key discipline mirrors ``participation.round_masks``: one split off
    the carried stream, then fixed per-kind sub-keys -- so each fault
    kind's realization is independent of the other kinds' rates, and the
    whole scenario replays bit-for-bit from the state rng. Callers must
    only invoke this when ``plan.enabled`` (a disabled plan must not
    advance the zero-fault rng stream).
    """
    fkey, next_rng = jax.random.split(rng)
    kc, kt, ku = jax.random.split(fkey, 3)

    def draw(key, rate, shape):
        if rate <= 0:
            return jnp.zeros(shape, jnp.float32)
        return jax.random.bernoulli(key, rate, shape).astype(jnp.float32)

    return FaultMasks(
        crash=draw(kc, plan.crash_rate, (G, K)),
        timeout=draw(kt, plan.timeout_rate, (G,)),
        corrupt=draw(ku, plan.corrupt_rate, (G, K)),
    ), next_rng


def corrupt_uploads(x_start, x_end, bad: jax.Array, plan: FaultPlan):
    """The upload view of ``x_end``: clients with ``bad != 0`` replace
    their delta ``x_end - x_start`` with the fault payload.

    ``bad`` is ``[G, K]`` (corrupt mask x activity: only clients that
    actually worked this group round can upload garbage). Clean clients'
    uploads keep their exact bits (``where``-select, never arithmetic).
    """
    delta = tu.tree_sub(x_end, x_start)
    if plan.corrupt_kind == "explode":
        payload = jax.tree.map(lambda d: d * plan.explode_factor, delta)
    else:
        val = jnp.nan if plan.corrupt_kind == "nan" else jnp.inf
        payload = jax.tree.map(lambda d: d + val, delta)
    return tu.tree_select(bad, tu.tree_add(x_start, payload), x_end)


def all_finite_mask(t, lead_ndim: int) -> jax.Array:
    """0/1 float mask over the first ``lead_ndim`` axes: 1 where every
    entry of every leaf under that index is finite."""
    out = None
    for leaf in jax.tree.leaves(t):
        axes = tuple(range(lead_ndim, leaf.ndim))
        fin = jnp.all(jnp.isfinite(leaf), axis=axes) if axes \
            else jnp.isfinite(leaf)
        out = fin if out is None else out & fin
    return out.astype(jnp.float32)


def client_delta_sq_norm(delta) -> jax.Array:
    """[G, K] squared L2 norm of each client's whole-model delta (f32)."""
    out = None
    for leaf in jax.tree.leaves(delta):
        f = leaf.astype(jnp.float32)
        s = jnp.sum(f * f, axis=tuple(range(2, f.ndim)))
        out = s if out is None else out + s
    return out


def screen_and_clip(x_start, x_up, defense: DefensePlan):
    """Apply the defense to one group round's uploads.

    Returns ``(x_up', ok)`` -- the (possibly clipped) upload view and the
    ``[G, K]`` 0/1 survivor mask. Callers AND ``ok`` into the activity
    mask to form the screen mask every aggregate and correction update is
    gated on. Clipping only rewrites clipped clients (``where``-select),
    so unclipped uploads keep their exact bits.
    """
    delta = tu.tree_sub(x_up, x_start)
    sqn = client_delta_sq_norm(delta)
    ok = jnp.ones(sqn.shape, jnp.float32)
    if defense.screen_nonfinite:
        ok = ok * all_finite_mask(x_up, 2)
    if defense.screen_norm is not None:
        thr = jnp.float32(defense.screen_norm) ** 2
        # NaN/Inf squared norms compare False -> also screened here.
        ok = ok * (sqn <= thr).astype(jnp.float32)
    if defense.clip_norm is not None:
        c = jnp.float32(defense.clip_norm)
        hit = jnp.isfinite(sqn) & (sqn > c * c)
        scale = jnp.where(hit, c * jax.lax.rsqrt(jnp.maximum(sqn, c * c)), 1.0)
        x_clip = jax.tree.map(
            lambda xs, d: xs + tu.expand_mask(scale, d).astype(d.dtype) * d,
            x_start, delta)
        x_up = tu.tree_select(hit.astype(jnp.float32), x_clip, x_up)
    return x_up, ok
