import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first backend init). 512 placeholder host devices let
# ``jax.make_mesh`` build the pinned production meshes on this CPU container.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each case this driver
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod) and
     the architecture's logical train mesh,
  2. lowers ``train_step`` (train_4k) or ``prefill``/``decode_step`` with
     explicit in/out shardings over ShapeDtypeStruct stand-ins (zero
     allocation),
  3. compiles, prints ``memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` (FLOPs / bytes for the roofline),
  4. parses collective bytes (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) out of the partitioned HLO,
  5. derives the three roofline terms (v5e: 197 TF/s bf16, 819 GB/s HBM,
     ~50 GB/s/link ICI) and writes a JSON record for EXPERIMENTS.md.

cost/memory analyses are of the *partitioned per-device module*, so terms
divide by per-chip peaks (equivalent to the global-FLOPs / (chips x peak)
formulation).

Usage:
    python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
"""
import argparse
import json
import time
import traceback
from pathlib import Path

# v5e hardware constants (TARGET hardware; container runs CPU).
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

# Collective-byte / shape parsing lives in launch/hlo_analysis.py (the
# trip-count-aware walker run_case already uses); the local duplicates
# that predated it are gone.


def _active_params(params_shape, num_experts: int, top_k: int):
    """(total, active) param counts; MoE experts scale by top_k/num_experts."""
    import jax

    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        names = [str(getattr(e, "key", e)) for e in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if num_experts and "moe" in names and len(leaf.shape) == 4:
            active += n * top_k // num_experts
        else:
            active += n
    return total, active


# ------------------------------------------------------------------ cases


def build_case(arch_id: str, shape_id: str, *, multi_pod: bool, overrides=None):
    """Returns (jitted_fn, example_args (SDS), mesh, meta)."""
    import jax

    from repro.configs import get_arch, get_plan
    from repro.configs.shapes import SHAPES, serve_specs, train_specs
    from repro.launch import mesh as meshlib
    from repro.launch.serve import make_serve_step
    from repro.launch.train import make_sharded_round
    from repro.models.transformer import build_model
    from repro.sharding import specs as sp

    cfg = get_arch(arch_id)
    plan = get_plan(arch_id)
    if overrides:
        import dataclasses
        cfg_over = {k: v for k, v in overrides.items() if hasattr(cfg, k)}
        plan_over = {k: v for k, v in overrides.items() if hasattr(plan, k)}
        if cfg_over:
            cfg = dataclasses.replace(cfg, **cfg_over)
        if plan_over:
            plan = dataclasses.replace(plan, **plan_over)
    bundle = build_model(cfg)
    kind = SHAPES[shape_id]["kind"]
    params_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    n_total, n_active = _active_params(params_sds, cfg.num_experts, cfg.top_k)

    if kind == "train":
        mesh = meshlib.make_train_mesh(plan, multi_pod=multi_pod)
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        G, K = axis_sizes["group"], axis_sizes["client"]
        batch_sds = train_specs(cfg, plan, multi_pod=multi_pod)
        state_sds = {
            "params": sp.with_lead(params_sds, (G, K)),
            "z": sp.with_lead(params_sds, (G, K)),
            "y": sp.with_lead(params_sds, (G,)),
        }
        st_specs = sp.train_state_specs(params_sds, axis_sizes, cfg)
        from repro.launch.train import ShardedHFLState
        state_sh = ShardedHFLState(
            params=sp.to_shardings(mesh, st_specs["params"]),
            z=sp.to_shardings(mesh, st_specs["z"]),
            y=sp.to_shardings(mesh, st_specs["y"]),
        )
        batch_sh = sp.to_shardings(mesh, sp.train_batch_spec(batch_sds))
        E, H = plan.dryrun_E, plan.dryrun_H
        step = make_sharded_round(bundle.loss, E=E, H=H, lr=0.01)
        jitted = jax.jit(
            step,
            in_shardings=(ShardedHFLState(*state_sh), batch_sh),
            out_shardings=(ShardedHFLState(*state_sh), None),
            donate_argnums=0,
        )
        state = ShardedHFLState(
            params=state_sds["params"], z=state_sds["z"], y=state_sds["y"]
        )
        lead = batch_sds["tokens"].shape  # [E,H,A,G,K,chunk,T_text]
        tokens = 1
        for s in lead[:-1]:
            tokens *= s
        tokens *= SHAPES[shape_id]["seq_len"]  # total positions incl. stubs
        meta = dict(kind=kind, tokens=int(tokens), flops_mult=6,
                    n_params=n_total, n_active=n_active,
                    logical_mesh=dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))))
        return jitted, (state, batch_sds), mesh, meta

    # ----- serve shapes -----
    # kv-split mesh is a DECODE optimization (head-aligned cache writes);
    # prefill is q-compute-bound and prefers full 16-way head sharding.
    kv_split = 1
    if kind == "decode":
        kv_split = meshlib.serve_kv_split(cfg.num_heads, cfg.num_kv_heads)
        if cfg.arch_type == "ssm":
            kv_split = meshlib.serve_kv_split(cfg.num_heads, cfg.num_heads)
    mesh = meshlib.make_serve_mesh(multi_pod=multi_pod, kv=kv_split)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    io = serve_specs(cfg, shape_id)
    param_specs_tree = sp.serve_param_specs(cfg, params_sds, axis_sizes)
    param_sh = sp.to_shardings(mesh, param_specs_tree)
    cache_sh = sp.to_shardings(mesh, sp.serve_cache_specs(cfg, io["cache"], shape_id, mesh))
    batch_sh = sp.to_shardings(mesh, sp.serve_batch_specs(io["batch"], mesh))
    step = make_serve_step(bundle, kind)
    jitted = jax.jit(
        step,
        in_shardings=(param_sh, batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=2,
    )
    B = SHAPES[shape_id]["global_batch"]
    tokens = B * (SHAPES[shape_id]["seq_len"] if kind == "prefill" else 1)
    meta = dict(kind=kind, tokens=int(tokens), flops_mult=2,
                n_params=n_total, n_active=n_active,
                logical_mesh=dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))))
    return jitted, (params_sds, io["batch"], io["cache"]), mesh, meta


def run_case(arch_id: str, shape_id: str, mesh_kind: str, overrides=None,
             verbose: bool = True) -> dict:
    from repro.configs.shapes import SkipShape

    multi_pod = mesh_kind == "multipod"
    rec: dict = dict(arch=arch_id, shape=shape_id, mesh=mesh_kind,
                     overrides=overrides or {})
    t0 = time.time()
    try:
        jitted, args, mesh, meta = build_case(
            arch_id, shape_id, multi_pod=multi_pod, overrides=overrides)
        rec.update(meta)
        with mesh:
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        chips = mesh.devices.size
        mema = compiled.memory_analysis()
        # Trip-count-aware accounting (XLA's cost_analysis counts every
        # while body once -- useless for scan-heavy programs; see
        # launch/hlo_analysis.py). Raw XLA numbers kept as cross-checks.
        from repro.launch import hlo_analysis as H
        cost = H.xla_cost_dict(compiled)
        hc = H.analyze(compiled.as_text())
        flops = hc.flops
        bytes_acc = hc.bytes
        coll = {k: float(v) for k, v in hc.per_collective.items()}
        coll_total = float(hc.collective_bytes)
        mem = {}
        if mema is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mema, k, None)
                if v is not None:
                    mem[k] = int(v)
        terms = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / LINK_BW,
        }
        dominant = max(terms, key=terms.get)
        model_flops = meta["flops_mult"] * meta["n_active"] * meta["tokens"]
        total_hlo_flops = flops * chips
        rec.update(
            status="ok",
            chips=int(chips),
            flops_per_device=flops,
            bytes_per_device=bytes_acc,
            collective_bytes_per_device=coll_total,
            collectives=coll,
            top_collectives=[[b, w] for b, w in hc.top_collectives],
            by_scope=hc.by_scope,
            xla_cost_analysis={"flops_body_once": float(cost.get("flops", 0.0)),
                               "bytes_body_once": float(cost.get("bytes accessed", 0.0))},
            memory=mem,
            terms=terms,
            dominant=dominant,
            model_flops=float(model_flops),
            total_hlo_flops=float(total_hlo_flops),
            useful_flops_ratio=(model_flops / total_hlo_flops) if total_hlo_flops else 0.0,
            compile_s=time.time() - t0,
        )
        if verbose:
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis: flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e}")
            print(f"  collectives/dev: { {k: f'{v:.3e}' for k, v in coll.items() if v} }")
            print(f"  terms(s): " + " ".join(f"{k}={v:.4f}" for k, v in terms.items())
                  + f"  dominant={dominant}")
            print(f"  useful-FLOPs ratio = {rec['useful_flops_ratio']:.3f}")
    except SkipShape as e:
        rec.update(status="skip", reason=str(e), compile_s=time.time() - t0)
        if verbose:
            print(f"  SKIP: {e}")
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:],
                   compile_s=time.time() - t0)
        if verbose:
            print(f"  ERROR: {type(e).__name__}: {e}")
    return rec


def main() -> None:
    from repro.configs import ARCH_IDS, SHAPE_IDS

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg/plan override key=value (ints parsed)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or not args.arch) else args.arch
    shapes = list(SHAPE_IDS) if (args.all or not args.shape) else args.shape
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    n_ok = n_skip = n_err = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                print(f"[dryrun:{args.tag}] {a} x {s} x {m}")
                rec = run_case(a, s, m, overrides=overrides or None)
                fn = outdir / f"{a}__{s}__{m}__{args.tag}.json"
                fn.write_text(json.dumps(rec, indent=1))
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skip"
                n_err += rec["status"] == "error"
    print(f"[dryrun:{args.tag}] ok={n_ok} skip={n_skip} error={n_err}")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
