"""Serving steps (prefill / one-token decode) for the production archs.

``make_serve_step`` returns the function the decode/prefill shapes lower:

    prefill_32k          : (params, batch, cache) -> (logits[B,V], cache)
    decode_32k/long_500k : (params, batch, cache) -> (logits[B,V], cache)

Serving is not federated -- params are a single copy sharded over the
physical ("data", "model") axes (see sharding.specs.serve_param_specs);
batch/cache shard over data (decode_32k) or sequence (long_500k). For the
same reason this module is deliberately standalone from ``repro.api`` (the
HFL *experiment* front door): it never touches round engines or their
state constructors.

CLI runs a small end-to-end batched-decode demo on the host:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke
"""
from __future__ import annotations

import argparse
from typing import Callable

from repro.models.transformer import ModelBundle


def make_serve_step(bundle: ModelBundle, kind: str) -> Callable:
    if kind == "prefill":
        return bundle.prefill
    if kind == "decode":
        return bundle.decode_step
    raise ValueError(kind)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models.transformer import build_model

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    B, T, S = args.batch, args.prompt_len, args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.vision_dim)), jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.float32)

    cache = bundle.init_cache(B, S)
    prefill = jax.jit(bundle.prefill)
    decode = jax.jit(bundle.decode_step)

    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    extra = {k: batch[k] for k in ("frames",) if k in batch}
    for i in range(args.gen - 1):
        logits, cache = decode(
            params, {"token": tok, "index": jnp.asarray(T + i, jnp.int32), **extra},
            cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, 1)
    print(f"[serve] arch={cfg.name} generated {gen.shape}: {np.asarray(gen[0])[:12]}...")


if __name__ == "__main__":
    main()
