"""Static program auditor: ``python -m repro.launch.audit``.

Lowers every representative :class:`repro.api.ExperimentSpec` through
``build(spec)`` + ``Engine.lower_chunk`` -- trace, lower, compile; never
execute -- and gates four static properties (see ``repro.analysis``):

1. invariants: donation aliases, no host sync in loop bodies, no f64,
   ``correction_dtype`` end-to-end, the fused-kernel contract;
2. rng key-discipline lint over ``src/``, ``examples/``, ``benchmarks/``;
3. compiled-cost budgets vs ``analysis/budgets.json`` (FLOPs / HBM bytes
   / collective bytes within a tolerance band);
4. retrace detection: an identical abstract re-trace must hit the jit
   tracing cache.

Usage::

    python -m repro.launch.audit --fast          # blocking-CI subset
    python -m repro.launch.audit                 # full matrix
    python -m repro.launch.audit --update        # regenerate budgets.json
    python -m repro.launch.audit --report out.json
    python -m repro.launch.audit --cases sim_mtgc_flat_fused --list

Exit status is nonzero iff any unsuppressed error-severity finding
remains. Budget drift is enforced only when ``budgets.json`` was
generated on this jax version + backend (pass ``--strict-budgets`` to
force enforcement anywhere).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _lint_roots() -> list[Path]:
    """src/repro plus the repo's examples/ and benchmarks/ when present
    (absent in an installed-wheel context -- the audit notes, not fails)."""
    import repro

    # ``repro`` is a namespace package (no __init__.py): locate it by path.
    pkg = Path(next(iter(repro.__path__))).resolve()
    roots = [pkg]
    repo = pkg.parent.parent
    for name in ("examples", "benchmarks"):
        d = repo / name
        if d.is_dir():
            roots.append(d)
    return roots


def _check_comm_model(case, params):
    """Modeled per-upload wire bytes of every active compressed link; an
    error finding for any link that fails to shrink below uncompressed."""
    import jax

    from repro.analysis.invariants import Finding
    from repro.core import compression as cmp

    plan = case.spec.compression
    stacked = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(tuple(case.spec.levels) + p.shape,
                                       p.dtype), params)
    sizes = cmp.model_leaf_sizes(stacked)
    base = cmp.upload_bytes(sizes, "none")
    model = {"uncompressed_upload_bytes": base}
    findings = []
    for link, mode in (("client", plan.client_mode),
                       ("group", plan.group_mode)):
        if mode == "none":
            continue
        got = cmp.upload_bytes(sizes, mode, plan.topk_frac)
        model[f"{link}_upload_bytes"] = got
        if got >= base:
            findings.append(Finding(
                case.name, "comm-budget",
                f"{link} link mode {mode!r} models {got:.0f} bytes per "
                f"upload, not smaller than uncompressed {base:.0f}"))
    return model, findings


def run_audit(fast: bool = False, case_names: list[str] | None = None,
              update: bool = False, strict_budgets: bool | None = None,
              budget_path: Path | None = None, verbose: bool = True) -> dict:
    """Run every pass; returns the report dict (see ``findings`` key)."""
    import jax

    from repro.analysis import budgets, invariants, keys
    from repro.analysis.specs import (
        abstract_data, abstract_params, audit_cases, case_by_name)

    t0 = time.time()
    if case_names:
        cases = [case_by_name(n) for n in case_names]
    else:
        cases = audit_cases(fast_only=fast)

    findings: list = []
    measured: dict[str, dict[str, float]] = {}
    programs: dict[str, dict] = {}
    for case in cases:
        if verbose:
            print(f"[audit] lowering {case.name} ...", flush=True)
        engine = case.build_engine()
        params = abstract_params()
        state = engine.abstract_state(params)
        data = abstract_data(engine)
        lc = engine.lower_chunk(data, state=state)
        findings += invariants.run_invariants(case, lc)
        findings += invariants.check_retrace(case.name, engine, state, data)
        measured[case.name] = measure = budgets.measure(lc)
        programs[case.name] = {
            "pallas_calls": invariants.count_primitive(lc.jaxpr,
                                                       "pallas_call"),
            "donated_leaves": len(jax.tree.leaves(state)),
            "aliased_params": sorted(invariants.aliased_parameters(lc.hlo)),
            **measure,
        }
        if case.spec.compressed:
            # Modeled comm budget: every compressed link must shrink the
            # per-upload wire bytes vs uncompressed (collective-bytes
            # measurements are all zero on the single-device CPU CI
            # container, so the wire model is the auditable quantity).
            model, comm_findings = _check_comm_model(case, params)
            programs[case.name]["comm_model"] = model
            findings += comm_findings

    # -- key-discipline lint over the source tree
    roots = _lint_roots()
    key_findings = keys.lint_paths(roots)
    open_keys = keys.unsuppressed(key_findings)
    for f in open_keys:
        findings.append(invariants.Finding(
            "keys", f.rule, f"{f.path}:{f.line}: {f.message}"))

    # -- budgets: regenerate or drift-check
    budget_path = budget_path or budgets.BUDGET_PATH
    if update:
        doc = budgets.save(measured, budget_path)
        if verbose:
            print(f"[audit] wrote {len(measured)} budgets -> {budget_path}")
    else:
        doc = budgets.load(budget_path)
        findings += budgets.check(measured, doc, strict=strict_budgets,
                                  complete=not (fast or case_names))

    errors = [f for f in findings if f.severity == "error"]
    notes = [f for f in findings if f.severity != "error"]
    report = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "mode": ("update" if update else "fast" if fast else "full"),
        "cases": sorted(c.name for c in cases),
        "programs": programs,
        "lint": {
            "roots": [str(r) for r in roots],
            "files": len({f.path for f in key_findings}) or None,
            "suppressed": [str(f) for f in key_findings if f.suppressed],
        },
        "errors": [str(f) for f in errors],
        "notes": [str(f) for f in notes],
        "elapsed_s": round(time.time() - t0, 2),
        "ok": not errors,
    }
    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.audit", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--fast", action="store_true",
                    help="blocking-CI subset of the case matrix")
    ap.add_argument("--cases", default=None,
                    help="comma-separated case names (see --list)")
    ap.add_argument("--update", action="store_true",
                    help="regenerate analysis/budgets.json from this run")
    ap.add_argument("--strict-budgets", action="store_true",
                    help="enforce budget drift even on a mismatched "
                         "jax version/backend")
    ap.add_argument("--budget-file", default=None,
                    help="alternate budgets.json path (tests)")
    ap.add_argument("--report", default=None,
                    help="write the full JSON report here")
    ap.add_argument("--list", action="store_true", dest="list_cases",
                    help="list audit case names and exit")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list_cases:
        from repro.analysis.specs import audit_cases
        for c in audit_cases():
            print(f"{c.name:32s} fast={c.fast} backend={c.spec.backend} "
                  f"layout={c.spec.state_layout} fusion={c.spec.fusion}")
        return 0

    if args.update and args.fast:
        ap.error("--update needs the full matrix (drop --fast)")

    report = run_audit(
        fast=args.fast,
        case_names=args.cases.split(",") if args.cases else None,
        update=args.update,
        strict_budgets=True if args.strict_budgets else None,
        budget_path=Path(args.budget_file) if args.budget_file else None,
        verbose=not args.quiet,
    )
    if args.report:
        out = Path(args.report)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=1))
    if not args.quiet:
        for line in report["notes"]:
            print(f"[audit] note: {line}")
    for line in report["errors"]:
        print(f"[audit] FAIL: {line}")
    n_cases = len(report["cases"])
    status = "ok" if report["ok"] else f"{len(report['errors'])} errors"
    print(f"[audit] {n_cases} cases, {report['mode']} mode, "
          f"{report['elapsed_s']}s: {status}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
