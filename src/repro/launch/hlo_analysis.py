"""Trip-count-aware roofline extraction from optimized (partitioned) HLO.

``compiled.cost_analysis()`` visits every while-loop body exactly ONCE, so
for scan-heavy programs (layers x local-steps x grad-accum x KV blocks) it
underestimates FLOPs/bytes by the product of trip counts. This module
re-derives the roofline quantities by walking the HLO text:

* computations are parsed into instruction lists;
* every call site (while body/cond, fusion, call, conditional) propagates a
  multiplier; while trip counts are read off the loop condition's
  ``compare(%iv, %constant)`` (jax scans always lower to 0..N counters);
* FLOPs: dots contribute 2 * prod(result) * prod(contracting dims);
  elementwise arithmetic contributes prod(result); reduces contribute
  prod(operand);
* HBM bytes: operand+result bytes of every *materializing* instruction
  (fusion boundaries, dots, collectives, copies, slices); instructions
  inside fused computations count zero (they live in registers/VMEM) --
  the same memory model XLA's own cost analysis uses;
* collective bytes: operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, times the enclosing
  multiplier, bucketed per op type; the largest contributors are kept for
  bottleneck attribution (which aggregation/timescale is hot).

The result is the per-device cost of one full step (one MTGC global round
for train; one prefill/decode for serve).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DT_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][0-9a-z]*)\[([0-9,]*)\]")
# dtype-shaped tokens missing from _DT_BYTES (new narrow float formats
# etc.) fall back to 4 bytes/elem rather than silently costing zero.
_DT_FALLBACK_RE = re.compile(r"^(?:[fsuc]|bf)[0-9]")
_DT_FALLBACK_BYTES = 4
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_FRAME_RE = re.compile(r"stack_frame_id=(\d+)")

# semantic buckets from jax.named_scope tags planted in the model code
BUCKETS = ("attn", "moe", "mlp", "rwkv", "ssm", "xent", "embed",
           "group_agg", "global_agg")

# (file substring, function name) -> bucket; resolved through the HLO
# stack-frame tables, which survive jvp/transpose/remat (named scopes don't).
_FUNC_BUCKETS = [
    ("layers.py", "blocked_attention", "attn"),
    ("layers.py", "naive_attention", "attn"),
    ("layers.py", "attention_block", "attn"),
    ("layers.py", "apply_rope", "attn"),
    ("layers.py", "swiglu", "mlp"),
    ("layers.py", "embed", "embed"),
    ("layers.py", "unembed", "xent"),
    ("moe.py", "", "moe"),
    ("rwkv6.py", "", "rwkv"),
    ("ssm.py", "", "ssm"),
    ("transformer.py", "chunked_xent", "xent"),
    ("transformer.py", "_rwkv_cmix", "rwkv"),
    ("train.py", "group_round", "group_agg"),
    ("train.py", "round_fn", "global_agg"),
]


def _bucket(op_name: str) -> str | None:
    for b in BUCKETS:
        if f"/{b}/" in op_name or op_name.endswith(f"/{b}"):
            return b
    return None


def parse_stack_tables(hlo: str) -> dict[int, str]:
    """stack_frame_id -> bucket, via FileNames/FunctionNames/FileLocations/
    StackFrames header tables (walking parent frames until a match)."""
    head = hlo.split("ENTRY", 1)[0]

    def table(name, rx):
        out = {}
        sec = re.search(rf"^{name}\n((?:\d+ .*\n)+)", head, re.M)
        if not sec:
            return out
        for line in sec.group(1).splitlines():
            m = re.match(rx, line)
            if m:
                out[int(m.group(1))] = m.group(2)
        return out

    files = table("FileNames", r'(\d+) "(.*)"')
    funcs = table("FunctionNames", r'(\d+) "(.*)"')
    locs = {}
    sec = re.search(r"^FileLocations\n((?:\d+ \{.*\}\n)+)", head, re.M)
    if sec:
        for line in sec.group(1).splitlines():
            m = re.match(r"(\d+) \{file_name_id=(\d+) function_name_id=(\d+)", line)
            if m:
                locs[int(m.group(1))] = (files.get(int(m.group(2)), ""),
                                         funcs.get(int(m.group(3)), ""))
    frames = {}
    sec = re.search(r"^StackFrames\n((?:\d+ \{.*\}\n)+)", head, re.M)
    if sec:
        for line in sec.group(1).splitlines():
            m = re.match(r"(\d+) \{file_location_id=(\d+)(?: parent_frame_id=(\d+))?", line)
            if m:
                frames[int(m.group(1))] = (int(m.group(2)),
                                           int(m.group(3)) if m.group(3) else 0)

    def loc_bucket(loc):
        fn, fun = loc
        for fsub, fname, b in _FUNC_BUCKETS:
            if fsub in fn and (not fname or fun == fname):
                return b
        return None

    out: dict[int, str] = {}
    for fid in frames:
        cur = fid
        b = None
        for _ in range(30):
            if cur not in frames:
                break
            loc_id, parent = frames[cur]
            b = loc_bucket(locs.get(loc_id, ("", "")))
            if b or not parent or parent == cur:
                break
            cur = parent
        if b:
            out[fid] = b
    return out

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "atan2", "remainder",
    "and", "or", "xor", "not", "select", "clamp", "compare", "sine", "cosine",
    "erf", "expm1",
}
ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "rng-bit-generator",
    "rng-get-and-update-state", "opt-barrier", "domain",
}
# ops that do not touch HBM themselves (control / pure aliasing)
NO_BYTES = ZERO_COST | {"while", "conditional", "call"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    operand_bytes: int
    operand_elems: int
    flops: float
    attrs: str


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)
    top_collectives: list = dataclasses.field(default_factory=list)
    top_flops: list = dataclasses.field(default_factory=list)
    top_bytes: list = dataclasses.field(default_factory=list)
    by_scope: dict = dataclasses.field(default_factory=dict)  # scope -> {flops, bytes, collective}
    notes: list = dataclasses.field(default_factory=list)


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (a plain
    dict in newer releases, a one-dict-per-device list in older ones)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_of(text: str):
    """(bytes, elems, dims-of-first-shape) of a result-type string."""
    b = e = 0
    first_dims = None
    for dt, dims in _SHAPE_RE.findall(text):
        nbytes = _DT_BYTES.get(dt)
        if nbytes is None:
            if not _DT_FALLBACK_RE.match(dt):
                continue  # not a dtype token (identifier-ish match)
            nbytes = _DT_FALLBACK_BYTES
        dd = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dd:
            n *= d
        if first_dims is None:
            first_dims = dd
        e += n
        b += n * nbytes
    return b, e, (first_dims or [])


def _split_result(rhs: str):
    """rhs = '<result type> <opcode>(<operands>), attrs...' -> parts."""
    if rhs.startswith("("):  # tuple result: find matching paren
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        result, rest = rhs[: i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, None, ("", "")
        result, rest = rhs[:sp], rhs[sp + 1:]
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return result, None, ("", "")
    opcode = m.group(1)
    depth = 0
    start = m.end() - 1
    i = start
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    operands = rest[start + 1: i]
    attrs = rest[i + 1:]
    return result, opcode, (operands, attrs)


def parse_computations(hlo: str) -> dict[str, list[Instr]]:
    """Parse every computation. Operand sizes resolve through a per-module
    symbol table (HLO prints operands as bare %names); constants feeding
    while-conditions are tracked for trip counts via the same table."""
    comps: dict[str, list[Instr]] = {}
    parse_computations._frames = parse_stack_tables(hlo)
    # symbol table: name -> (bytes, elems, dims, const_value|None)
    sym: dict[str, tuple] = {}
    cur: list[Instr] | None = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            m = _COMP_RE.match(s)
            if m:
                comps[m.group(1)] = cur = []
            continue
        if s == "}" or s.startswith("} //"):
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.groups()
        result, opcode, (operands, attrs) = _split_result(rhs)
        if opcode is None:
            continue
        rb, re_, rdims = _shape_of(result)
        cval = None
        if opcode == "constant":
            cm = re.match(r"\s*(\d+)\s*$", operands)
            if cm and result.startswith(("s", "u")):
                cval = int(cm.group(1))
        sym[name] = (rb, re_, rdims, cval)
        # operand sizes: inline shapes if printed, else look up names
        ob, oe, _ = _shape_of(operands)
        op_names = _NAME_RE.findall(operands)
        if ob == 0 and op_names:
            for nm in op_names:
                ent = sym.get(nm)
                if ent:
                    ob += ent[0]
                    oe += ent[1]
        lhs_dims = None
        if op_names and op_names[0] in sym:
            lhs_dims = sym[op_names[0]][2]
        flops = _instr_flops(opcode, operands, attrs, re_, oe, lhs_dims)
        ins = Instr(name, opcode, rb, re_, ob, oe, flops, attrs)
        ins.operand_names = op_names
        mm = _OPNAME_RE.search(attrs)
        ins.scope = _bucket(mm.group(1)) if mm else None
        if ins.scope is None:
            fm = _FRAME_RE.search(attrs)
            if fm:
                ins.scope = parse_computations._frames.get(int(fm.group(1)))
        cur.append(ins)
    parse_computations._sym = sym  # stashed for trip-count lookup
    return comps


def _instr_flops(opcode, operands, attrs, result_elems, operand_elems, lhs_dims):
    if opcode == "dot":
        m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", operands + " " + attrs)
        csize = 1
        if m and lhs_dims:
            for d in (int(x) for x in m.group(1).split(",") if x):
                if d < len(lhs_dims):
                    csize *= lhs_dims[d]
        return 2.0 * result_elems * csize
    if opcode == "convolution":
        return 2.0 * result_elems
    if opcode in ("reduce", "reduce-window"):
        return float(operand_elems)
    if opcode in ELEMENTWISE:
        return float(result_elems)
    return 0.0


def _while_trip(cond_name: str, comps, sym) -> int:
    """Trip count: the integer constant feeding the condition's compare."""
    best = 0
    for ins in comps.get(cond_name, ()):
        names = list(getattr(ins, "operand_names", ()))
        if ins.opcode == "compare" or "compare" in ins.attrs or ins.opcode == "fusion":
            for nm in names:
                ent = sym.get(nm)
                if ent and ent[3] is not None:
                    best = max(best, ent[3])
    if best == 0:  # fall back: any integer constant defined in the condition
        for ins in comps.get(cond_name, ()):
            ent = sym.get(ins.name)
            if ent and ent[3] is not None:
                best = max(best, ent[3])
    return max(best, 1)


def analyze(hlo: str, entry: str | None = None) -> HloCosts:
    comps = parse_computations(hlo)
    sym = parse_computations._sym
    if not comps:
        return HloCosts(notes=["no computations parsed"])

    if entry is None:
        # ENTRY computation: the one never called by others
        called = set()
        for instrs in comps.values():
            for ins in instrs:
                for rx in (_CALLS_RE, _TO_APPLY_RE, _COND_RE, _BODY_RE):
                    called.update(rx.findall(ins.attrs))
                bm = _BRANCHES_RE.search(ins.attrs)
                if bm:
                    called.update(x.strip().lstrip("%") for x in bm.group(1).split(","))
        entries = [c for c in comps if c not in called]
        # dead comparators etc. can also be uncalled: prefer the real entry
        mains = [c for c in entries if "main" in c]
        if mains:
            entry = mains[0]
        elif entries:
            entry = max(entries, key=lambda c: len(comps[c]))
        else:
            entry = next(iter(comps))

    # fusion bodies: instructions there cost flops but zero HBM bytes
    fusion_bodies = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode == "fusion":
                fusion_bodies.update(_CALLS_RE.findall(ins.attrs))

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS in call order; HLO call graphs are acyclic
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for ins in comps[c]:
            targets: list[tuple[str, float]] = []
            if ins.opcode == "while":
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                trip = _while_trip(cond.group(1), comps, sym) if cond else 1
                if body:
                    targets.append((body.group(1), float(trip)))
                if cond:
                    targets.append((cond.group(1), float(trip)))
            elif ins.opcode == "fusion":
                for t in _CALLS_RE.findall(ins.attrs):
                    targets.append((t, 1.0))
            elif ins.opcode in ("call", "reduce", "reduce-window", "sort",
                                 "scatter", "select-and-scatter", "map",
                                 "all-reduce", "reduce-scatter"):
                for t in _TO_APPLY_RE.findall(ins.attrs):
                    targets.append((t, 0.0))  # tiny scalar lambdas: ignore
            elif ins.opcode == "conditional":
                bm = _BRANCHES_RE.search(ins.attrs)
                if bm:
                    for t in bm.group(1).split(","):
                        targets.append((t.strip().lstrip("%"), 1.0))
            for t, k in targets:
                if t not in comps:
                    continue
                mult[t] += mult[c] * k
                if t not in seen:
                    seen.add(t)
                    order.append(t)

    # fusions whose root is a dynamic-update-slice update their big operand
    # in place: HBM traffic is the update slice (r/w), not the whole buffer.
    dus_root = set()
    for cname, instrs in comps.items():
        if any(i.opcode == "dynamic-update-slice" for i in instrs):
            dus_root.add(cname)

    out = HloCosts(per_collective={c: 0.0 for c in COLLECTIVES})
    flop_items: list[tuple[float, str]] = []
    coll_items: list[tuple[float, str]] = []
    byte_items: list[tuple[float, str]] = []
    for c, instrs in comps.items():
        m = mult.get(c, 0.0)
        if m == 0.0:
            continue
        in_fusion = c in fusion_bodies
        for ins in instrs:
            sc = getattr(ins, "scope", None) or "other"
            bucket = out.by_scope.setdefault(
                sc, {"flops": 0.0, "bytes": 0.0, "collective": 0.0})
            if ins.flops:
                out.flops += m * ins.flops
                bucket["flops"] += m * ins.flops
                if ins.opcode == "dot":
                    flop_items.append((m * ins.flops, f"{c}/{ins.name}"))
            opc = ins.opcode.replace("-start", "")
            if opc in COLLECTIVES:
                b = ins.operand_bytes or ins.result_bytes
                out.collective_bytes += m * b
                out.per_collective[opc] += m * b
                bucket["collective"] += m * b
                coll_items.append((m * b, f"{c}/{ins.name} {opc} x{m:g}"))
            if not in_fusion and ins.opcode not in NO_BYTES and not ins.opcode.endswith("-done"):
                rw = ins.operand_bytes + ins.result_bytes
                is_dus = ins.opcode == "dynamic-update-slice" or (
                    ins.opcode == "fusion"
                    and any(t in dus_root for t in _CALLS_RE.findall(ins.attrs))
                )
                if is_dus and ins.operand_bytes >= ins.result_bytes:
                    # in-place: subtract the aliased whole-buffer read+write
                    rw = max(rw - 2 * ins.result_bytes, 2 * (
                        ins.operand_bytes - ins.result_bytes))
                elif ins.opcode == "dynamic-slice":
                    rw = 2 * ins.result_bytes  # reads only the slice
                b = m * rw
                out.bytes += b
                bucket["bytes"] += b
                byte_items.append((b, f"{c}/{ins.name} {ins.opcode} x{m:g}"))
    out.top_flops = sorted(flop_items, reverse=True)[:8]
    out.top_collectives = sorted(coll_items, reverse=True)[:12]
    out.top_bytes = sorted(byte_items, reverse=True)[:16]
    return out
