"""Production MTGC training round (sharded, microbatched).

This is Algorithm 1 restructured for the multi-pod mesh: the same update
equations as ``core.engine`` (which the tests cross-check against a pure
oracle), but with

* grad accumulation over A microbatch chunks inside every local step
  (big models / long sequences do not fit a full per-client batch),
* state stacked [G, K, ...] and sharded over (group, client) with each
  replica ZeRO-3/Megatron-sharded over (fsdp, model),
* the group-global correction ``y`` kept at [G, ...] (never materialized
  per client: it broadcasts into the update via a unit axis),
* group aggregation -> all-reduce over ``client`` every H steps; global
  aggregation -> all-reduce over ``group`` (x ``pod``) every E*H steps,
* optionally (``use_fused_update``) the corrected local step runs through
  the fused Pallas ``mtgc_update`` kernel -- the microbatch mean ``g/A``,
  the corrections and the AXPY stream through VMEM in one pass instead of
  three parameter-sized HBM round-trips,
* optionally (``sharded_init(..., use_flat_state=True)``) the state lives
  in contiguous flat buffers (core/packer.py): the round detects the
  layout at trace time, repacks tree views once per group round for the
  gradient loop, folds ``z + y`` into one precomputed correction tensor,
  and runs aggregations / z / y updates as whole-model ops. Combined with
  ``use_fused_update`` the local step is a single batched Pallas call over
  the entire flat model. Flat states require params and corrections in one
  dtype (no ``correction_dtype``).

Partial participation (``client_participation`` / ``group_participation``
on :func:`make_sharded_round`) threads the same per-round ``[G]`` / ``[G,
K]`` masks as the simulator engine through the production round: masks are
drawn from ``state.rng`` exactly like ``core.participation.round_masks``
(host pipelines and the jitted round agree on who participates), inactive
replicas keep their params/z frozen via the same ``where`` gating the
fused Pallas kernel applies in-register, aggregations become masked means
with the engine's weighting semantics (``participation_weighting="none" |
"inverse_prob"``; see core/participation.py), and y updates fire only for
groups with an active client. Masks are data: the program shape -- and
under GSPMD the collective schedule -- is unchanged, inactive clients'
contributions folding to no-ops inside the same all-reduces, and with
full participation (the default) the masked machinery is compiled out
bit-for-bit. Parity with the simulator engine under partial participation
is gated in tests/test_weighting.py.

Under GSPMD this lowers to exactly the paper's two-timescale collective
schedule; local steps generate zero cross-client traffic.

K here is the *materialized cohort*, not necessarily the population: with
``ExperimentSpec.population`` set, ``core.population`` holds P >> K
virtual clients' corrections in a host store and gathers/scatters each
sampled cohort through this unchanged round between driver chunks
(``--population`` / ``--cohort-size`` / ``--client-state`` on this CLI).

Also used as the lowering target of the train_4k dry-run.

The CLI is one ``repro.api`` client: its experiment flags are generated
from the ``ExperimentSpec`` CLI table (``repro.api.add_spec_args``; this
entry point pins ``backend="sharded"`` and ``microbatches=1``), and
training runs through ``build``/``fit`` over ``core/driver.py``: the
token stream is packed into per-client shard blocks and uploaded once,
every round's batches are gathered on device, and the state buffers are
donated through each dispatch (tree and flat layouts alike). ``--chunk
N`` compiles N global rounds into a single scan dispatch (``run_rounds``;
default 1 = one donated dispatch per round, 0 = the whole horizon as one
dispatch). Chunking does not change numerics (driver parity is gated in
tests/test_driver.py) -- it bounds how much work one dispatch commits to
while amortizing dispatch overhead and returning metrics one transfer per
chunk.

CLI (example, small-enough-for-CPU config):
    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --rounds 2 --chunk 2
"""
from __future__ import annotations

import argparse
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree as tu
from repro.core.packer import FlatBuffers, is_flat, make_packer
from repro.core.participation import inclusion_prob, sample_hfl_masks

PyTree = Any


class ShardedHFLState(NamedTuple):
    params: PyTree   # [G, K, ...] per-client replicas
    z: PyTree        # [G, K, ...] client->group corrections
    y: PyTree        # [G, ...]    group->global corrections
    rng: jax.Array | None = None  # participation sampling key (None = full)
    round: jax.Array | None = None  # window counter (async cadences only)
    snap: PyTree | None = None   # [G, ...] last-downloaded global per group
    glob: PyTree | None = None   # [...]    last global model (delay comp.)
    dl: jax.Array | None = None  # [G] realized downloads (timeout faults + async)
    efc: PyTree | None = None    # [G, K, ...] client-link error-feedback residuals
    efg: PyTree | None = None    # [G, ...]    group-link error-feedback residuals


class ShardedMetrics(NamedTuple):
    loss: jax.Array          # [E, H] mean loss per local step (active clients)
    grad_norm: jax.Array     # scalar, last step
    z_norm: jax.Array
    y_norm: jax.Array
    participation: jax.Array  # fraction of clients active this round
    screened: jax.Array      # count of screened contributions (0 undefended)
    comm_bytes: jax.Array    # scalar modeled upload bytes on the wire this round


def sharded_init(params0: PyTree, G: int, K: int,
                 *, use_flat_state: bool = False,
                 correction_dtype=None,
                 rng: jax.Array | None = None,
                 round_counter: bool = False,
                 staleness_snapshots: bool = False,
                 fault_download: bool = False,
                 ef_client: bool = False,
                 ef_group: bool = False) -> ShardedHFLState:
    """Stacked per-client state. ``correction_dtype`` stores z/y in a
    narrower dtype (bf16) -- a beyond-paper memory optimization; the update
    math still runs in the params' dtype. Incompatible with flat states
    (one contiguous buffer per dtype requires params and corrections to
    share it). ``rng`` seeds per-round participation sampling; required by
    rounds built with partial participation, ignored otherwise.

    ``round_counter`` carries the window counter async report cadences are
    derived from; ``staleness_snapshots`` adds the per-group download
    snapshots (``snap``/``glob``) delay-compensated async rounds need (see
    core/staleness.py); ``fault_download`` carries the realized-download
    mask group-timeout faults under an async schedule need
    (core/faults.py); ``ef_client`` / ``ef_group`` carry the per-link
    error-feedback residuals compressed uploads accumulate
    (core/compression.py) -- always in the params' dtype, since they
    store upload-delta error, not corrections. All default off: the sync
    state is unchanged."""
    rnd = jnp.zeros((), jnp.int32) if round_counter else None
    dl = jnp.ones((G,), jnp.float32) if fault_download else None
    if use_flat_state:
        if correction_dtype is not None:
            raise ValueError(
                "flat state packs params and corrections into one buffer "
                "per dtype; correction_dtype needs the tree layout")
        packer = make_packer(params0)
        flat0 = packer.flatten(params0)
        stacked = FlatBuffers(
            {k: jnp.broadcast_to(b, (G, K) + b.shape) for k, b in flat0.bufs.items()},
            packer,
        )
        snap = glob = None
        if staleness_snapshots:
            glob = flat0
            snap = FlatBuffers(
                {k: jnp.broadcast_to(b, (G,) + b.shape)
                 for k, b in flat0.bufs.items()},
                packer,
            )
        return ShardedHFLState(
            params=stacked, z=packer.zeros((G, K)), y=packer.zeros((G,)),
            rng=rng, round=rnd, snap=snap, glob=glob, dl=dl,
            efc=packer.zeros((G, K)) if ef_client else None,
            efg=packer.zeros((G,)) if ef_group else None,
        )
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (G, K) + x.shape), params0)
    cdt = correction_dtype
    z0 = jax.tree.map(lambda x: jnp.zeros(x.shape, cdt or x.dtype), stacked)
    y0 = jax.tree.map(lambda x: jnp.zeros((G,) + x.shape, cdt or x.dtype), params0)
    snap = glob = None
    if staleness_snapshots:
        # jnp.array copies: glob must not alias the caller's params, or
        # the driver's donated scans would delete them out from under it.
        glob = jax.tree.map(jnp.array, params0)
        snap = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape), params0)
    efc0 = (jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), stacked)
            if ef_client else None)
    efg0 = (jax.tree.map(lambda x: jnp.zeros((G,) + x.shape, x.dtype), params0)
            if ef_group else None)
    return ShardedHFLState(params=stacked, z=z0, y=y0, rng=rng,
                           round=rnd, snap=snap, glob=glob, dl=dl,
                           efc=efc0, efg=efg0)


def make_sharded_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    *, E: int, H: int, lr: float, algorithm: str = "mtgc",
    use_fused_update: bool = False,
    fused_mode: str | None = None,
    client_participation: float = 1.0,
    group_participation: float = 1.0,
    participation_mode: str = "uniform",
    participation_weighting: str = "none",
) -> Callable[[ShardedHFLState, PyTree], tuple[ShardedHFLState, ShardedMetrics]]:
    """One MTGC global round. batches: leaves [E, H, A, G, K, chunk, ...].

    .. deprecated::
        ``make_sharded_round`` is the legacy constructor; new code should
        declare an ``ExperimentSpec(backend="sharded")`` and use
        ``repro.api.build(spec, loss_fn)`` -- this shim delegates to that
        adapter. (The returned round function reads ``(G, K)`` from the
        state it is traced with, so the spec's ``levels`` do not shape
        it -- only ``build().init`` consumes them.)

    ``algorithm``: "mtgc" | "hfedavg" (corrections off -> the paper's
    baseline, same schedule).  ``use_fused_update``
    routes the corrected step (mtgc only) through the fused Pallas kernel;
    ``fused_mode`` overrides the backend dispatch ("auto" resolves to the
    compiled kernel on TPU and the jnp oracle elsewhere; "interpret" runs
    the kernel body op-by-op for CPU validation). The returned function
    adapts at trace time to flat or pytree states (``sharded_init``'s
    ``use_flat_state``); narrow corrections (``sharded_init``'s
    ``correction_dtype``) are cast to f32 inside the update either way.

    ``client_participation`` / ``group_participation`` < 1 enable the
    engine's partial-participation semantics on the production round:
    per-round masks drawn from ``state.rng`` (``sharded_init(...,
    rng=...)``; same key schedule as ``core.participation.round_masks``),
    frozen inactive replicas, masked aggregations under
    ``participation_weighting`` ("none" realized-count | "inverse_prob"
    Horvitz-Thompson), and gated z/y updates -- matching ``core.engine``
    state-for-state (tests/test_weighting.py). The participation mask rides
    into the fused Pallas kernel in-register.
    """
    import warnings

    from repro.core.api import ExperimentSpec, RoundSchedule, build

    warnings.warn(
        "make_sharded_round is deprecated: declare an "
        "ExperimentSpec(backend='sharded') and use "
        "repro.api.build(spec, loss_fn)", DeprecationWarning, stacklevel=2)

    spec = ExperimentSpec(
        schedule=RoundSchedule(group_rounds=E, local_steps=H),
        algorithm=algorithm,
        lr=lr,
        backend="sharded",
        state_layout="tree",  # the round adapts to the state at trace time
        fusion="fused" if use_fused_update else "none",
        fused_mode=fused_mode,
        client_participation=client_participation,
        group_participation=group_participation,
        participation_mode=participation_mode,
        participation_weighting=participation_weighting,
    )
    return build(spec, loss_fn).round_fn


def _build_sharded_round(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    *, E: int, H: int, lr: float, algorithm: str = "mtgc",
    use_fused_update: bool = False,
    fused_mode: str | None = None,
    client_participation: float = 1.0,
    group_participation: float = 1.0,
    participation_mode: str = "uniform",
    participation_weighting: str = "none",
    plan=None,
    faults=None,
    defense=None,
    compression=None,
) -> Callable[[ShardedHFLState, PyTree], tuple[ShardedHFLState, ShardedMetrics]]:
    """The real production-round builder behind ``repro.api``'s adapter.

    See :func:`make_sharded_round` (the delegating shim) for the full
    semantics; parameters and the returned contract are identical.

    ``plan`` (a ``core.staleness.StalenessPlan``) switches the round into
    async group-round mode: ``E`` becomes the padded loop length
    ``max(E_g)``, the static per-group iteration mask composes with the
    participation freeze/recover machinery (and rides into the fused
    Pallas kernel in-register exactly like the client mask), and the
    global aggregation becomes the staleness-aware merge of the groups
    reporting this window -- identical semantics to the simulator engine's
    async path (see core/engine.py and core/staleness.py). ``plan=None``
    traces the legacy sync program bit for bit.

    ``faults`` / ``defense`` (``core.faults.FaultPlan`` /
    ``DefensePlan``) inject per-round crash / timeout / corrupted-upload
    faults and screen/clip uploads before aggregation -- identical
    semantics to the simulator engine's fault path (see core/faults.py).
    Disabled (or None) plans trace the legacy program, bit for bit.

    ``compression`` (``core.compression.CompressionPlan``) quantizes /
    sparsifies the upload deltas at both aggregation links, with
    per-link error-feedback residuals carried in the state
    (``sharded_init(..., ef_client=..., ef_group=...)``) and the modeled
    ``comm_bytes`` metric -- identical semantics to the simulator
    engine's compression seam (see core/compression.py): compress ->
    corrupt -> screen, so the defense sees the dequantized bytes and a
    screened contribution never pollutes a residual.
    """
    use_corr = algorithm == "mtgc"
    if algorithm not in ("mtgc", "hfedavg"):
        raise ValueError(f"unknown sharded algorithm {algorithm!r} "
                         "(choose 'mtgc' or 'hfedavg')")
    if use_fused_update and not use_corr:
        raise ValueError("use_fused_update fuses exactly g/A + z + y: mtgc only")
    if participation_mode not in ("uniform", "fixed"):
        raise ValueError(f"unknown participation mode {participation_mode!r}")
    if participation_weighting not in ("none", "inverse_prob"):
        raise ValueError(
            f"unknown participation weighting {participation_weighting!r}")
    if not (0.0 < client_participation <= 1.0
            and 0.0 < group_participation <= 1.0):
        raise ValueError("participation fractions must be in (0, 1], got "
                         f"{client_participation}/{group_participation}")
    if use_fused_update:
        from repro.kernels import ops as kops
    fmode = fused_mode or "auto"
    partial = client_participation < 1.0 or group_participation < 1.0
    ht = partial and participation_weighting == "inverse_prob"
    faults = faults if (faults is not None and faults.enabled) else None
    defense = defense if (defense is not None and defense.enabled) else None
    fault_mode = faults is not None
    defended = defense is not None
    if fault_mode:
        faults.validate()
        f_crash = faults.crash_rate > 0
        f_timeout = faults.timeout_rate > 0
        f_corrupt = faults.corrupt_rate > 0
    else:
        f_crash = f_timeout = f_corrupt = False
    if defended:
        defense.validate()
    if fault_mode or defended:
        from repro.core import faults as _flt
    comp = compression if (compression is not None
                           and compression.enabled) else None
    comp_mode = comp is not None
    if comp_mode:
        comp.validate()
        if plan is not None:
            raise ValueError(
                "compressed uploads under an async schedule are not "
                "supported yet: stale reports would need their own "
                "residual timeline (see ROADMAP)")
    # Imported unconditionally: the comm_bytes metric is reported whether
    # or not a plan is active.
    from repro.core import compression as _cmp
    comp_c = comp_mode and comp.client_mode != "none"
    comp_g = comp_mode and comp.group_mode != "none"
    ef_c = comp_mode and comp.ef_client
    ef_g = comp_mode and comp.ef_group
    comp_stoch = comp_mode and comp.stochastic
    c_noise = comp_mode and comp.client_mode == "int8_stochastic"
    # Compression kernels ride the same dispatch knob as the fused update.
    comp_dispatch = fmode if use_fused_update else "ref"
    vg = jax.vmap(jax.vmap(jax.value_and_grad(loss_fn)))  # over [G, K]
    async_mode = plan is not None
    if async_mode:
        if plan.e_pad != E:
            raise ValueError(f"E must be the padded loop length "
                             f"max(E_g)={plan.e_pad}, got {E}")
        em_all = jnp.asarray(plan.iteration_mask())              # [E_pad, G]
        dw = jnp.asarray(plan.discount_weights())                # [G]
        e_eff = jnp.asarray(plan.effective_rounds, jnp.float32)  # [G]

    def round_fn(state: ShardedHFLState, batches: PyTree):
        x, z, y = state.params, state.z, state.y
        flat = is_flat(x)
        packer = x.packer if flat else None
        G, K = jax.tree.leaves(x)[0].shape[:2]

        if partial:
            if state.rng is None:
                raise ValueError(
                    "partial participation draws per-round masks from the "
                    "state: build it with sharded_init(..., rng=key)")
            # Identical key schedule to core.participation.round_masks, so
            # host pipelines and the jitted round agree on the masks.
            mkey, rng = jax.random.split(state.rng)
            masks = sample_hfl_masks(
                mkey, G, K, client_participation, group_participation,
                participation_mode)
            cmask, gmask = masks.client, masks.group       # [G, K], [G]
            cdenom = (inclusion_prob(client_participation, K,
                                     participation_mode) * K if ht else None)
            gdenom = (inclusion_prob(group_participation, G,
                                     participation_mode) * G if ht else None)
        else:
            cmask = None
            cdenom = gdenom = None
            rng = state.rng

        if fault_mode:
            if rng is None:
                raise ValueError(
                    "fault injection draws per-round masks from the state: "
                    "build it with sharded_init(..., rng=key)")
            # Fault draw AFTER the participation draw, off the same carried
            # stream: the zero-fault rng stream is untouched.
            fm, rng = _flt.fault_masks(rng, faults, G, K)
            if f_crash:
                alive = 1.0 - fm.crash
                cmask = alive if cmask is None else cmask * alive
            if f_timeout:
                tm_keep = 1.0 - fm.timeout                 # [G]
        if comp_stoch:
            if rng is None:
                raise ValueError(
                    "stochastic compression draws rounding noise from the "
                    "state: build it with sharded_init(..., rng=key)")
            # Compression draw AFTER the participation and fault draws,
            # off the same carried stream: deterministic plans leave the
            # stream untouched.
            ckey, rng = jax.random.split(rng)
            kc, kg = jax.random.split(ckey)
        if (fault_mode or defended) and cmask is None:
            cmask = jnp.ones((G, K), jnp.float32)
        masked = cmask is not None
        if masked:
            n_active = jnp.maximum(jnp.sum(cmask), 1.0)

        if async_mode:
            if plan.num_groups != G:
                raise ValueError(f"staleness plan covers {plan.num_groups} "
                                 f"groups, state has {G}")
            if plan.needs_round_counter and state.round is None:
                raise ValueError(
                    "this async schedule derives report cadences from the "
                    "window counter: build the state with "
                    "sharded_init(..., round_counter=True) (repro.api.build "
                    "does this for you)")
            t = state.round if state.round is not None else 0
            rep = plan.report_mask(t)                      # [G]
            fresh = plan.fresh_mask(t)                     # [G]
            if f_timeout:
                if state.dl is None:
                    raise ValueError(
                        "group-timeout faults under an async schedule carry "
                        "the realized-download mask in the state: build it "
                        "with sharded_init(..., fault_download=True) "
                        "(repro.api.build does this for you)")
                rep = rep * tm_keep
                fresh = state.dl

        if use_corr:
            # Alg. 1 line 3 (with the experimental zero init of footnote 2):
            # the client-group correction restarts every global round --
            # for participants only; frozen clients keep their z. Only y
            # persists across rounds. Async: restarts per report *cycle*
            # (only groups starting from a fresh download reset).
            if async_mode:
                zmask = (fresh[:, None] * cmask if masked
                         else jnp.broadcast_to(fresh[:, None], (G, K)))
                z = tu.tree_select(zmask, tu.tree_zeros_like(z), z)
            else:
                z0 = tu.tree_zeros_like(z)
                z = tu.tree_select(cmask, z0, z) if masked else z0

        def step_loss_mean(lsum_gk, inv_a, am, n_act):
            """Scalar step loss from the per-client sums over A chunks."""
            lpc = lsum_gk * inv_a
            if defended:
                # Screen not-yet-healed corrupted clients out of the metric
                # (their uploads are screened; see core/engine.py).
                w = am * jnp.isfinite(lpc).astype(jnp.float32)
                return (jnp.sum(jnp.where(w != 0, lpc, 0))
                        / jnp.maximum(jnp.sum(w), 1.0))
            if am is not None:
                return jnp.sum(jnp.where(am != 0, lpc, 0)) / n_act
            return jnp.mean(lpc)

        def step_grad_norm(g, inv_a, am):
            if defended:
                w = am * _flt.all_finite_mask(g, 2)
                return tu.tree_masked_sq_norm(g, w) * inv_a * inv_a
            if am is not None:
                return tu.tree_masked_sq_norm(g, am) * inv_a * inv_a
            return tu.tree_sq_norm(g) * inv_a * inv_a

        def accum_grads(x_t, batch_h):
            """Per-client summed loss [G, K] + summed grads over the A
            microbatch chunks."""
            def accum(acc, batch_a):
                gsum, lsum = acc
                loss, g = vg(x_t, batch_a)
                return (tu.tree_add(gsum, g), lsum + loss), None

            A = jax.tree.leaves(batch_h)[0].shape[0]
            (g, lsum), _ = jax.lax.scan(
                accum,
                (tu.tree_zeros_like(x_t), jnp.zeros((G, K), jnp.float32)),
                batch_h,
            )
            return g, lsum, 1.0 / A

        def local_step(carry, batch_h, am, n_act):
            # batch_h leaves: [A, G, K, chunk, ...]
            x, z, y = carry
            g, lsum, inv_a = accum_grads(x, batch_h)
            if use_corr and use_fused_update:
                # Fused AXPY through VMEM: g/A + z + y and the update in one
                # pass (kernels/mtgc_update.py). The [G, K, n]-layout kernel
                # broadcasts y across clients via its block index map, so y
                # is never materialized per client even per leaf -- and the
                # participation/iteration mask gates frozen replicas
                # in-register.
                def fused_leaf(xi, gi, zi, yi):
                    Gl, Kl = xi.shape[:2]
                    out = kops.mtgc_update_flat(
                        xi.reshape(Gl, Kl, -1), gi.reshape(Gl, Kl, -1),
                        zi.reshape(Gl, Kl, -1), yi.reshape(Gl, -1),
                        am, lr=lr, g_scale=inv_a, mode=fmode)
                    return out.reshape(xi.shape)

                x = jax.tree.map(fused_leaf, x, g, z, y)
            elif use_corr:
                x_new = jax.tree.map(
                    lambda xi, gi, zi, yi: xi - lr * (
                        gi * inv_a + zi.astype(gi.dtype) + yi[:, None].astype(gi.dtype)
                    ),
                    x, g, z, y,
                )
                x = tu.tree_select(am, x_new, x) if am is not None else x_new
            else:
                x_new = jax.tree.map(lambda xi, gi: xi - lr * gi * inv_a, x, g)
                x = tu.tree_select(am, x_new, x) if am is not None else x_new
            return (x, z, y), (step_loss_mean(lsum, inv_a, am, n_act),
                               step_grad_norm(g, inv_a, am))

        def local_phase_flat(x, z, y, batch_e, am, n_act):
            """H local steps on a flat state, repacking at the phase edge.

            z/y are constant inside the phase: their sum collapses into one
            precomputed correction tensor (non-fused) or feeds the single
            batched Pallas call over the whole flat model (fused); the
            participation gate folds into the same expression.
            """
            if use_corr and use_fused_update:
                def step(xf, batch_h):
                    g, lsum, inv_a = accum_grads(packer.unflatten(xf), batch_h)
                    gf = packer.flatten(g)
                    xf = FlatBuffers(
                        {k: kops.mtgc_update_flat(
                            xf.bufs[k], gf.bufs[k], z.bufs[k], y.bufs[k],
                            am, lr=lr, g_scale=inv_a, mode=fmode)
                         for k in xf.bufs},
                        packer,
                    )
                    return xf, (step_loss_mean(lsum, inv_a, am, n_act),
                                step_grad_norm(gf, inv_a, am))

                return jax.lax.scan(step, x, batch_e)

            corr_t = (packer.unflatten(
                jax.tree.map(lambda zb, yb: zb + yb[:, None], z, y))
                if use_corr else None)

            def step(x_t, batch_h):
                g, lsum, inv_a = accum_grads(x_t, batch_h)
                if use_corr:
                    x_new = jax.tree.map(
                        lambda xi, gi, ci: xi - lr * (gi * inv_a + ci),
                        x_t, g, corr_t)
                else:
                    x_new = jax.tree.map(
                        lambda xi, gi: xi - lr * gi * inv_a, x_t, g)
                if am is not None:
                    x_t = jax.tree.map(
                        lambda xn, xi: jnp.where(
                            tu.expand_mask(am, xn) != 0, xn, xi),
                        x_new, x_t)
                else:
                    x_t = x_new
                return x_t, (step_loss_mean(lsum, inv_a, am, n_act),
                             step_grad_norm(g, inv_a, am))

            x_t, out = jax.lax.scan(step, packer.unflatten(x), batch_e)
            return packer.flatten(x_t), out

        def group_round(carry, inp):
            # batch_e leaves: [H, A, G, K, chunk, ...]
            x, z, y, efc = carry
            if async_mode:
                # Iteration liveness joins the participation mask: a
                # straggler past its E_g rounds this window is frozen
                # exactly like an unsampled client, so aggregation, z
                # update and dissemination below need no further gating.
                batch_e, em = inp
                am = (em[:, None] * cmask if masked
                      else jnp.broadcast_to(em[:, None], (G, K)))
                n_act = jnp.maximum(jnp.sum(am), 1.0)
            else:
                if c_noise:
                    batch_e, ek = inp
                else:
                    batch_e = inp
                    ek = None
                am = cmask if masked else None
                n_act = n_active if masked else None
            x_start = x  # phase-start model: upload deltas are vs this
            if flat:
                x, (losses, gnorm) = local_phase_flat(x, z, y, batch_e,
                                                      am, n_act)
            else:
                (x, z, y), (losses, gnorm) = jax.lax.scan(
                    lambda c, b: local_step(c, b, am, n_act), (x, z, y),
                    batch_e)
            # Upload view: compression first -- the wire carries the
            # dequantized delta, so corruption faults rewrite (and the
            # defense screens) exactly what the group server would
            # reconstruct; clean/frozen clients keep their exact bits
            # either way (where-selects, never arithmetic).
            x_end = x
            if comp_c:
                delta = tu.tree_sub(x, x_start)
                u = tu.tree_add(delta, efc) if ef_c else delta
                deq = _cmp.roundtrip(
                    u, mode=comp.client_mode, lead_ndim=2,
                    frac=comp.topk_frac, key=ek, dispatch=comp_dispatch)
                x_cmp = tu.tree_add(x_start, deq)
                x = tu.tree_select(am, x_cmp, x) if am is not None else x_cmp
            if f_corrupt:
                x = _flt.corrupt_uploads(x_start, x, fm.corrupt * am, faults)
            if defended:
                x, ok = _flt.screen_and_clip(x_start, x, defense)
                smask = am * ok
                scr = jnp.sum(am) - jnp.sum(smask)
            else:
                smask = am
            # Correction-state view: z is client-side state, updated from
            # the client's *own* local model plus the received broadcast --
            # the error-feedback residual re-applied on the wire must never
            # enter z (released residual mass fed back through the
            # correction destabilizes EF). Uncompressed, the wire view is
            # the local model and the legacy program is untouched.
            x_loc = x
            if comp_c:
                x_loc = x_end
                if f_corrupt:
                    x_loc = _flt.corrupt_uploads(x_start, x_loc,
                                                 fm.corrupt * am, faults)
            if ef_c:
                # Residual carries forward only for contributions that
                # entered the aggregate: a screened or inactive client
                # leaves its error-feedback state untouched.
                err = tu.tree_sub(u, deq)
                efc = (tu.tree_select(smask, err, efc)
                       if smask is not None else err)
            with jax.named_scope("group_agg"):
                # Group aggregation: mean over (active, surviving) clients;
                # under inverse_prob the masked sum divides by the expected
                # count.
                xbar = (tu.tree_masked_mean(x, smask, axis=1, denom=cdenom)
                        if smask is not None else tu.tree_mean(x, axis=1))
            if use_corr:
                # z_i += (x_{i,H} - xbar_j) / (H * lr)   (Alg. 1 line 9)
                # Gated on the screen mask: screened contributions never
                # integrate into the correction state.
                z_new = jax.tree.map(
                    lambda zi, xe, xb: (
                        zi.astype(jnp.float32)
                        + (xe.astype(jnp.float32) - xb[:, None].astype(jnp.float32)) / (H * lr)
                    ).astype(zi.dtype),
                    z, x_loc, xbar,
                )
                z = tu.tree_select(smask, z_new, z) if smask is not None else z_new
            # dissemination: every active client restarts from its group
            # model; frozen clients keep their params. Under the defense,
            # screened-but-active clients also download (healing) -- unless
            # the whole group was screened (hardened zero mean), in which
            # case its active clients revert to the phase-start model so a
            # screened upload never survives into the global recovery mean
            # (x_start is bit-identical to x for frozen clients).
            xbar_b = jax.tree.map(
                lambda xb, xi: jnp.broadcast_to(xb[:, None], xi.shape), xbar, x
            )
            if smask is None:
                x = xbar_b
            elif defended:
                has_srv = (jnp.sum(smask, axis=1) > 0).astype(jnp.float32)
                x = tu.tree_select(am * has_srv[:, None], xbar_b, x_start)
            else:
                x = tu.tree_select(am, xbar_b, x)
            out = (losses, gnorm, scr) if defended else (losses, gnorm)
            return (x, z, y, efc), out

        if ef_c:
            if state.efc is None:
                raise ValueError(
                    "client-link error feedback carries per-client "
                    "residuals in the state: build it with "
                    "sharded_init(..., ef_client=True) (repro.api.build "
                    "does this for you)")
            efc = state.efc
        else:
            efc = None
        if async_mode:
            scan_xs = (batches, em_all)
        elif c_noise:
            scan_xs = (batches, jax.random.split(kc, E))
        else:
            scan_xs = batches
        (x, z, y, efc), scan_out = jax.lax.scan(
            group_round, (x, z, y, efc), scan_xs)
        if defended:
            losses, gnorms, scrs = scan_out
            screened = jnp.sum(scrs)
        else:
            losses, gnorms = scan_out
            screened = jnp.zeros((), jnp.float32)

        # --- global aggregation + y update (Alg. 1 lines 10-11) ----------
        if ef_g:
            if state.efg is None:
                raise ValueError(
                    "group-link error feedback carries per-group residuals "
                    "in the state: build it with sharded_init(..., "
                    "ef_group=True) (repro.api.build does this for you)")
            efg = state.efg
        else:
            efg = None

        def compress_group(xbar_j, gref, gact):
            """Group -> global link: compress each group's aggregate delta
            vs its round-start reference; inactive groups keep their exact
            (unused) report bits."""
            gdelta = tu.tree_sub(xbar_j, gref)
            ug = tu.tree_add(gdelta, efg) if ef_g else gdelta
            deqg = _cmp.roundtrip(ug, mode=comp.group_mode, lead_ndim=1,
                                  frac=comp.topk_frac,
                                  key=kg if comp_stoch else None,
                                  dispatch=comp_dispatch)
            xbar_c = tu.tree_add(gref, deqg)
            if gact is not None:
                xbar_c = tu.tree_select(gact, xbar_c, xbar_j)
            return xbar_c, ug, deqg

        if async_mode:
            # Staleness-aware merge of the groups reporting this window:
            # same semantics as the simulator engine's async path (see
            # core/engine.py and core/staleness.py), f32 math for narrow
            # correction dtypes.
            if masked:
                gact = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)
                gup = jnp.sum(rep * gact)   # reports actually sent
                with jax.named_scope("global_agg"):
                    xbar_j = tu.tree_masked_mean(x, cmask, axis=1)
                if defended and defense.screen_nonfinite:
                    # Backstop group-level screen before the merge.
                    gfin = _flt.all_finite_mask(xbar_j, 1)
                    screened = screened + jnp.sum(
                        cmask * ((gact * (1.0 - gfin))[:, None]))
                    gact = gact * gfin
                obs = rep * gact
            else:
                xbar_j = jax.tree.map(lambda xi: xi[:, 0], x)
                obs = rep
                gup = jnp.sum(rep)
            if plan.needs_snapshots:
                if state.snap is None or state.glob is None:
                    raise ValueError(
                        "staleness='delay_compensated' carries per-group "
                        "download snapshots in the state: build it with "
                        "sharded_init(..., staleness_snapshots=True) "
                        "(repro.api.build does this for you)")
                xbar_used = jax.tree.map(
                    lambda xj, gl, sn: xj + (jnp.expand_dims(gl, 0) - sn),
                    xbar_j, state.glob, state.snap)
            else:
                xbar_used = xbar_j

            w = rep * dw
            if partial and ht:
                wsum = w * gmask
                sup = wsum * gact
                den = (gdenom / G) * jnp.sum(w)
            elif masked:
                wsum = w * gact
                sup = wsum
                den_raw = jnp.sum(wsum)
                den = jnp.where(den_raw > 0, den_raw, 1.0)
            else:
                wsum = w
                sup = wsum
                den = jnp.sum(w)

            def _stale_merge(v):
                live = tu.expand_mask(sup, v) != 0
                return jnp.sum(
                    jnp.where(live, v, 0) * tu.expand_mask(wsum, v),
                    axis=0) / den

            with jax.named_scope("global_agg"):
                xbar = jax.tree.map(_stale_merge, xbar_used)
        elif masked and (fault_mode or defended or comp_g):
            # The recovery/estimation split opened up so timeouts, the
            # group-level finite screen and the compressed report compose
            # into the estimation path (identical to the simulator
            # engine's fault path).
            with jax.named_scope("global_agg"):
                xbar_j = tu.tree_masked_mean(x, cmask, axis=1)
                gact = (jnp.sum(cmask, axis=1) > 0).astype(jnp.float32)
                if f_timeout:
                    gact = gact * tm_keep
                gup = jnp.sum(gact)   # reports actually sent (pre-screen)
                if comp_g:
                    # Reference the group server and the global server
                    # share: the participating replicas' round-start mean.
                    gref = tu.tree_masked_mean(state.params, cmask, axis=1)
                    xbar_srv = xbar_j  # group's own (pre-wire) aggregate
                    xbar_j, ug, deqg = compress_group(xbar_j, gref, gact)
                if defended and defense.screen_nonfinite:
                    gfin = _flt.all_finite_mask(xbar_j, 1)
                    screened = screened + jnp.sum(
                        cmask * ((gact * (1.0 - gfin))[:, None]))
                    gact = gact * gfin
                if ht:
                    xbar_j0 = jax.tree.map(
                        lambda v: jnp.where(
                            tu.expand_mask(gact, v) != 0, v, 0), xbar_j)
                    xbar = tu.tree_masked_mean(xbar_j0, gmask, axis=0,
                                               denom=gdenom)
                else:
                    xbar = tu.tree_masked_mean(xbar_j, gact, axis=0)
        elif partial:
            with jax.named_scope("global_agg"):
                # Same recovery-then-estimate aggregate as the simulator
                # engine (tree_group_global_mean), keeping the two round
                # builders in lockstep for the parity gates.
                xbar_j, xbar, gact = tu.tree_group_global_mean(
                    x, cmask, gmask if ht else None, gdenom)
            gup = jnp.sum(gact)
        else:
            xbar_j = jax.tree.map(lambda xi: xi[:, 0], x)    # clients equal
            gup = jnp.float32(G)
            if comp_g:
                gref = jax.tree.map(lambda xi: xi[:, 0], state.params)
                xbar_srv = xbar_j  # group's own (pre-wire) aggregate
                xbar_j, ug, deqg = compress_group(xbar_j, gref, None)
            with jax.named_scope("global_agg"):
                xbar = tu.tree_mean(xbar_j, axis=0)
        if ef_g:
            # Gated on the FINAL estimation mask (post timeout + screen):
            # a screened or timed-out report never pollutes the residual.
            errg = tu.tree_sub(ug, deqg)
            efg = tu.tree_select(gact, errg, efg) if masked else errg
        if use_corr:
            if async_mode:
                # y_j += (report_j - xbar) / (H * E_j * r_j * lr): a
                # reporting group ran E_j * r_j group rounds since its
                # download. The policy discount dw weights the merge only
                # -- the y tracking update runs at full rate (see
                # core/staleness.py).
                coef = 1.0 / (e_eff * H * lr)                         # [G]
                y_new = jax.tree.map(
                    lambda yj, xj, xg: (
                        yj.astype(jnp.float32)
                        + tu.expand_mask(coef, yj)
                        * (xj.astype(jnp.float32)
                           - jnp.expand_dims(xg.astype(jnp.float32), 0))
                    ).astype(yj.dtype),
                    y, xbar_used, xbar,
                )
                y = tu.tree_select(obs, y_new, y)
            else:
                # Like z above, y is group-server-side state: it updates
                # from the group's own aggregate (pre-wire), never from
                # the dequantized view carrying the EF residual.
                y_src = xbar_srv if comp_g else xbar_j
                y_new = jax.tree.map(
                    lambda yj, xj, xg: (
                        yj.astype(jnp.float32)
                        + (xj.astype(jnp.float32) - xg.astype(jnp.float32)) / (H * E * lr)
                    ).astype(yj.dtype),
                    y, y_src, xbar,
                )
                y = tu.tree_select(gact, y_new, y) if masked else y_new
        x_glob = jax.tree.map(
            lambda xg: jnp.broadcast_to(xg, (G, K) + xg.shape), xbar
        )
        if async_mode:
            if fault_mode or defended:
                # No download from a window that aggregated nothing (every
                # report screened/timed out: hardened exact-zero merge).
                any_obs = (jnp.sum(obs) > 0).astype(jnp.float32)
                dmask = rep[:, None] * cmask * any_obs
            elif masked:
                # Only reporting groups download; stragglers keep their
                # mid-cycle replicas.
                dmask = rep[:, None] * cmask
            else:
                dmask = jnp.broadcast_to(rep[:, None], (G, K))
            x = tu.tree_select(dmask, x_glob, x)
        else:
            if fault_mode or defended:
                # Timed-out groups miss the download too; no one downloads
                # a global mean with zero surviving groups.
                any_g = (jnp.sum(gact) > 0).astype(jnp.float32)
                dm = cmask * any_g
                if f_timeout:
                    dm = dm * tm_keep[:, None]
                x = tu.tree_select(dm, x_glob, x)
            elif masked:
                x = tu.tree_select(cmask, x_glob, x)
            else:
                x = x_glob

        snap, glob = state.snap, state.glob
        if async_mode and plan.needs_snapshots:
            any_obs = (jnp.sum(obs) > 0).astype(jnp.float32)
            snap = tu.tree_select(
                obs, jax.tree.map(
                    lambda xg, sn: jnp.broadcast_to(
                        jnp.expand_dims(xg, 0), sn.shape), xbar, snap),
                snap)
            glob = tu.tree_select(any_obs, xbar, glob)
        dl = state.dl
        if async_mode and f_timeout:
            # Realized downloads this window (rep already excludes timed-out
            # groups): next round's freshness for the z re-init.
            dl = rep * any_obs
        new_round = None if state.round is None else state.round + 1
        # Bytes on the wire: uploads *sent* this round (screened uploads
        # were transmitted; crashed/unsampled clients and timed-out groups
        # sent nothing), priced by core/compression.py's wire model.
        if async_mode:
            n_up_c = (jnp.sum(em_all[:, :, None] * cmask[None]) if masked
                      else jnp.sum(em_all) * K)
        else:
            n_up_c = (E * jnp.sum(cmask) if masked
                      else jnp.float32(E * G * K))
        comm = _cmp.round_comm_bytes(state.params, comp, n_up_c, gup)
        metrics = ShardedMetrics(
            loss=losses,
            grad_norm=gnorms[-1, -1],
            z_norm=tu.tree_sq_norm(z) / (G * K),
            y_norm=tu.tree_sq_norm(y) / G,
            participation=(jnp.sum(cmask) / (G * K)) if masked
            else jnp.ones((), jnp.float32),
            screened=screened,
            comm_bytes=comm,
        )
        return ShardedHFLState(params=x, z=z, y=y, rng=rng, round=new_round,
                               snap=snap, glob=glob, dl=dl,
                               efc=efc if ef_c else state.efc,
                               efg=efg if ef_g else state.efg), metrics

    return round_fn


# --------------------------------------------------------------------- CLI


def main() -> None:
    from repro.core.api import (
        ExperimentSpec,
        RoundSchedule,
        add_spec_args,
        build,
        fit,
        spec_from_args,
    )

    # Spec flags (--levels/--E/--H/--algorithm/--lr/--state-layout/...) are
    # generated from repro.api's one declarative CLI table; this entry
    # point pins backend="sharded" and microbatches=1 and only hand-keeps
    # the flags that are not ExperimentSpec fields.
    defaults = ExperimentSpec(
        backend="sharded", lr=0.05, state_layout="tree",
        schedule=RoundSchedule(group_rounds=2, local_steps=2, microbatches=1))
    ap = argparse.ArgumentParser(description=__doc__)
    add_spec_args(ap, defaults=defaults, exclude=("backend",))
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host CPU (2 layers, d<=512)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=1,
                    help="global rounds per compiled scan dispatch "
                         "(core/driver.py run_rounds); 0 = the whole "
                         "horizon as one dispatch")
    ap.add_argument("--shards", type=int, default=8,
                    help="packed batch blocks per client uploaded once "
                         "(on-device batch selection)")
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_arch
    from repro.data.lm import make_lm_tokens
    from repro.models.transformer import build_model

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    bundle = build_model(cfg)
    rng = np.random.default_rng(args.seed)
    toks, _ = make_lm_tokens(rng, cfg.vocab_size, 200_000)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))

    spec = spec_from_args(args, defaults=defaults, backend="sharded",
                          microbatches=1)
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"algo={spec.algorithm}")

    engine = build(spec, bundle.loss)
    data = engine.pack_tokens(
        toks, batch_size=args.batch, seq_len=args.seq, shards=args.shards,
        rng=rng, key=jax.random.PRNGKey(args.seed + 1))
    if spec.population is not None:
        G, K = spec.levels
        if spec.client_state == "stateful":
            # Segment-table arithmetic (Packer.state_bytes): the host
            # store holds [G, P] correction rows, the device only [G, K].
            from repro.core.packer import make_packer
            per_client = make_packer(params).state_bytes()
            nfields = len(engine.population_fields)
            print(f"[train] population={spec.population}/group cohort={K} "
                  f"store={G * spec.population * per_client * nfields/1e6:.1f}"
                  f"MB host, device corrections "
                  f"{G * K * per_client * nfields/1e6:.1f}MB")
        else:
            print(f"[train] population={spec.population}/group cohort={K} "
                  "stateless (no store)")
    state, hz = fit(
        engine, data, args.rounds, params=params,
        rng=(jax.random.PRNGKey(args.seed + 2)
             if not spec.full_participation else None),
        chunk=args.chunk)

    for t in range(args.rounds):
        print(f"round {t}: loss {float(hz.metrics.loss[t].mean()):.4f} "
              f"z^2 {float(hz.metrics.z_norm[t]):.3e} "
              f"y^2 {float(hz.metrics.y_norm[t]):.3e}")


if __name__ == "__main__":
    main()
