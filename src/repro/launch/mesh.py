"""Production meshes.

Physical meshes are pinned by the deployment target (TPU v5e pods):

    single-pod : (16, 16)       axes ("data", "model")   = 256 chips
    multi-pod  : (2, 16, 16)    axes ("pod", "data", "model") = 512 chips

Functions (never module-level constants) so importing this module never
touches jax device state -- the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

Training *re-factors the same device array* into the logical HFL mesh
``(group, client, fsdp, model)`` per the architecture's MeshPlan: groups x
clients carry the paper's topology (MTGC's two all-reduce timescales), and
fsdp x model shard each client's replica. On the multi-pod mesh the pod
axis multiplies the group axis -- pods ARE groups, so the infrequent
global aggregation (every E*H steps) is the only traffic on the slow
inter-pod links, which is exactly the paper's communication design.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding.plan import MeshPlan

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_train_mesh(plan: MeshPlan, *, multi_pod: bool = False) -> Mesh:
    """Logical (group, client, fsdp, model) mesh over the production devices.

    The physical device order is preserved (pure relabeling): the last
    logical axis runs over the last physical axis, so ``model`` stays on
    the fastest ICI dimension and ``group`` spans pods in the 2-pod case.
    """
    g, k, f, m = plan.validate().train_factors
    phys = make_production_mesh(multi_pod=multi_pod)
    if multi_pod:
        g *= MULTI_POD[0]
    devices = phys.devices.reshape(g, k, f, m)
    return Mesh(devices, ("group", "client", "fsdp", "model"))


def make_serve_mesh(*, multi_pod: bool = False, kv: int = 1) -> Mesh:
    """Serving mesh. ``kv`` splits the 16-way model axis into (kv, tp):
    GQA kv-heads get their own axis so the KV cache shards by HEAD.

    Why: when kv_heads doesn't divide 16, the cache would otherwise shard
    by sequence, and the one-token cache write (dynamic-update-slice at a
    traced index on a sharded dim) makes SPMD rewrite the entire cache
    shard every layer -- the dominant decode HBM term (Perf iteration 2,
    EXPERIMENTS.md §Perf). kv=1 degenerates to the plain (data, model) mesh.
    """
    if kv <= 1:
        return make_production_mesh(multi_pod=multi_pod)
    tp = 16 // kv
    phys = make_production_mesh(multi_pod=multi_pod)
    if multi_pod:
        devices = phys.devices.reshape(2, 16, kv, tp)
        return Mesh(devices, ("pod", "data", "kv", "tp"))
    devices = phys.devices.reshape(16, kv, tp)
    return Mesh(devices, ("data", "kv", "tp"))


def serve_kv_split(num_heads: int, num_kv_heads: int) -> int:
    """Largest power-of-2 divisor of 16 that divides both head counts."""
    for kv in (16, 8, 4, 2):
        if num_kv_heads % kv == 0 and num_heads % kv == 0:
            return kv
    return 1


def describe(mesh: Mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))} ({mesh.devices.size} chips)"


def smoke_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Tiny host mesh for CPU tests (requires >=4 forced host devices)."""
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
