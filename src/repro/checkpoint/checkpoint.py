"""Step-tagged pytree checkpoints as .npz (flattened key paths) + metadata.

Good enough for CPU-scale runs and round-trips arbitrary nested
dict/tuple/NamedTuple pytrees of arrays -- including the engines' states:
flat-buffer states (``core.packer.FlatBuffers`` registers key paths, so
the contiguous ``[G, K, N]`` buffers round-trip losslessly into a ``like``
state built from the same template) and ``ShardedHFLState.rng`` /
``HFLState.rng`` PRNG keys (saved as their raw uint32 words; a ``None``
rng is structure, not a leaf, and survives untouched). Gated by
tests/test_checkpoint.py's save -> restore -> one-round bit-exactness.

The virtual-population store (``core.population.PopulationStore``) is a
registered pytree of host numpy buffers, so a ``{"state": state,
"population": store}`` tree checkpoints and restores with no special
casing here -- the store's unflatten coerces leaves back to host numpy so
in-place cohort scatter keeps working on a restored store (gated by
tests/test_population.py).

Sharded production checkpoints would swap in tensorstore under the same
API.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "||"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: PyTree, metadata: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    flat = _flatten(tree)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    meta = dict(metadata or {})
    meta["step"] = step
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (values replaced, dtypes kept)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for keypath, leaf in paths:
        key = _SEP.join(str(p) for p in keypath)
        if key not in data:
            raise ValueError(
                f"checkpoint {path} has no leaf {key!r}; was it saved from "
                "a state with a different structure?")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, but the "
                f"`like` state expects {tuple(leaf.shape)}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
