"""Fused MTGC local update: ``x <- x - lr * (g + z + y)`` (Alg. 1 line 7).

This is the paper's per-iteration hot-spot: a 4-operand AXPY executed
H*E times per round on every parameter element of every client replica.
Unfused, XLA emits three binary ops -> up to 3 extra HBM round-trips of the
parameter-sized intermediates. The kernel streams all four operands through
VMEM once (arithmetic intensity is fixed at ~0.75 flop/byte, so HBM
bandwidth is the ceiling and fusion is the whole win).

Layout: operands are flattened and tiled to (ROWS, 128) lanes -- the TPU
vector layout -- with a (block_rows, 128) VMEM block per grid step (default
1024x128xf32 x 5 buffers = 2.6 MB of VMEM); the correction sum runs in f32
regardless of the storage dtype (z/y may be bf16 under the beyond-paper
low-precision-correction option).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 1024


def _kernel(lr, x_ref, g_ref, z_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    d = (g_ref[...].astype(jnp.float32)
         + z_ref[...].astype(jnp.float32)
         + y_ref[...].astype(jnp.float32))
    o_ref[...] = (x - lr * d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "block_rows", "interpret"))
def mtgc_update(x, g, z, y, *, lr: float, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = False):
    """Fused corrected update over arbitrary-shaped (equal-shape) arrays."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows = -(-n // LANE)
    rows_p = -(-rows // block_rows) * block_rows
    pad = rows_p * LANE - n

    def prep(a):
        a = a.reshape(-1)
        if pad:
            a = jnp.pad(a, (0, pad))
        return a.reshape(rows_p, LANE)

    xs = [prep(a) for a in (x, g, z, y)]
    grid = (rows_p // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, float(lr)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
                  for _ in range(4)],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, LANE), dtype),
        interpret=interpret,
    )(*xs)
    return out.reshape(-1)[:n].reshape(shape)
