"""Fused MTGC local update: ``x <- x - lr * (g + z + y)`` (Alg. 1 line 7).

This is the paper's per-iteration hot-spot: a 4-operand AXPY executed
H*E times per round on every parameter element of every client replica.
Unfused, XLA emits three binary ops -> up to 3 extra HBM round-trips of the
parameter-sized intermediates. The kernel streams all four operands through
VMEM once (arithmetic intensity is fixed at ~0.75 flop/byte, so HBM
bandwidth is the ceiling and fusion is the whole win).

Two entry points:

* :func:`mtgc_update` -- one (equal-shape) leaf at a time. Layout: operands
  are flattened and tiled to (ROWS, 128) lanes -- the TPU vector layout --
  with a (block_rows, 128) VMEM block per grid step (default 1024x128xf32
  x 5 buffers = 2.6 MB of VMEM); the correction sum runs in f32 regardless
  of the storage dtype (z/y may be bf16 under the beyond-paper
  low-precision-correction option).

* :func:`mtgc_update_flat` -- the whole model at once over the contiguous
  flat-state layout (core/packer.py): x/g/z are ``[G, K, N]``, ``y`` stays
  ``[G, N]`` and is broadcast across clients *by the block index map* (never
  materialized per client), and an optional ``[G, K]`` participation mask is
  folded into the update in-register -- eliminating the parameter-sized
  ``tree_select`` HBM pass per local step. One lane-padding for the entire
  model instead of one per leaf.

``g_scale`` folds the microbatch-accumulation mean (``g / A`` on the
sharded path) into the same pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 1024


def _kernel(lr, g_scale, x_ref, g_ref, z_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    d = (g_ref[...].astype(jnp.float32) * g_scale
         + z_ref[...].astype(jnp.float32)
         + y_ref[...].astype(jnp.float32))
    o_ref[...] = (x - lr * d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "g_scale", "block_rows",
                                             "interpret"))
def mtgc_update(x, g, z, y, *, lr: float, g_scale: float = 1.0,
                block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False):
    """Fused corrected update over arbitrary-shaped (equal-shape) arrays."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows = -(-n // LANE)
    rows_p = -(-rows // block_rows) * block_rows
    pad = rows_p * LANE - n

    def prep(a):
        a = a.reshape(-1)
        if pad:
            a = jnp.pad(a, (0, pad))
        return a.reshape(rows_p, LANE)

    xs = [prep(a) for a in (x, g, z, y)]
    grid = (rows_p // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, float(lr), float(g_scale)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
                  for _ in range(4)],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, LANE), dtype),
        interpret=interpret,
    )(*xs)
    return out.reshape(-1)[:n].reshape(shape)


def _flat_kernel(lr, g_scale, x_ref, g_ref, z_ref, y_ref, m_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    d = (g_ref[...].astype(jnp.float32) * g_scale
         + z_ref[...].astype(jnp.float32)
         + y_ref[...].astype(jnp.float32))
    x_new = x - lr * d
    if m_ref is not None:
        x_new = jnp.where(m_ref[0, 0] != 0, x_new, x)
    o_ref[...] = x_new.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "g_scale", "block_rows",
                                             "interpret"))
def mtgc_update_flat(x, g, z, y, mask=None, *, lr: float, g_scale: float = 1.0,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False):
    """Whole-model fused update over flat buffers.

    x, g, z: [G, K, N]; y: [G, N] (broadcast over clients via the index
    map); mask: optional [G, K] 0/1 participation gate -- frozen replicas
    keep their exact bits. Returns the updated [G, K, N] buffer.
    """
    G, K, n = x.shape
    dtype = x.dtype
    rows = -(-n // LANE)
    # Clamp the block to the (8-row aligned) model size so small models do
    # not pay a 1024-row pad; one pad for the entire model either way.
    br = min(block_rows, -(-rows // 8) * 8)
    rows_p = -(-rows // br) * br
    pad = rows_p * LANE - n

    def prep(a, lead):
        a = a.reshape(lead + (n,))
        if pad:
            a = jnp.pad(a, [(0, 0)] * len(lead) + [(0, pad)])
        return a.reshape(lead + (rows_p, LANE))

    xs, gs, zs = (prep(a, (G * K,)) for a in
                  (x.reshape(G * K, n), g.reshape(G * K, n), z.reshape(G * K, n)))
    ys = prep(y, (G,))
    grid = (G * K, rows_p // br)
    ck_spec = pl.BlockSpec((1, br, LANE), lambda i, j: (i, j, 0))
    in_specs = [ck_spec, ck_spec, ck_spec,
                pl.BlockSpec((1, br, LANE), lambda i, j: (i // K, j, 0))]
    operands = [xs, gs, zs, ys]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, 0)))
        operands.append(mask.reshape(G * K, 1).astype(jnp.float32))
        kern = functools.partial(_flat_kernel, float(lr), float(g_scale))
    else:
        kern = functools.partial(
            lambda lr_, gs_, x_, g_, z_, y_, o_: _flat_kernel(
                lr_, gs_, x_, g_, z_, y_, None, o_),
            float(lr), float(g_scale))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, br, LANE), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((G * K, rows_p, LANE), dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(G * K, rows_p * LANE)[:, :n].reshape(G, K, n)
