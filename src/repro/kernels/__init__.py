"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three artifacts: ``<name>.py`` (pl.pallas_call with
explicit BlockSpec VMEM tiling), a pure-jnp oracle in ``ref.py``, and a
dispatching wrapper in ``ops.py`` (Pallas on TPU, interpret/ref on CPU).
"""
from repro.kernels.ops import flash_attention, mtgc_update, rwkv6_scan

__all__ = ["flash_attention", "mtgc_update", "rwkv6_scan"]
