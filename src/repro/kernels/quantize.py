"""Batched upload-compression kernels over the flat row layout.

Both kernels consume a batch of per-row upload vectors -- ``u: [R, N]``
where a row is one client's (or one group's) whole-model delta in the
contiguous flat layout (core/packer.py) -- plus one per-row scalar
(quantization scale or top-k threshold) fed through the same ``(1, 1)``
block-spec idiom the fused MTGC kernel uses for participation masks, so
the scalar is read once per grid row and the quantize -> dequantize
round trip happens entirely in-register: the int8 payload is never
materialized in HBM (bytes on the wire are accounted analytically in
``core/compression.py``).

* :func:`int8_roundtrip` -- stochastic rounding to int8 and back:
  ``q = clip(floor(u / scale + noise), -127, 127)``, ``deq = q * scale``
  with ``noise ~ U[0, 1)`` drawn outside the kernel from the carried
  state rng (an explicit operand keeps pallas/interpret/ref bit-exact).
  With ``scale = amax(|row|) / 127`` the clip never binds; it guards the
  zero-row ``scale = 1`` fallback.

* :func:`topk_mask` -- magnitude sparsification: keep entries with
  ``|u| >= thresh`` (the per-row k-th largest magnitude, computed outside
  via ``jax.lax.top_k``), zero the rest. Ties at the threshold are all
  kept, so the realized density can exceed k/N by the tie count.

Layout matches ``mtgc_update_flat``: rows flatten to (rows, 128) lanes,
block rows clamp to the 8-aligned model size, one lane-pad for the whole
batch. Padding lanes are zero in every operand, and both kernel bodies
map zero inputs to zero outputs (``floor(0 + noise) = 0`` for
``noise < 1``), so the pad never leaks into real lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 1024


def _geometry(n: int, block_rows: int):
    rows = -(-n // LANE)
    br = min(block_rows, -(-rows // 8) * 8)
    rows_p = -(-rows // br) * br
    return br, rows_p, rows_p * LANE - n


def _prep(a, R: int, n: int, rows_p: int, pad: int):
    a = a.reshape(R, n)
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)])
    return a.reshape(R, rows_p, LANE)


def _int8_kernel(u_ref, n_ref, s_ref, o_ref):
    scale = s_ref[0, 0]
    u = u_ref[...].astype(jnp.float32)
    q = jnp.floor(u / scale + n_ref[...].astype(jnp.float32))
    q = jnp.clip(q, -127.0, 127.0)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_roundtrip(u, scale, noise, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """Stochastic int8 quantize + dequantize. u/noise: [R, N]; scale: [R]."""
    R, n = u.shape
    dtype = u.dtype
    br, rows_p, pad = _geometry(n, block_rows)
    us = _prep(u, R, n, rows_p, pad)
    ns = _prep(noise, R, n, rows_p, pad)
    grid = (R, rows_p // br)
    row_spec = pl.BlockSpec((1, br, LANE), lambda i, j: (i, j, 0))
    out = pl.pallas_call(
        _int8_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec,
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((R, rows_p, LANE), dtype),
        interpret=interpret,
    )(us, ns, scale.reshape(R, 1).astype(jnp.float32))
    return out.reshape(R, rows_p * LANE)[:, :n]


def _topk_kernel(u_ref, t_ref, o_ref):
    thresh = t_ref[0, 0]
    u = u_ref[...]
    o_ref[...] = jnp.where(jnp.abs(u) >= thresh, u,
                           jnp.zeros_like(u)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def topk_mask(u, thresh, *, block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False):
    """Keep entries with |u| >= per-row thresh, zero the rest. u: [R, N]."""
    R, n = u.shape
    dtype = u.dtype
    br, rows_p, pad = _geometry(n, block_rows)
    us = _prep(u, R, n, rows_p, pad)
    grid = (R, rows_p // br)
    row_spec = pl.BlockSpec((1, br, LANE), lambda i, j: (i, j, 0))
    out = pl.pallas_call(
        _topk_kernel,
        grid=grid,
        in_specs=[row_spec, pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((R, rows_p, LANE), dtype),
        interpret=interpret,
    )(us, thresh.reshape(R, 1).astype(dtype))
    return out.reshape(R, rows_p * LANE)[:, :n]
