"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against over shape/dtype sweeps, and the path the models use
on non-TPU backends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mtgc_update_ref(x, g, z, y, lr, g_scale=1.0):
    """x <- x - lr * (g * g_scale + z + y), correction sum in f32."""
    d = (g.astype(jnp.float32) * g_scale + z.astype(jnp.float32)
         + y.astype(jnp.float32))
    return (x.astype(jnp.float32) - lr * d).astype(x.dtype)


def mtgc_update_flat_ref(x, g, z, y, mask=None, lr=0.1, g_scale=1.0):
    """Flat-layout oracle: x/g/z [G,K,N], y [G,N], mask [G,K] or None.

    The masked branch keeps frozen replicas' exact bits (``where``, not
    multiplication), matching the Pallas kernel and ``tree_select``.
    """
    d = (g.astype(jnp.float32) * g_scale + z.astype(jnp.float32)
         + y.astype(jnp.float32)[:, None])
    x_new = (x.astype(jnp.float32) - lr * d).astype(x.dtype)
    if mask is None:
        return x_new
    return jnp.where(mask[..., None] != 0, x_new, x)


def int8_roundtrip_ref(u, scale, noise):
    """Stochastic int8 quantize + dequantize. u/noise: [R, N]; scale: [R].

    ``q = clip(floor(u / scale + noise), -127, 127)``, ``deq = q * scale``
    -- same op order and f32 arithmetic as the Pallas kernel, so the two
    are bit-exact. ``noise ~ U[0, 1)`` makes the rounding unbiased.
    """
    s = scale.astype(jnp.float32)[:, None]
    q = jnp.floor(u.astype(jnp.float32) / s + noise.astype(jnp.float32))
    q = jnp.clip(q, -127.0, 127.0)
    return (q * s).astype(u.dtype)


def topk_mask_ref(u, thresh):
    """Keep entries with |u| >= per-row thresh, zero the rest. u: [R, N]."""
    return jnp.where(jnp.abs(u) >= thresh.astype(u.dtype)[:, None], u,
                     jnp.zeros_like(u))


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Naive attention with GQA expansion. q: [B,T,H,Dh]; k/v: [B,S,Kv,Dh]."""
    B, T, H, Dh = q.shape
    Kv = k.shape[2]
    if Kv != H:
        k = jnp.repeat(k, H // Kv, axis=2)
        v = jnp.repeat(v, H // Kv, axis=2)
    S = k.shape[1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (Dh ** -0.5)
    qpos = jnp.arange(T) + q_offset
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, logw, u, state):
    """Sequential RWKV-6 recurrence (per-head).

    r/k/v/logw: [B, H, T, Dh] (f32); u: [H, Dh]; state: [B, H, Dh, Dh].
    Returns (o [B,H,T,Dh], final state).
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(S, inp):
        rt, kt, vt, lwt = inp                                 # [B,H,Dh]
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        o = jnp.einsum("bhd,bhde->bhe", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, o

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r, k, v, logw))  # [T,B,H,Dh]
    state, o = jax.lax.scan(step, state, xs)
    return o.transpose(1, 2, 0, 3), state
