"""Blocked online-softmax (flash) attention for TPU.

Supports the whole assigned-arch attention matrix: causal, sliding-window
(mixtral/gemma3-local/hymba), and GQA (kv heads indexed as h // group).

Grid = (B * H, n_q_blocks, n_kv_blocks); on TPU the grid runs sequentially
over the LAST axis, so the (m, l, acc) online-softmax state lives in VMEM
scratch and is carried across kv blocks of the same (bh, q-block) cell.
Fully-masked kv blocks (future blocks under causality, blocks older than
the sliding window) are skipped with ``pl.when`` -- on real hardware that
makes causal attention ~2x cheaper than the dense jnp fallback and makes
sliding-window cost O(T * W) instead of O(T^2).

Block shapes: q (1, bq, 1, Dh), k/v (1, bk, 1, Dh), both 128-lane-aligned;
VMEM per step ~ bq*Dh(q) + 2*bk*Dh(kv) + bq*bk(logits,f32) + bq*Dh(acc,f32)
= ~2.6 MB at bq=bk=512, Dh=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(causal, window, bq, bk, scale, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * bq
    k0 = ik * bk
    # block-level skip: entire kv block in the future (causal) or entirely
    # older than the sliding window for every query row of this block.
    live = True
    if causal:
        live = k0 <= q0 + bq - 1
    if window > 0:
        live = jnp.logical_and(live, k0 + bk - 1 > q0 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: [B, T, H, Dh]; k/v: [B, S, Kv, Dh] (GQA: Kv divides H).

    Returns [B, T, H, Dh] in q.dtype. T % block_q == 0 and S % block_k == 0
    are required (callers pad); window/causal semantics match
    ``ref.flash_attention_ref``.
    """
    B, T, H, Dh = q.shape
    S, Kv = k.shape[1], k.shape[2]
    assert H % Kv == 0, (H, Kv)
    group = H // Kv
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    scale = Dh ** -0.5

    grid = (B * H, T // bq, S // bk)
    kernel = functools.partial(_kernel, causal, int(window), bq, bk, scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda bh, iq, ik: (bh // H, iq, bh % H, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda bh, iq, ik: (bh // H, ik, (bh % H) // group, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda bh, iq, ik: (bh // H, ik, (bh % H) // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh),
                               lambda bh, iq, ik: (bh // H, iq, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l (running denom)
            pltpu.VMEM((bq, Dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
