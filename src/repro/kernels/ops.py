"""Dispatching wrappers: Pallas on TPU, interpret-mode or jnp ref elsewhere.

The model code calls these; ``mode`` resolves per backend:
    "auto"      -> compiled Pallas on TPU, pure-jnp reference on CPU/GPU
    "pallas"    -> compiled Pallas (TPU only)
    "interpret" -> Pallas kernel body interpreted op-by-op (CPU validation)
    "ref"       -> pure-jnp oracle
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as fa
from repro.kernels import mtgc_update as mu
from repro.kernels import quantize as qz
from repro.kernels import ref
from repro.kernels import rwkv6_scan as rs


def _resolve(mode: str) -> str:
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def mtgc_update(x, g, z, y, *, lr, g_scale=1.0, mode: str = "auto", **kw):
    m = _resolve(mode)
    if m == "ref":
        return ref.mtgc_update_ref(x, g, z, y, lr, g_scale)
    return mu.mtgc_update(x, g, z, y, lr=lr, g_scale=g_scale,
                          interpret=(m == "interpret"), **kw)


def mtgc_update_flat(x, g, z, y, mask=None, *, lr, g_scale=1.0,
                     mode: str = "auto", **kw):
    """Whole-model fused update on the flat [G,K,N] layout (see packer.py)."""
    m = _resolve(mode)
    if m == "ref":
        return ref.mtgc_update_flat_ref(x, g, z, y, mask, lr, g_scale)
    return mu.mtgc_update_flat(x, g, z, y, mask, lr=lr, g_scale=g_scale,
                               interpret=(m == "interpret"), **kw)


def int8_roundtrip(u, scale, noise, *, mode: str = "auto", **kw):
    """Stochastic int8 quantize+dequantize of upload rows (see quantize.py)."""
    m = _resolve(mode)
    if m == "ref":
        return ref.int8_roundtrip_ref(u, scale, noise)
    return qz.int8_roundtrip(u, scale, noise, interpret=(m == "interpret"),
                             **kw)


def topk_mask(u, thresh, *, mode: str = "auto", **kw):
    """Per-row magnitude sparsification of upload rows (see quantize.py)."""
    m = _resolve(mode)
    if m == "ref":
        return ref.topk_mask_ref(u, thresh)
    return qz.topk_mask(u, thresh, interpret=(m == "interpret"), **kw)


def flash_attention(q, k, v, *, causal=True, window=0, mode: str = "auto", **kw):
    m = _resolve(mode)
    if m == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=(m == "interpret"), **kw)


def rwkv6_scan(r, k, v, logw, u, state, *, mode: str = "auto", **kw):
    """ref-style shapes: r/k/v/logw [B,H,T,Dh]; u [H,Dh]; state [B,H,Dh,Dh]."""
    m = _resolve(mode)
    if m == "ref":
        return ref.rwkv6_scan_ref(r, k, v, logw, u, state)
    import jax.numpy as jnp
    B, H, T, Dh = r.shape
    flat = lambda a: a.reshape(B * H, T, Dh)
    u_b = jnp.broadcast_to(u[None], (B, H, Dh)).reshape(B * H, Dh)
    o, s = rs.rwkv6_scan(flat(r), flat(k), flat(v), flat(logw), u_b,
                         state.reshape(B * H, Dh, Dh),
                         interpret=(m == "interpret"), **kw)
    return o.reshape(B, H, T, Dh), s.reshape(B, H, Dh, Dh)
