"""Chunked RWKV-6 (Finch) linear recurrence for TPU.

The CUDA kernels released with the paper stream tokens sequentially per
thread-block; that shape is wrong for the MXU. The TPU-native re-blocking
is the *chunked parallel form*: inside a chunk of C tokens all work is
dense [C, Dh] x [Dh, Dh] / [C, C] matmuls (MXU), and only the [Dh, Dh]
state crosses chunks (sequentially, via the Pallas grid which executes the
last axis in order).

Per (batch*head, chunk) grid cell, in VMEM:
    r, k, v, logw blocks    [C, Dh]
    pairwise decay tensor   [C, C, Dh] (f32)  -- C=64, Dh=64 -> 1 MB
    state scratch           [Dh, Dh]   (f32)

Recurrence (per head, state S in R^{Dh x Dv}):
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(logw_t)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(C, Dh, r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            o_ref, sout_ref, s_ref):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)          # [C, Dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [1, Dh] broadcast row
    S = s_ref[...]

    cum = jnp.cumsum(lw, axis=0)              # inclusive [C, Dh]
    cum_ex = cum - lw                         # exclusive

    # carried-state contribution: (r * exp(cum_ex)) @ S
    a = r * jnp.exp(cum_ex)
    o_state = jax.lax.dot_general(a, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # intra-chunk pairwise decays exp(cum_ex[t] - cum[i]) for i < t
    dmat = cum_ex[:, None, :] - cum[None, :, :]          # [C, C, Dh]
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    w_pair = jnp.where(tri[..., None], jnp.exp(dmat), 0.0)
    att = jnp.einsum("cd,id,cid->ci", r, k, w_pair,
                     preferred_element_type=jnp.float32)
    o_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # diagonal bonus: (r_t . (u * k_t)) v_t
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)    # [C, 1]
    o_ref[0] = (o_state + o_intra + bonus * v).astype(o_ref.dtype)

    # state update: S' = diag(prod w) S + sum_i exp(cum[-1] - cum[i]) k_i v_i^T
    wtot = jnp.exp(cum[-1, :])                            # [Dh]
    kdec = k * jnp.exp(cum[-1:, :] - cum)                 # [C, Dh]
    s_new = wtot[:, None] * S + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ic == pl.num_programs(1) - 1)
    def _finish():
        sout_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, state, *, chunk: int = 64,
               interpret: bool = False):
    """r/k/v/logw: [BH, T, Dh] (any float dtype); u: [BH, Dh] (the per-head
    bonus, pre-broadcast over batch); state: [BH, Dh, Dh] f32.
    Returns (o [BH, T, Dh] f32, final_state [BH, Dh, Dh] f32).
    T % chunk == 0 (callers pad with k=0, logw=0 -- state-preserving).
    """
    BH, T, Dh = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    nc = T // C
    grid = (BH, nc)
    kernel = functools.partial(_kernel, C, Dh)
    u2 = u[:, None, :]  # [BH, 1, Dh]
    o, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Dh), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, Dh, Dh), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Dh, Dh), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, Dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, Dh, Dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dh, Dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u2, state)
    return o, s_out
