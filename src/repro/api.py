"""``repro.api`` -- the unified experiment surface (see ``repro.core.api``).

One import gives the whole front door::

    from repro import api
    engine = api.build(api.ExperimentSpec(levels=(4, 5)), loss_fn)
    state, horizon = api.fit(engine, data, T=30, params=params)

Everything here is re-exported from :mod:`repro.core.api`, which holds the
implementation next to the engines it adapts.
"""
from repro.core.api import *  # noqa: F401,F403
from repro.core.api import __all__  # noqa: F401
