"""The representative spec matrix the static auditor lowers.

One :class:`AuditCase` per load-bearing corner of the configuration
space -- algorithms x {tree, flat} x {simulator, sharded, multilevel} x
{sync, async} x {faults, population} -- each small enough that
trace + lower + compile on CPU takes well under a second, because the
auditor inspects *programs*, never runs them: shapes only matter insofar
as they exercise distinct lowering paths (flat vs tree state, fused vs
unfused kernels, padded async inner loops, screened aggregation, the
M-level recursion).

Fused cases pin an interpret-mode kernel dispatch off-TPU (the sharded
backend via ``fused_mode="interpret"``; the simulator engine picks
interpret itself) so the ``pallas_call`` fusion contract is auditable on
the CPU CI container, where ``"auto"`` would fall back to the pure-jnp
reference and lower zero kernels.

Everything an audit pass needs is derived here with zero allocation:
``abstract_params`` / ``Engine.abstract_state`` / ``abstract_data``
produce ShapeDtypeStruct pytrees that flow through
``Engine.lower_chunk`` untouched.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api import (
    CompressionPlan,
    DefensePlan,
    ExperimentSpec,
    FaultPlan,
    PackedBatches,
    RoundSchedule,
    build,
)

# Tiny but non-degenerate: every topology axis >= 2 so a transposed or
# dropped axis cannot lower to the same program by coincidence.
DIM = 6
BATCH = 2
SHARDS = 3
CHUNK = 2


def quad_loss(params, batch):
    """0.5 * ||a * w - b||^2 -- the conformance-suite loss; one dense
    param leaf keeps per-leaf kernel counts and cost budgets readable."""
    r = batch["a"] * params["w"] - batch["b"]
    return 0.5 * jnp.sum(r * r)


def abstract_params(dim: int = DIM):
    return {"w": jax.ShapeDtypeStruct((dim,), jnp.float32)}


def abstract_data(engine, *, dim: int = DIM, batch: int = BATCH,
                  shards: int = SHARDS) -> PackedBatches:
    """Abstract :class:`PackedBatches` in this engine's driver layout.

    Leaves are ``[*levels, S, steps, B, D]`` ShapeDtypeStructs with
    ``steps = local_steps * microbatches`` -- exactly what the engine's
    ``pack_arrays`` would upload, minus the upload.
    """
    spec = engine.spec
    steps = engine._pack_steps * (engine._pack_microbatches or 1)
    shape = tuple(spec.levels) + (shards, steps, batch, dim)

    def leaf():
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    return PackedBatches(
        {"a": leaf(), "b": leaf()},
        jax.ShapeDtypeStruct((2,), jnp.uint32),
        engine._pack_rounds,
        engine._pack_steps,
        engine._pack_microbatches,
        topo_ndim=len(spec.levels),
    )


@dataclasses.dataclass(frozen=True)
class AuditCase:
    """One audited configuration.

    name: stable identifier -- the key into ``analysis/budgets.json``.
    spec: the :class:`ExperimentSpec` lowered through ``build``.
    fast: included in the blocking ``audit --fast`` subset.
    fused_leaves: expected ``pallas_call`` count per audited program when
        ``spec.fusion == "fused"`` -- one per correction buffer the round
        updates (1 for the single-dtype flat layout, one per param leaf
        for tree), plus one per compressed upload link that is
        kernel-backed (``int8_stochastic`` / ``topk``; ``bf16`` is a
        pure cast and lowers no kernel). Unfused specs must lower to
        exactly zero -- including compressed ones, whose round trips
        then route through the jnp reference.
    """

    name: str
    spec: ExperimentSpec
    fast: bool = True

    @property
    def fused_leaves(self) -> int:
        if self.spec.fusion != "fused":
            return 0
        # Flat state packs all same-dtype leaves into one buffer; the
        # quad-loss model is single-leaf f32 either way, so both layouts
        # expect one kernel per round phase that touches z.
        n = 1
        comp = self.spec.compression
        if comp is not None:
            n += sum(1 for mode in (comp.client_mode, comp.group_mode)
                     if mode in ("int8_stochastic", "topk"))
        return n

    def build_engine(self, loss_fn=quad_loss):
        return build(self.spec, loss_fn)


def _spec(**kw) -> ExperimentSpec:
    kw.setdefault("levels", (2, 3))
    kw.setdefault("schedule", RoundSchedule(group_rounds=2, local_steps=2))
    return ExperimentSpec(**kw).validate()


def audit_cases(fast_only: bool = False) -> list[AuditCase]:
    """The audited matrix; ``fast_only`` selects the blocking-CI subset."""
    cases = [
        # -- simulator backend: both layouts, fused and reference paths.
        AuditCase("sim_mtgc_tree", _spec(
            algorithm="mtgc", state_layout="tree")),
        AuditCase("sim_mtgc_flat_fused", _spec(
            algorithm="mtgc", state_layout="flat", fusion="fused")),
        AuditCase("sim_hfedavg_flat", _spec(
            algorithm="hfedavg", state_layout="flat")),
        # -- sharded production round: fused flat (interpret off-TPU) and
        #    the narrow-correction tree path.
        AuditCase("sharded_mtgc_flat_fused", _spec(
            algorithm="mtgc", backend="sharded", state_layout="flat",
            fusion="fused", fused_mode="interpret",
            schedule=RoundSchedule(group_rounds=2, local_steps=2,
                                   microbatches=2))),
        AuditCase("sharded_mtgc_tree_bf16", _spec(
            algorithm="mtgc", backend="sharded", state_layout="tree",
            correction_dtype="bfloat16",
            schedule=RoundSchedule(group_rounds=2, local_steps=2,
                                   microbatches=2))),
        # -- M-level recursion (Appendix E), 3-level client-edge-cloud.
        AuditCase("multilevel_mtgc_3level", _spec(
            algorithm="mtgc", backend="multilevel", levels=(2, 2, 2),
            state_layout="tree",
            schedule=RoundSchedule(periods=(4, 2, 1)))),
        # -- async group rounds: padded straggler loop + staleness merge.
        AuditCase("sim_async_discount_flat", _spec(
            algorithm="mtgc", state_layout="flat", staleness="discount",
            schedule=RoundSchedule(group_rounds=(2, 1), local_steps=2))),
        # -- fault injection + screened aggregation + HT weighting.
        AuditCase("sim_faults_defended_flat", _spec(
            algorithm="mtgc", state_layout="flat",
            client_participation=0.7,
            participation_weighting="inverse_prob",
            faults=FaultPlan(crash_rate=0.1, timeout_rate=0.1,
                             corrupt_rate=0.1, corrupt_kind="explode"),
            defense=DefensePlan(screen_nonfinite=True, screen_norm=10.0))),
        # -- compressed uploads: kernel-backed quantize/top-k round trips
        #    at both links ride the fused dispatch (1 MTGC + 2 link
        #    kernels expected), plus the modeled comm-budget shrink gate.
        AuditCase("sim_compressed_int8_flat", _spec(
            algorithm="mtgc", state_layout="flat", fusion="fused",
            compression=CompressionPlan(client_mode="int8_stochastic",
                                        group_mode="int8_stochastic"))),
        AuditCase("sharded_compressed_topk_tree", _spec(
            algorithm="mtgc", backend="sharded", state_layout="tree",
            fusion="fused", fused_mode="interpret",
            compression=CompressionPlan(client_mode="topk",
                                        group_mode="bf16", topk_frac=0.1),
            schedule=RoundSchedule(group_rounds=2, local_steps=2,
                                   microbatches=2)), fast=False),
        # -- virtual population: cohort-shaped buffers + stateless wrap.
        AuditCase("sim_population_flat", _spec(
            algorithm="mtgc", state_layout="flat", population=8,
            cohort_size=3)),
        AuditCase("sim_stateless_flat", _spec(
            algorithm="mtgc", state_layout="flat", population=8,
            client_state="stateless")),
        # -- full-matrix extras (cheap, but redundant for the blocking
        #    gate): remaining simulator algorithms.
        AuditCase("sim_local_corr_tree", _spec(
            algorithm="local_corr", state_layout="tree"), fast=False),
        AuditCase("sim_group_corr_flat", _spec(
            algorithm="group_corr", state_layout="flat"), fast=False),
        AuditCase("sim_fedprox_flat", _spec(
            algorithm="fedprox", state_layout="flat", prox_mu=0.1),
            fast=False),
        AuditCase("sim_feddyn_flat", _spec(
            algorithm="feddyn", state_layout="flat", feddyn_alpha=0.1),
            fast=False),
        AuditCase("sharded_hfedavg_flat", _spec(
            algorithm="hfedavg", backend="sharded", state_layout="flat",
            schedule=RoundSchedule(group_rounds=2, local_steps=2,
                                   microbatches=2)), fast=False),
    ]
    if fast_only:
        cases = [c for c in cases if c.fast]
    names = [c.name for c in cases]
    assert len(names) == len(set(names)), "duplicate audit case names"
    return cases


def case_by_name(name: str) -> AuditCase:
    for c in audit_cases():
        if c.name == name:
            return c
    raise KeyError(f"unknown audit case {name!r} "
                   f"(see `python -m repro.launch.audit --list`)")
