"""Compiled-cost budgets: the audited programs' roofline, pinned to disk.

For every audit case, ``launch/hlo_analysis.analyze`` extracts
trip-count-aware FLOPs / HBM bytes / collective bytes from the optimized
HLO of the lowered driver chunk. Those numbers are checked into
``analysis/budgets.json`` with a relative tolerance band; an accidental
retrace-shaped blowup, a lost fusion, or a fattened collective then
fails the audit *before* any benchmark runs.

Budgets are a property of the compiler as much as of this repo, so the
file records the jax version and backend it was generated on. On a
mismatched environment the drift check degrades to notes (severity
``"note"``) rather than failures -- refresh with::

    python -m repro.launch.audit --update
"""
from __future__ import annotations

import json
from pathlib import Path

import jax

from repro.analysis.invariants import Finding
from repro.launch import hlo_analysis

BUDGET_PATH = Path(__file__).with_name("budgets.json")
METRICS = ("flops", "bytes", "collective_bytes")
DEFAULT_RTOL = 0.2


def measure(lc) -> dict[str, float]:
    """Roofline terms of one lowered chunk (per device, whole chunk)."""
    costs = hlo_analysis.analyze(lc.hlo)
    return {"flops": float(costs.flops),
            "bytes": float(costs.bytes),
            "collective_bytes": float(costs.collective_bytes)}


def load(path: Path | str = BUDGET_PATH) -> dict:
    path = Path(path)
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def save(measured: dict[str, dict[str, float]],
         path: Path | str = BUDGET_PATH,
         rtol: float = DEFAULT_RTOL) -> dict:
    doc = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rtol": rtol,
        "specs": {name: {k: round(v, 3) for k, v in m.items()}
                  for name, m in sorted(measured.items())},
    }
    Path(path).write_text(json.dumps(doc, indent=1) + "\n")
    return doc


def environment_matches(doc: dict) -> bool:
    return (doc.get("jax") == jax.__version__
            and doc.get("backend") == jax.default_backend())


def check(measured: dict[str, dict[str, float]],
          doc: dict | None = None,
          *,
          strict: bool | None = None,
          complete: bool = True) -> list[Finding]:
    """Compare measured costs to the checked-in budgets.

    ``strict=None`` enforces only when the budget file was generated on
    this jax version + backend (compiler drift legitimately moves the
    numbers); pass ``strict=True``/``False`` to force either mode.
    """
    doc = load() if doc is None else doc
    if not doc:
        return [Finding(name, "budget",
                        "no budgets.json checked in (run audit --update)",
                        "note")
                for name in sorted(measured)]
    if strict is None:
        strict = environment_matches(doc)
    severity = "error" if strict else "note"
    rtol = float(doc.get("rtol", DEFAULT_RTOL))
    budgets = doc.get("specs", {})
    out: list[Finding] = []
    if not strict:
        out.append(Finding(
            "*", "budget",
            f"budgets generated on jax {doc.get('jax')}/"
            f"{doc.get('backend')}, running jax {jax.__version__}/"
            f"{jax.default_backend()}: drift reported but not enforced",
            "note"))
    for name in sorted(measured):
        ref = budgets.get(name)
        if ref is None:
            out.append(Finding(name, "budget",
                               "no budget entry (run audit --update)",
                               severity))
            continue
        for metric in METRICS:
            got, want = measured[name][metric], float(ref.get(metric, 0.0))
            tol = rtol * max(abs(want), 1.0)
            if abs(got - want) > tol:
                out.append(Finding(
                    name, "budget",
                    f"{metric} drifted: measured {got:.6g}, budget "
                    f"{want:.6g} (|delta| {abs(got - want):.6g} > "
                    f"{rtol:.0%} band {tol:.6g})", severity))
    stale = sorted(set(budgets) - set(measured)) if complete else []
    if stale:
        out.append(Finding("*", "budget",
                           f"stale budget entries (cases gone): {stale}",
                           "note"))
    return out
