"""Static program analysis for the HFL reproduction.

Audits the *lowered* programs -- jaxpr + optimized HLO of every
representative :class:`repro.api.ExperimentSpec`, obtained through
``Engine.lower_chunk`` without executing a round -- plus an AST lint of
the source tree's PRNG key discipline. Front door:
``python -m repro.launch.audit``.

Submodules: :mod:`specs` (the audited case matrix), :mod:`invariants`
(donation / host-sync / f64 / correction-dtype / fusion / retrace),
:mod:`keys` (key-discipline lint), :mod:`budgets` (compiled-cost bands).
"""
from repro.analysis.invariants import Finding  # noqa: F401
from repro.analysis.keys import KeyFinding  # noqa: F401
from repro.analysis.specs import AuditCase, audit_cases  # noqa: F401
