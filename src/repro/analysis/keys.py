"""AST lint for jax PRNG key discipline.

The whole reproduction leans on one rng contract (``core.participation``
round_masks, the fault draws, the cohort sampler, the driver's shard
selection): *every* key is consumed exactly once -- you either ``split``
it (consuming it, yielding fresh keys) or ``fold_in`` static data (a
derivation that leaves the parent usable) -- and host ``numpy.random``
never appears inside traced code, where it would bake one draw into the
compiled program. PRs 1/6/7/8 each re-proved this by hand; this module
is the static form.

Rules (findings carry the rule name):

* ``key-reuse`` -- a key expression is passed to a consuming
  ``jax.random`` function (``split``, ``normal``, ``randint``, ...) after
  already having been consumed on a reaching path in the same scope.
  ``fold_in`` and the key constructors (``PRNGKey``/``key``/...) do not
  consume; rebinding a name (``mkey, rng = split(rng)``) resets it.
  Loop bodies are analyzed twice, so consuming a loop-invariant key
  inside a ``for``/``while``/comprehension is caught as second-iteration
  reuse.
* ``host-random`` -- a ``numpy.random.*`` module-level call (the global
  stream: ``np.random.normal`` etc.) inside a function that also touches
  ``jax.numpy``/``jax.lax``. Explicit ``np.random.default_rng`` /
  ``Generator`` objects are host-side by construction and fine.

False-positive escape hatch: append ``# key-ok: <reason>`` to the
flagged line. The audit CLI requires *zero unsuppressed findings* over
``src/``, ``examples/`` and ``benchmarks/``.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

SUPPRESS_MARK = "# key-ok"

# jax.random.* that mint or derive keys without consuming the argument.
KEY_CONSTRUCTORS = frozenset({
    "PRNGKey", "key", "key_data", "wrap_key_data", "clone", "key_impl",
})
NON_CONSUMING = KEY_CONSTRUCTORS | {"fold_in"}


@dataclasses.dataclass(frozen=True)
class KeyFinding:
    path: str
    line: int
    rule: str  # "key-reuse" | "host-random"
    message: str
    suppressed: bool = False

    def __str__(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


def _dotted(node: ast.AST) -> str | None:
    """`a.b.c` -> "a.b.c" for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Aliases(ast.NodeVisitor):
    """Module-level import aliases for jax / jax.random / numpy."""

    def __init__(self):
        self.jax: set[str] = set()
        self.jax_random: set[str] = set()
        self.numpy: set[str] = set()
        self.direct: dict[str, str] = {}  # local name -> jax.random fn

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            name, bound = a.name, a.asname or a.name.split(".")[0]
            if name == "jax":
                self.jax.add(bound)
            elif name == "jax.random":
                # `import jax.random` binds "jax"; with asname it binds
                # the submodule.
                (self.jax_random if a.asname else self.jax).add(bound)
            elif name == "numpy":
                self.numpy.add(bound)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "jax":
            for a in node.names:
                if a.name == "random":
                    self.jax_random.add(a.asname or a.name)
        elif node.module == "jax.random":
            for a in node.names:
                self.direct[a.asname or a.name] = a.name
        elif node.module == "numpy":
            for a in node.names:
                if a.name == "random":
                    self.numpy.add(a.asname or "random")  # numpy.random alias

    def random_fn(self, call: ast.Call) -> str | None:
        """The jax.random function name this call invokes, if any."""
        f = call.func
        if isinstance(f, ast.Name):
            return self.direct.get(f.id)
        chain = _dotted(f)
        if chain is None:
            return None
        parts = chain.split(".")
        # jr.split -- jr aliases jax.random (import ... as / from jax import)
        if len(parts) == 2 and parts[0] in self.jax_random:
            return parts[1]
        # jax.random.split -- any alias of the jax module
        if len(parts) == 3 and parts[0] in self.jax and parts[1] == "random":
            return parts[2]
        return None

    def host_random_fn(self, call: ast.Call) -> str | None:
        chain = _dotted(call.func)
        if chain is None:
            return None
        parts = chain.split(".")
        if len(parts) == 3 and parts[0] in self.numpy and parts[1] == "random":
            return parts[2]
        return None


class _ScopeLint:
    """Consumed-key dataflow over one function (or module) body.

    Branches fork the consumed set and merge by union; loop bodies run
    twice so loop-carried reuse surfaces. Precision over soundness: a key
    smuggled through a container or a helper call is not tracked -- the
    goal is catching the overwhelmingly common direct-reuse shape, not
    proving the program correct.
    """

    def __init__(self, lint: "KeyLint"):
        self.lint = lint
        self.consumed: dict[str, int] = {}  # key expr -> line consumed

    # ---- dataflow ----------------------------------------------------
    def _kill(self, target: ast.AST):
        name = _dotted(target)
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._kill(elt)
            return
        if isinstance(target, ast.Starred):
            self._kill(target.value)
            return
        if name is None:
            return
        prefix = name + "."
        for k in [k for k in self.consumed
                  if k == name or k.startswith(prefix)]:
            del self.consumed[k]

    def _consume(self, arg: ast.AST, fn: str, line: int):
        expr = _dotted(arg)
        if expr is None:
            return  # fresh subexpression (split(...)[0], fold_in(...)...)
        prev = self.consumed.get(expr)
        if prev is not None:
            self.lint._emit(line, "key-reuse",
                            f"key `{expr}` consumed by jax.random.{fn} was "
                            f"already consumed at line {prev}")
        else:
            self.consumed[expr] = line

    # ---- statement walk ----------------------------------------------
    def run(self, body: list[ast.stmt]):
        for stmt in body:
            self.stmt(stmt)

    def stmt(self, node: ast.stmt):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                self.expr(dec)
            self.lint._lint_scope(node.body)
            return
        if isinstance(node, ast.ClassDef):
            self.lint._lint_scope(node.body)
            return
        if isinstance(node, (ast.If,)):
            self.expr(node.test)
            self._fork(node.body, node.orelse)
            return
        if isinstance(node, ast.Try):
            branches = [node.body + node.orelse] + \
                [h.body for h in node.handlers]
            self._fork(*branches)
            for stmt in node.finalbody:
                self.stmt(stmt)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self.expr(node.iter)
            self._loop([node.target], node.body)
            for stmt in node.orelse:
                self.stmt(stmt)
            return
        if isinstance(node, ast.While):
            self.expr(node.test)
            self._loop([], node.body)
            for stmt in node.orelse:
                self.stmt(stmt)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                self.expr(item.context_expr)
                if item.optional_vars is not None:
                    self._kill(item.optional_vars)
            self.run(node.body)
            return
        # plain statement: visit expressions, then apply kills
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.expr(child)
        if isinstance(node, ast.Assign):
            for t in node.targets:
                self._kill(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            self._kill(node.target)

    def _fork(self, *branches: list[ast.stmt]):
        base = dict(self.consumed)
        merged: dict[str, int] = dict(base)
        for body in branches:
            self.consumed = dict(base)
            self.run(body)
            merged.update(self.consumed)
        self.consumed = merged

    def _loop(self, targets: list[ast.AST], body: list[ast.stmt]):
        # Two passes: pass 2 sees pass 1's consumptions, so consuming a
        # loop-invariant key flags as reuse -- while keys rebound by the
        # loop target (``for k in keys``) reset every iteration. Findings
        # dedup on (line, rule), so straight-line reuse inside the body
        # does not double-report.
        for _ in range(2):
            for target in targets:
                self._kill(target)
            self.run(body)

    # ---- expression walk ---------------------------------------------
    def expr(self, node: ast.expr):
        if isinstance(node, ast.Lambda):
            # Separate scope; its body only runs when called, so key flow
            # does not join this scope's.
            self.lint._lint_scope([ast.Expr(value=node.body)])
            return
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            self._comprehension(node)
            return
        if isinstance(node, ast.Call):
            self._call(node)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.expr(child)
            elif isinstance(child, ast.keyword):
                self.expr(child.value)

    def _comprehension(self, node):
        for gen in node.generators:
            self.expr(gen.iter)  # evaluated once, outside the loop
        elts = ([node.key, node.value] if isinstance(node, ast.DictComp)
                else [node.elt])
        conds = [c for gen in node.generators for c in gen.ifs]
        body = [ast.Expr(value=e) for e in elts + conds]
        self._loop([gen.target for gen in node.generators], body)

    def _call(self, node: ast.Call):
        fn = self.lint.aliases.random_fn(node)
        if fn is not None and fn not in NON_CONSUMING and node.args:
            self._consume(node.args[0], fn, node.lineno)
            return
        host = self.lint.aliases.host_random_fn(node)
        if host is not None and host not in ("default_rng", "Generator",
                                             "SeedSequence", "RandomState"):
            if self.lint.traced_scope:
                self.lint._emit(
                    node.lineno, "host-random",
                    f"numpy.random.{host} (host global stream) inside a "
                    "function that uses jax.numpy -- a traced call bakes "
                    "one draw into the compiled program")


class KeyLint:
    """Lint one python source file; collect findings."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.aliases = _Aliases()
        self.aliases.visit(self.tree)
        self.findings: list[KeyFinding] = []
        self._seen: set[tuple[int, str]] = set()
        self.traced_scope = False
        self._scope_stack: list[list[ast.stmt]] = []

    def _emit(self, line: int, rule: str, message: str):
        if (line, rule) in self._seen:
            return
        self._seen.add((line, rule))
        text = self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        suppressed = SUPPRESS_MARK in text
        self.findings.append(KeyFinding(self.path, line, rule, message,
                                        suppressed))

    def _scope_uses_jnp(self, body: list[ast.stmt]) -> bool:
        markers = {"jnp", "lax"} | self.aliases.jax | self.aliases.jax_random
        for stmt in body:
            for sub in ast.walk(stmt):
                chain = _dotted(sub) if isinstance(sub, ast.Attribute) else None
                if chain and chain.split(".")[0] in markers:
                    return True
        return False

    def _lint_scope(self, body: list[ast.stmt]):
        outer = self.traced_scope
        self.traced_scope = self._scope_uses_jnp(body)
        _ScopeLint(self).run(body)
        self.traced_scope = outer

    def run(self) -> list[KeyFinding]:
        self._lint_scope(self.tree.body)
        return self.findings


def lint_source(source: str, path: str = "<string>") -> list[KeyFinding]:
    return KeyLint(path, source).run()


def lint_file(path: Path | str) -> list[KeyFinding]:
    p = Path(path)
    try:
        return lint_source(p.read_text(), str(p))
    except SyntaxError as e:
        return [KeyFinding(str(p), e.lineno or 0, "parse-error", str(e))]


def lint_paths(roots: list[Path | str]) -> list[KeyFinding]:
    """Lint every ``.py`` under the given files/directories."""
    out: list[KeyFinding] = []
    for root in roots:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            out.extend(lint_file(f))
    return out


def unsuppressed(findings: list[KeyFinding]) -> list[KeyFinding]:
    return [f for f in findings if not f.suppressed]
