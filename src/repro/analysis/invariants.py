"""Structural invariants of the lowered driver chunk, checked statically.

Every check here consumes a :class:`repro.core.driver.LoweredChunk` --
the AOT trace of ``run_chunk(state, data, eval_mask)`` over abstract
inputs -- and returns :class:`Finding`s instead of raising, so the audit
CLI can report the whole matrix in one run:

* **donation** -- every leaf of the donated state argument must appear in
  the compiled executable's ``input_output_alias`` table. The state is
  argument 0 of ``run_chunk``, so its flattened leaves are exactly
  parameters ``0 .. n_leaves-1`` of the entry computation; a leaf missing
  from the alias table means XLA kept an extra parameter-sized copy live
  across the chunk (the regression the PR 3 donation win guards against).
* **host-sync** -- no host callback / infeed / outfeed primitive inside a
  ``while``/``scan``/``cond`` body: one host round-trip per scanned round
  serializes the whole async dispatch pipeline.
* **f64** -- no double-precision anywhere in the optimized HLO. jax
  disables x64 by default, but a stray numpy scalar in a weak-typed
  position can still promote through, doubling state bytes silently.
* **correction dtype** -- ``spec.correction_dtype`` honored end-to-end:
  the correction leaves (``z``/``y``) of both the abstract *input* state
  and the traced *output* state carry the narrow dtype, so a cast back to
  f32 anywhere in the round cannot round-trip unnoticed.
* **fusion contract** -- a fused spec lowers to exactly the expected
  ``pallas_call`` count in the jaxpr (one per correction buffer); an
  unfused spec lowers to exactly zero.
"""
from __future__ import annotations

import dataclasses
import re

import jax

# Primitives that force a device->host->device round trip when they
# appear inside a compiled loop body.
HOST_SYNC_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})

# jax prints the alias table on the HloModule header line:
#   input_output_alias={ {0}: (0, {}, may-alias), ... }, entry_computation...
# Entries nest braces ({output_index}: (param, {tuple_index}, kind)), so the
# table is extracted by brace matching, not regex.
_ALIAS_PARAM_RE = re.compile(r"\(\s*(\d+)\s*,")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One audit violation (or skip note, when ``severity == "note"``)."""

    case: str
    check: str
    message: str
    severity: str = "error"

    def __str__(self) -> str:
        return f"[{self.case}] {self.check}: {self.message}"


def iter_jaxprs(jaxpr, _inside_loop: bool = False):
    """Yield ``(eqn, inside_loop)`` over a jaxpr and all sub-jaxprs.

    ``inside_loop`` is True once the walk has descended through a
    ``while``/``scan``/``cond`` body (anything re-executed or branch-
    selected at runtime).
    """
    for eqn in jaxpr.eqns:
        yield eqn, _inside_loop
        inside = _inside_loop or eqn.primitive.name in (
            "while", "scan", "cond")
        for sub in _sub_jaxprs(eqn):
            yield from iter_jaxprs(sub, inside)


def _sub_jaxprs(eqn):
    for val in eqn.params.values():
        for item in (val if isinstance(val, (list, tuple)) else (val,)):
            inner = getattr(item, "jaxpr", None)
            if inner is not None:
                yield inner
            elif hasattr(item, "eqns"):
                yield item


def count_primitive(jaxpr, name: str) -> int:
    return sum(1 for eqn, _ in iter_jaxprs(jaxpr)
               if eqn.primitive.name == name)


def aliased_parameters(hlo: str) -> set[int]:
    """Entry-parameter numbers appearing in the input_output_alias table."""
    for line in hlo.splitlines():
        start = line.find("input_output_alias={")
        if start < 0:
            continue
        i = line.index("{", start)
        depth = 0
        for j in range(i, len(line)):
            depth += line[j] == "{"
            depth -= line[j] == "}"
            if depth == 0:
                body = line[i + 1: j]
                return {int(p) for p in _ALIAS_PARAM_RE.findall(body)}
    return set()


def check_donation(case: str, lc) -> list[Finding]:
    """Every donated state leaf must alias an output buffer."""
    if not lc.donate:
        return [Finding(case, "donation",
                        "runner traced with donate=False", "note")]
    n_state = len(jax.tree.leaves(lc.state))
    aliased = aliased_parameters(lc.hlo)
    missing = sorted(set(range(n_state)) - aliased)
    if not missing:
        return []
    leaves = jax.tree.leaves(lc.state)
    descr = ", ".join(
        f"param {i} ({leaves[i].dtype}{list(leaves[i].shape)})"
        for i in missing)
    return [Finding(case, "donation",
                    f"{len(missing)}/{n_state} donated state leaves have no "
                    f"input-output alias: {descr}")]


def check_host_sync(case: str, lc) -> list[Finding]:
    """No callback/infeed/outfeed primitive inside a loop body."""
    out = []
    for eqn, inside in iter_jaxprs(lc.jaxpr):
        if inside and eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            out.append(Finding(
                case, "host-sync",
                f"{eqn.primitive.name} inside a compiled loop body "
                "(one host round-trip per scanned round)"))
    return out


def check_no_f64(case: str, lc) -> list[Finding]:
    """No f64/c128 in the optimized HLO (jaxpr checked too, for location)."""
    out = []
    for eqn, _ in iter_jaxprs(lc.jaxpr):
        for var in eqn.outvars:
            dt = getattr(getattr(var, "aval", None), "dtype", None)
            if dt is not None and str(dt) in ("float64", "complex128"):
                out.append(Finding(
                    case, "f64",
                    f"primitive {eqn.primitive.name} produces {dt}"))
    hits = len(re.findall(r"\bf64\[", lc.hlo))
    hits += len(re.findall(r"\bc128\[", lc.hlo))
    if hits and not out:
        out.append(Finding(case, "f64",
                           f"{hits} f64/c128 shapes in optimized HLO"))
    return out


def _correction_leaves(state) -> list:
    picked = [getattr(state, f) for f in ("z", "y")
              if getattr(state, f, None) is not None]
    return jax.tree.leaves(picked)


def check_correction_dtype(case: str, lc, spec) -> list[Finding]:
    """z/y leaves carry ``spec.correction_dtype`` on the way in AND out."""
    want = spec.correction_dtype
    if want is None:
        return []
    out = []
    for side, state in (("input", lc.state), ("output", lc.out_state)):
        leaves = _correction_leaves(state)
        if not leaves:
            out.append(Finding(case, "correction-dtype",
                               f"{side} state has no z/y leaves to check"))
            continue
        bad = sorted({str(x.dtype) for x in leaves if str(x.dtype) != want})
        if bad:
            out.append(Finding(
                case, "correction-dtype",
                f"{side} state z/y leaves are {bad}, spec says {want!r}"))
    return out


def check_fusion(case: str, lc, expected: int) -> list[Finding]:
    """Exactly ``expected`` pallas_call sites in the traced jaxpr."""
    got = count_primitive(lc.jaxpr, "pallas_call")
    if got == expected:
        return []
    kind = "fused" if expected else "unfused"
    return [Finding(case, "fusion",
                    f"{kind} spec lowered to {got} pallas_call sites, "
                    f"expected {expected}")]


def check_retrace(case: str, engine, state, data, chunk: int = 2) -> list[Finding]:
    """Tracing the chunk runner twice over identical abstract shapes must
    hit the jit tracing cache the second time -- a miss means something in
    the round closure defeats caching (unhashable static arg, fresh
    closure identity per call) and every driver chunk would re-trace.
    """
    try:
        from jax._src import test_util as jtu
        counter = jtu.count_jit_tracing_cache_miss
    except ImportError:  # internal API moved: degrade to a note, not a pass
        return [Finding(case, "retrace",
                        "jax internal tracing-cache counter unavailable on "
                        "this jax version; retrace gate skipped", "note")]
    engine.lower_chunk(data, state=state, chunk=chunk, compile=False)  # warm
    with counter() as misses:
        engine.lower_chunk(data, state=state, chunk=chunk, compile=False)
    n = misses[0] if misses else 0
    if n == 0:
        return []
    return [Finding(case, "retrace",
                    f"identical abstract re-trace missed the jit tracing "
                    f"cache {n} times (expected 0)")]


def run_invariants(case, lc) -> list[Finding]:
    """All per-program invariant checks for one audited case."""
    out = []
    out += check_donation(case.name, lc)
    out += check_host_sync(case.name, lc)
    out += check_no_f64(case.name, lc)
    out += check_correction_dtype(case.name, lc, case.spec)
    out += check_fusion(case.name, lc, case.fused_leaves)
    return out
