"""repro.optim -- minimal functional optimizers (paper uses plain SGD)."""
from repro.optim.optimizers import Optimizer, adamw, sgd
from repro.optim.schedule import constant, cosine, linear_warmup_cosine

__all__ = ["Optimizer", "sgd", "adamw", "constant", "cosine", "linear_warmup_cosine"]
