"""Functional optimizers (init/update pairs over pytrees).

The paper's experiments use plain SGD (lr 0.1, no momentum); the production
LM training path uses AdamW. Kept dependency-free (no optax in container).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def sgd(lr: float | Callable[[jax.Array], jax.Array], momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
            return new, state
        state = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        new = jax.tree.map(lambda p, m: p - lr_t * m, params, state)
        return new, state

    return Optimizer(init, update)


def adamw(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        lr_t = lr_fn(step)

        def upd(p, mi, vi):
            upd_ = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            return (p - lr_t * (upd_ + weight_decay * p)).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)
