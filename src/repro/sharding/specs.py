"""PartitionSpec rules for every parameter / batch / cache leaf.

Rules are path-based (Megatron/MaxText-style logical axis rules):

* "in"-projections  (wq/wk/wv/wi/wg/win/wdt/wb/wc, embed)  shard their
  output dim over ``model`` and the d_model dim over ``fsdp``;
* "out"-projections (wo/wout, cmix wv) shard the contracting dim over
  ``model`` (the all-reduce after them is the Megatron pattern);
* MoE expert stacks [L, E, D, F] shard (D->fsdp, F->model) at train and
  (D->data, F->model) at serve (mixtral's 282 GB does not fit model-only);
* vectors / norms / token-shift mixes are replicated.

An axis is only assigned when the dim is divisible by the axis size --
otherwise it is dropped (replicated on that axis). Vocab dims are padded to
a multiple of 512 by the model (``ArchConfig.vocab_padded``) so embedding /
unembedding shard cleanly.

Training state is stacked: params/z get ("group", "client") prepended,
y gets ("group",). Batches shard [E,H,A,G,K,chunk,T] over
(group, client, fsdp) -- grad-accumulation chunks stay local to a client.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig

PyTree = Any

# Leaf names whose 2-D matmul weight is an out-projection (contracting dim
# is the sharded "feature" dim; Megatron row-parallel).
_OUT_PROJ = ("wo", "wout")

_REPLICATED_NAMES = (
    "mix", "u", "decay_base", "d_skip", "log_a", "enc_pos",
    "ln1", "ln2", "ln_x", "ln_f", "ln_out", "q_norm", "k_norm",
    "scale", "bias", "b",
)


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
        else:
            out.append(str(e))
    return out


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _axis(dim: int, name, size: int):
    return name if _div(dim, size) else None


def _size_of(name, axis_sizes: dict) -> int:
    """Axis size; ``name`` may be a tuple of mesh axes (product)."""
    if name is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(name, 1)


def param_pspec(
    path, shape: tuple[int, ...], *, axis_sizes: dict[str, int],
    model: str = "model", fsdp: str | None = "fsdp", cfg: ArchConfig | None = None,
    attn_model=None,
) -> P:
    """PartitionSpec for one (unstacked) parameter leaf.

    ``model`` may be a tuple of axes (serve meshes split it into (kv, tp));
    ``attn_model`` overrides the axis used for attention head dims (serve:
    just "kv", so head sharding aligns with the head-sharded cache).
    """
    names = _path_names(path)
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    stacked = "layers" in names or "encoder" in names
    tail = shape[1:] if stacked else shape
    msz = _size_of(model, axis_sizes)
    fsz = _size_of(fsdp, axis_sizes)
    attn_model = attn_model if attn_model is not None else model
    asz = _size_of(attn_model, axis_sizes)

    def out(*tail_spec):
        lead = (None,) if stacked else ()
        return P(*(lead + tail_spec))

    if leaf in _REPLICATED_NAMES or parent in _REPLICATED_NAMES or len(tail) <= 1:
        return out(*(None,) * len(tail))

    if parent == "embed" and leaf == "table":            # [V, D]
        # never shard the gathered (vocab) dim: SPMD would fully
        # rematerialize the table at every lookup.
        return out(None, _axis(tail[1], fsdp, fsz))

    # attention projections reshape to [.., heads, d_head]: only shard the
    # head dim when whole heads land on each model shard, else SPMD inserts
    # a full reshard around every reshape.
    if cfg is not None and parent in ("wq", "wk", "wv", "wo") and (
        "attn" in names or "xattn" in names
    ):
        n_h = cfg.num_heads if parent in ("wq", "wo") else cfg.num_kv_heads
        heads_ok = asz > 1 and n_h % asz == 0
        if parent == "wo":  # row-parallel [H*Dh, D]
            return out(_axis(tail[0], attn_model, asz) if heads_ok else None,
                       _axis(tail[1], fsdp, fsz))
        return out(_axis(tail[0], fsdp, fsz),
                   _axis(tail[1], attn_model, asz) if heads_ok else None)
    if parent == "unembed":                              # [D, V]
        return out(_axis(tail[0], fsdp, fsz), _axis(tail[1], model, msz))
    if parent == "moe" and len(tail) == 3:               # [E, D, F] / [E, F, D]
        # Expert parallelism: when the expert count divides the fsdp axis,
        # shard EXPERTS over it (each shard owns whole experts; the dispatch
        # einsums route tokens via a small all-to-all/partial-reduce) instead
        # of sharding d_model (which all-reduces the full [E, C, D] dispatch
        # buffers after every contraction -- the dominant train collective
        # for mixtral; Perf iteration, EXPERIMENTS.md §Perf).
        import os
        if _div(tail[0], fsz) and os.environ.get("REPRO_MOE_EP", "1") != "0":
            if leaf == "wo":
                return out(fsdp, _axis(tail[1], model, msz), None)
            return out(fsdp, None, _axis(tail[2], model, msz))
        if leaf == "wo":
            return out(None, _axis(tail[1], model, msz), _axis(tail[2], fsdp, fsz))
        return out(None, _axis(tail[1], fsdp, fsz), _axis(tail[2], model, msz))

    if len(tail) == 2:
        if parent in _OUT_PROJ or (parent == "cmix" and leaf == "w"):
            # row-parallel: contract over model-sharded dim
            return out(_axis(tail[0], model, msz), _axis(tail[1], fsdp, fsz))
        if leaf == "w" and names[-2] == "wv" and "cmix" in names:  # [F, D]
            return out(_axis(tail[0], model, msz), _axis(tail[1], fsdp, fsz))
        # column-parallel default: [d_model, out]
        return out(_axis(tail[0], fsdp, fsz), _axis(tail[1], model, msz))

    return out(*(None,) * len(tail))


def param_spec_tree(
    params_shape: PyTree, *, axis_sizes, model="model", fsdp="fsdp", lead: tuple = (),
    cfg: ArchConfig | None = None, attn_model=None,
) -> PyTree:
    """Tree of PartitionSpecs; ``lead`` prepends FL topology axes."""

    def f(path, leaf):
        # ``params_shape`` leaves are UNstacked; ``lead`` only prefixes the
        # emitted spec (the stacked state adds those axes separately).
        spec = param_pspec(path, leaf.shape, axis_sizes=axis_sizes,
                           model=model, fsdp=fsdp, cfg=cfg,
                           attn_model=attn_model)
        return P(*(lead + tuple(spec)))

    return jax.tree_util.tree_map_with_path(f, params_shape)


def with_lead(params_shape: PyTree, lead_shape: tuple) -> PyTree:
    """ShapeDtypeStructs with FL topology axes prepended (for eval_shape)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead_shape + s.shape, s.dtype), params_shape
    )


def train_state_specs(params_shape: PyTree, axis_sizes: dict,
                      cfg: ArchConfig | None = None) -> dict:
    """PartitionSpecs for HFLTrainState(params, z, y) stacked trees."""
    gk = ("group", "client")
    kw = dict(axis_sizes=axis_sizes, cfg=cfg)
    return {
        "params": param_spec_tree(params_shape, lead=gk, **kw),
        "z": param_spec_tree(params_shape, lead=gk, **kw),
        "y": param_spec_tree(params_shape, lead=("group",), **kw),
    }


def train_batch_spec(batch_specs: PyTree) -> PyTree:
    """[E, H, A, G, K, chunk, ...] -> (group, client, fsdp) on axes 3..5."""

    def f(leaf):
        tail = (None,) * (len(leaf.shape) - 6)
        return P(None, None, None, "group", "client", "fsdp", *tail)

    return jax.tree.map(f, batch_specs)


# ------------------------------------------------------------------ serve


def serve_param_specs(cfg: ArchConfig, params_shape: PyTree, axis_sizes: dict) -> PyTree:
    """Single-copy serving params: model-parallel only; MoE experts also
    shard d_model over the ``data`` axis (fits mixtral in HBM).

    On kv-split serve meshes (axes data/kv/tp) the tensor-parallel axis is
    the combined ("kv", "tp") pair, while attention head dims shard over
    just "kv" -- aligned with the head-sharded cache."""
    kv_mesh = "kv" in axis_sizes
    model = ("kv", "tp") if kv_mesh else "model"
    attn_model = "kv" if kv_mesh else None
    fsdp = "data" if cfg.num_experts else None
    tree = param_spec_tree(params_shape, axis_sizes=axis_sizes, model=model,
                           fsdp=fsdp, cfg=cfg, attn_model=attn_model)
    if cfg.num_experts:
        # only the 3-D expert stacks keep the data-axis factor; everything
        # else stays replicated over data (decode re-reads weights per token,
        # so gathering non-expert weights every step would dominate).
        def fix(path, spec, leaf):
            names = _path_names(path)
            if "moe" in names and len(leaf.shape) == 4:
                return spec
            return P(*(s if s != "data" else None for s in spec))

        tree = jax.tree_util.tree_map_with_path(fix, tree, params_shape)
    return tree


def serve_data_axes(mesh: Mesh) -> tuple:
    """Batch-bearing axes of the serving mesh (('pod','data') when present)."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "kv", "tp"))


def serve_cache_specs(cfg: ArchConfig, cache_shape: PyTree, shape_id: str, mesh: Mesh) -> PyTree:
    """KV/recurrent cache specs. decode_32k shards batch over data and kv
    heads over model; long_500k (batch=1) shards the *sequence* over data."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data = serve_data_axes(mesh)
    dsz = 1
    for a in data:
        dsz *= axis_sizes[a]
    msz = axis_sizes.get("model", 1)

    kv_mesh = "kv" in axis_sizes
    head_ax = "kv" if kv_mesh else "model"
    hsz = axis_sizes.get(head_ax, 1)

    def f(path, leaf):
        names = _path_names(path)
        shp = leaf.shape
        if names[-1] in ("k", "v"):                 # [L, B, S, kv, Dh]
            if shape_id == "long_500k":
                return P(None, None, _axis(shp[2], data, dsz), _axis(shp[3], head_ax, hsz), None)
            # batch over data; kv heads over their own axis (kv-split mesh)
            # or the model axis. Sequence-sharding is the last resort: the
            # one-token cache write then rewrites whole shards per layer.
            if _div(shp[3], hsz):
                return P(None, _axis(shp[1], data, dsz), None, head_ax, None)
            return P(None, _axis(shp[1], data, dsz), _axis(shp[2], head_ax, hsz), None, None)
        if names[-1] == "state":                    # rwkv [L, B, H, dh, dh]
            return P(None, _axis(shp[1], data, dsz), _axis(shp[2], head_ax, hsz), None, None)
        if names[-1] == "sstate":                   # hymba [L, B, Di, S]
            return P(None, _axis(shp[1], data, dsz), _axis(shp[2], head_ax, hsz), None)
        if names[-1] in ("x_prev", "ffn_prev"):     # [L, B, D]
            return P(None, _axis(shp[1], data, dsz), None)
        return P(*(None,) * len(shp))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def serve_batch_specs(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    data = serve_data_axes(mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsz = 1
    for a in data:
        dsz *= axis_sizes[a]

    def f(leaf):
        if not leaf.shape:
            return P()
        b = _axis(leaf.shape[0], data, dsz)
        return P(b, *(None,) * (len(leaf.shape) - 1))

    return jax.tree.map(f, batch_shape)


def to_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
