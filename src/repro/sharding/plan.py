"""Per-architecture distribution plans: how an arch factors the pinned
physical production mesh into the logical HFL training mesh.

The physical meshes are fixed (launch/mesh.py):
    single-pod : (16, 16)        axes ("data", "model")
    multi-pod  : (2, 16, 16)     axes ("pod", "data", "model")

Training re-factors the same 256/512 devices into the logical axes

    (group, client, fsdp, model)   with  G*K*F*M == #chips

* ``group``/``client`` carry the paper's HFL topology: MTGC's group
  aggregation is an all-reduce over ``client``; global aggregation is an
  all-reduce over ``group`` (x ``pod`` in the multi-pod case -- pods are
  groups, so inter-group non-i.i.d. rides the slow inter-pod links).
* ``fsdp`` ZeRO-3-shards each client's replica; ``model`` is Megatron-style
  tensor parallelism. Both are *inside* a client submesh.

Serving uses the physical ("data", "model") axes directly (no FL topology).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """How one architecture maps onto the production meshes.

    train_factors: (G, K, F, M) for the 256-chip pod. On the 2-pod mesh the
        pod axis multiplies G (2 pods => 2*G groups).
    microbatch:    per-device microbatch for train_4k (grad-accumulated over
        the per-client batch 256/(G*K) split across F).
    dryrun_E/H:    group rounds / local steps baked into the dry-run round
        (scans -- HLO size is independent of these; FLOPs scale linearly).
    """

    train_factors: tuple[int, int, int, int] = (4, 4, 1, 16)
    microbatch: int = 4
    dryrun_E: int = 2
    dryrun_H: int = 2

    def validate(self, chips: int = 256) -> "MeshPlan":
        g, k, f, m = self.train_factors
        assert g * k * f * m == chips, (self.train_factors, chips)
        return self

    @property
    def clients(self) -> int:
        g, k, _, _ = self.train_factors
        return g * k
