"""Synthetic datasets standing in for EMNIST/FMNIST/CIFAR/Shakespeare.

The container is offline, so we generate *statistically controlled*
classification and language data. The FL benchmarks only depend on the
partition protocol and relative algorithm behaviour (see DESIGN.md §2,
changed assumption 3), both of which are preserved:

* classification: a Gaussian-mixture over ``num_classes`` class prototypes
  with within-class covariance -- learnable by the paper's MLP/CNN models,
  and Dirichlet-partitionable by label exactly like CIFAR/EMNIST.
* image variant: prototypes are reshaped to HxWxC "images" so the CNN /
  ResNet paths exercise real conv stacks.
* language: an order-2 Markov token stream per latent "style" (stands in for
  Shakespeare characters); clients get style-skewed shards.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x: np.ndarray        # [n, ...] features (float32) or tokens (int32)
    y: np.ndarray        # [n] int labels (classification) or next-tokens
    num_classes: int


def make_classification(
    rng: np.random.Generator,
    num_samples: int = 20000,
    num_classes: int = 10,
    dim: int = 64,
    noise: float = 1.0,
    image_shape: tuple | None = None,
) -> Dataset:
    """Gaussian mixture classification data.

    ``image_shape=(H, W, C)`` reshapes features into images (H*W*C == dim).
    """
    protos = rng.normal(size=(num_classes, dim)).astype(np.float32)
    protos *= 2.0 / np.sqrt(dim) ** 0.5
    y = rng.integers(0, num_classes, size=(num_samples,))
    x = protos[y] + noise * rng.normal(size=(num_samples, dim)).astype(np.float32)
    x = x.astype(np.float32)
    if image_shape is not None:
        h, w, c = image_shape
        assert h * w * c == dim, (image_shape, dim)
        x = x.reshape(num_samples, h, w, c)
    return Dataset(x=x, y=y.astype(np.int32), num_classes=num_classes)


def make_feature_shift(ds: Dataset, rotations: np.ndarray, assignment: np.ndarray) -> Dataset:
    """Paper App. C feature shift: rotate (here: orthogonally mix) features of
    each sample according to its group's angle. ``rotations[g]`` in degrees,
    ``assignment[n]`` = group of sample n. Works on flat features."""
    x = ds.x.reshape(ds.x.shape[0], -1).copy()
    d = x.shape[1]
    for g in np.unique(assignment):
        theta = np.deg2rad(rotations[g])
        # Rotate in the first two principal coordinates (cheap proxy for
        # image rotation that produces the same train/test feature shift).
        c, s = np.cos(theta), np.sin(theta)
        sel = assignment == g
        x0, x1 = x[sel, 0].copy(), x[sel, 1].copy()
        x[sel, 0] = c * x0 - s * x1
        x[sel, 1] = s * x0 + c * x1
    return Dataset(x=x.reshape(ds.x.shape), y=ds.y, num_classes=ds.num_classes)


def make_language(
    rng: np.random.Generator,
    num_styles: int = 10,
    vocab: int = 64,
    samples_per_style: int = 300,
    seq_len: int = 80,
) -> tuple[Dataset, np.ndarray]:
    """Markov "Shakespeare": per-style transition matrices -> token sequences.

    Returns (dataset of [n, seq_len] int32 sequences with next-token targets
    [n, seq_len], style_of_sample[n]) -- styles play the role of labels for
    partitioning.
    """
    x = np.zeros((num_styles * samples_per_style, seq_len), np.int32)
    styles = np.zeros((num_styles * samples_per_style,), np.int32)
    for s in range(num_styles):
        # Sparse, style-specific transition structure.
        trans = rng.dirichlet(0.1 * np.ones(vocab), size=vocab).astype(np.float64)
        for i in range(samples_per_style):
            n = s * samples_per_style + i
            styles[n] = s
            tok = rng.integers(0, vocab)
            for t in range(seq_len):
                x[n, t] = tok
                tok = rng.choice(vocab, p=trans[tok])
    # next-token targets
    y = np.roll(x, -1, axis=1)
    y[:, -1] = x[:, -1]
    ds = Dataset(x=x, y=y, num_classes=vocab)
    return ds, styles


def train_test_split(ds: Dataset, rng: np.random.Generator, test_frac: float = 0.2):
    n = ds.x.shape[0]
    perm = rng.permutation(n)
    k = int(n * (1 - test_frac))
    tr, te = perm[:k], perm[k:]
    return (
        Dataset(ds.x[tr], ds.y[tr], ds.num_classes),
        Dataset(ds.x[te], ds.y[te], ds.num_classes),
    )
