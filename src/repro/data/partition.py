"""Dirichlet non-i.i.d. partitioning (paper Sec. 5.1 protocol, verbatim).

Three distribution settings:
  'group_iid'     -- group i.i.d. & client non-i.i.d.: dataset split uniformly
                     into N group segments, each segment Dirichlet-split over
                     its clients.
  'client_iid'    -- group non-i.i.d. & client i.i.d.: Dirichlet split over
                     groups, uniform split within each group.
  'both_noniid'   -- Dirichlet over groups, then Dirichlet over clients.
  'label_shift'   -- App. C: 3 classes per group, 2 per client.

Returns index arrays so the same dataset array is shared by all clients.
"""
from __future__ import annotations

import numpy as np


def _dirichlet_split(rng, labels, num_parts, alpha, idx_pool):
    """Split ``idx_pool`` into ``num_parts`` label-skewed parts (Dirichlet).

    Standard protocol [Acar et al. 2021]: for each class, split its samples
    among parts with proportions ~ Dir(alpha).
    """
    parts = [[] for _ in range(num_parts)]
    for c in np.unique(labels[idx_pool]):
        idx_c = idx_pool[labels[idx_pool] == c]
        rng.shuffle(idx_c)
        props = rng.dirichlet(alpha * np.ones(num_parts))
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for p, chunk in enumerate(np.split(idx_c, cuts)):
            parts[p].extend(chunk.tolist())
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]


def _uniform_split(rng, num_parts, idx_pool):
    idx = idx_pool.copy()
    rng.shuffle(idx)
    return [np.asarray(sorted(c), dtype=np.int64) for c in np.array_split(idx, num_parts)]


def partition(
    labels: np.ndarray,
    num_groups: int,
    clients_per_group: int,
    mode: str = "both_noniid",
    alpha: float = 0.1,
    seed: int = 0,
    min_per_client: int = 8,
) -> list[list[np.ndarray]]:
    """Returns indices[g][k] = sample indices of client k in group g."""
    rng = np.random.default_rng(seed)
    all_idx = np.arange(len(labels))

    for _attempt in range(50):
        if mode == "group_iid":
            groups = _uniform_split(rng, num_groups, all_idx)
            out = [_dirichlet_split(rng, labels, clients_per_group, alpha, g) for g in groups]
        elif mode == "client_iid":
            groups = _dirichlet_split(rng, labels, num_groups, alpha, all_idx)
            out = [_uniform_split(rng, clients_per_group, g) for g in groups]
        elif mode == "both_noniid":
            groups = _dirichlet_split(rng, labels, num_groups, alpha, all_idx)
            out = [_dirichlet_split(rng, labels, clients_per_group, alpha, g) for g in groups]
        elif mode == "label_shift":
            out = _label_shift(rng, labels, num_groups, clients_per_group)
        else:
            raise ValueError(f"unknown partition mode {mode!r}")
        if min(len(c) for g in out for c in g) >= min_per_client:
            return out
    raise RuntimeError("could not build a partition with enough samples/client")


def _label_shift(rng, labels, num_groups, clients_per_group,
                 classes_per_group=3, classes_per_client=2):
    """App. C label shift: assign 3 of C classes per group, 2 per client."""
    classes = np.unique(labels)
    out = []
    for _g in range(num_groups):
        gcls = rng.choice(classes, size=classes_per_group, replace=False)
        gidx = np.where(np.isin(labels, gcls))[0]
        clients = []
        for _k in range(clients_per_group):
            kcls = rng.choice(gcls, size=classes_per_client, replace=False)
            kidx = gidx[np.isin(labels[gidx], kcls)]
            # subsample so clients don't all share every sample
            take = max(len(kidx) // clients_per_group, 8)
            clients.append(np.sort(rng.choice(kidx, size=min(take, len(kidx)), replace=False)))
        out.append(clients)
    return out


def sample_round_batches(
    data_x: np.ndarray,
    data_y: np.ndarray,
    indices: list[list[np.ndarray]],
    rng: np.random.Generator,
    group_rounds: int,
    local_steps: int,
    batch_size: int,
    client_mask: np.ndarray | None = None,
):
    """Pre-sample one global round of batches: leaves [E, H, G, K, b, ...].

    (Pre-sampling keeps the round function purely functional; per-round host
    sampling mirrors an input pipeline feeding the jitted step.)

    ``client_mask`` ([G, K] 0/1, e.g. ``repro.core.round_masks(state.rng,
    cfg).client``) skips packing for inactive clients: their slots stay
    zero -- the engine freezes them anyway -- which drops host sampling work
    and host->device bytes by the non-participation fraction.
    """
    G, K = len(indices), len(indices[0])
    E, H, B = group_rounds, local_steps, batch_size
    bx = np.zeros((E, H, G, K, B) + data_x.shape[1:], data_x.dtype)
    by = np.zeros((E, H, G, K, B) + data_y.shape[1:], data_y.dtype)
    for g in range(G):
        for k in range(K):
            if client_mask is not None and not client_mask[g][k]:
                continue
            pool = indices[g][k]
            sel = rng.choice(pool, size=(E, H, B), replace=True)
            bx[:, :, g, k] = data_x[sel]
            by[:, :, g, k] = data_y[sel]
    return {"x": bx, "y": by}


def partition_stats(labels: np.ndarray, indices) -> dict:
    """Heterogeneity diagnostics used by tests and benchmark logs."""
    num_classes = int(labels.max()) + 1
    G = len(indices)
    gdist = []
    for g in range(G):
        gi = np.concatenate(indices[g])
        gdist.append(np.bincount(labels[gi], minlength=num_classes) / len(gi))
    gdist = np.stack(gdist)
    global_dist = gdist.mean(0)
    inter = float(np.abs(gdist - global_dist).sum(-1).mean())  # total variation
    intra = []
    for g in range(G):
        cd = np.stack(
            [np.bincount(labels[c], minlength=num_classes) / max(len(c), 1) for c in indices[g]]
        )
        intra.append(np.abs(cd - gdist[g]).sum(-1).mean())
    return {"inter_group_tv": inter, "intra_group_tv": float(np.mean(intra))}
