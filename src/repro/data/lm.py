"""Token-stream pipeline for the production LM training path.

Generates a deterministic pseudo-corpus (mixture of per-domain Markov chains)
and packs it into fixed-length training sequences. Domains play the role of
data heterogeneity for hierarchical training: each (group, client) shard
draws from a skewed mixture of domains, so multi-pod MTGC training sees real
inter-shard drift.
"""
from __future__ import annotations

import numpy as np


def make_lm_tokens(
    rng: np.random.Generator,
    vocab: int,
    num_tokens: int,
    num_domains: int = 8,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens[num_tokens] int32, domain_of_token[num_tokens])."""
    # Per-domain unigram-with-momentum generator: cheap, non-degenerate.
    protos = rng.dirichlet(0.05 * np.ones(vocab), size=num_domains)
    toks = np.zeros(num_tokens, np.int32)
    doms = np.zeros(num_tokens, np.int32)
    chunk = 2048
    pos = 0
    while pos < num_tokens:
        d = rng.integers(0, num_domains)
        n = min(chunk, num_tokens - pos)
        toks[pos : pos + n] = rng.choice(vocab, size=n, p=protos[d])
        doms[pos : pos + n] = d
        pos += n
    return toks, doms


def lm_batches(
    tokens: np.ndarray,
    rng: np.random.Generator,
    shape: tuple,  # (..., batch, seq_len) leading axes included
    seq_len: int,
):
    """Sample next-token-prediction batches: dict(tokens, targets) with the
    requested leading shape, e.g. (E, H, G, K, B, seq_len)."""
    n_seq = int(np.prod(shape))
    starts = rng.integers(0, len(tokens) - seq_len - 1, size=n_seq)
    x = np.stack([tokens[s : s + seq_len] for s in starts]).reshape(shape + (seq_len,))
    y = np.stack([tokens[s + 1 : s + seq_len + 1] for s in starts]).reshape(shape + (seq_len,))
    return {"tokens": x.astype(np.int32), "targets": y.astype(np.int32)}
