"""repro.data -- synthetic datasets + the paper's Dirichlet partitioner."""
from repro.data.lm import lm_batches, make_lm_tokens
from repro.data.partition import partition, partition_stats, sample_round_batches
from repro.data.synthetic import (
    Dataset,
    make_classification,
    make_feature_shift,
    make_language,
    train_test_split,
)

__all__ = [
    "Dataset",
    "make_classification",
    "make_feature_shift",
    "make_language",
    "train_test_split",
    "partition",
    "partition_stats",
    "sample_round_batches",
    "make_lm_tokens",
    "lm_batches",
]
