"""InternVL2-26B language backbone (InternLM2-20B-chat side) [arXiv:2404.16821].

The InternViT-6B vision tower is a stub per the assignment: ``input_specs``
feeds 256 pre-computed patch embeddings (pixel-shuffled tile tokens) of
width 3200 per sample; the MLP projector + decoder are implemented.
"""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    d_head=128,
    rope_base=1e6,
    vision_tokens=256,
    vision_dim=3200,
    source="InternVL2 [arXiv:2404.16821]; InternLM2-20B backbone",
)

PLAN = MeshPlan(train_factors=(2, 2, 4, 16), microbatch=2)
