"""Granite-3.0-1B-A400M sparse MoE: 32 experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    d_head=64,
    num_experts=32,
    top_k=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

PLAN = MeshPlan(train_factors=(8, 4, 1, 8), microbatch=4)
