"""Gemma-3-27B dense decoder [hf:google/gemma-3 family]:
5 local (SWA-1024) layers per 1 global layer, 128k context, huge vocab."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    d_head=128,
    rope_base=1e6,
    sliding_window=1024,
    local_global_ratio=5,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt card family (assignment)",
)

PLAN = MeshPlan(train_factors=(2, 2, 4, 16), microbatch=1)
