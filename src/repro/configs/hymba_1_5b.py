"""Hymba-1.5B hybrid-head decoder [arXiv:2411.13676].

Every block runs attention heads and Mamba(SSM) heads *in parallel* on the
same input and fuses (mean) their outputs. Attention heads use sliding
windows (the paper keeps only 3 global-attention layers and argues the SSM
path carries global context; we make all attention layers SWA-1024 so the
arch is sub-quadratic end-to-end -- noted in DESIGN.md). 25 heads / kv=5.
"""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    d_head=64,
    sliding_window=1024,
    ssm_state=16,
    ssm_d_inner=3200,
    source="Hymba [arXiv:2411.13676]",
)

PLAN = MeshPlan(train_factors=(8, 4, 1, 8), microbatch=2)
