"""Qwen2.5-32B dense decoder [hf:Qwen/Qwen2.5-* family]: GQA kv=8 + QKV bias."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    d_head=128,
    rope_base=1e6,
    qkv_bias=True,
    source="hf:Qwen/Qwen2.5 model card family (0.5B cited in assignment)",
)

PLAN = MeshPlan(train_factors=(2, 2, 8, 8), microbatch=1)
