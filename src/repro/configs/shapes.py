"""Assigned input shapes -> ShapeDtypeStruct stand-ins (no allocation).

SHAPES (assignment):
    train_4k     seq  4,096   global_batch 256   (training, one MTGC round)
    prefill_32k  seq 32,768   global_batch  32   (inference prefill)
    decode_32k   seq 32,768   global_batch 128   (one-token decode, 32k cache)
    long_500k    seq 524,288  global_batch   1   (long-context decode)

``train_specs`` shapes one *global round* of batches
``[E, H, A, G, K, chunk, T]``: E group rounds x H local steps x A
grad-accumulation chunks; ``chunk = microbatch * F`` samples live at once
per client (sharded over the client's fsdp submesh). ``serve_specs`` shapes
the request batch + KV/recurrent cache for the serve step.

Decode shapes lower ``decode_step`` (ONE new token against a full cache),
never ``train_step``. ``long_500k`` is only generated for sub-quadratic
archs (``cfg.sub_quadratic``); asking for it on a full-attention arch raises
``SkipShape`` which the dry-run records as an assignment-sanctioned skip.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


class SkipShape(Exception):
    """(arch, shape) pair excluded by the assignment's skip rules."""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _frontend_train(cfg: ArchConfig, lead, seq):
    """Stub-modality extras + the effective text length for VLM/audio."""
    extras = {}
    t_text = seq
    if cfg.arch_type == "vlm":
        t_text = seq - cfg.vision_tokens
        extras["patches"] = _sds(lead + (cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16)
    if cfg.arch_type == "audio":
        extras["frames"] = _sds(lead + (cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    return extras, t_text


def train_specs(cfg: ArchConfig, plan: MeshPlan, *, multi_pod: bool = False) -> dict:
    """Batch ShapeDtypeStructs for one MTGC global round of ``train_4k``."""
    s = SHAPES["train_4k"]
    G, K, F, M = plan.train_factors
    if multi_pod:
        G *= 2  # pods multiply the group axis; global batch stays pinned
    B_c = s["global_batch"] // (G * K)          # per-client batch per step
    chunk = min(plan.microbatch * F, B_c)       # live samples per client
    A = max(B_c // chunk, 1)                    # grad-accumulation steps
    E, H = plan.dryrun_E, plan.dryrun_H
    lead = (E, H, A, G, K, chunk)
    extras, t_text = _frontend_train(cfg, lead, s["seq_len"])
    return {
        "tokens": _sds(lead + (t_text,), jnp.int32),
        "targets": _sds(lead + (t_text,), jnp.int32),
        **extras,
    }


def serve_specs(cfg: ArchConfig, shape_id: str) -> dict[str, Any]:
    """Request batch + cache ShapeDtypeStructs for prefill/decode shapes."""
    s = SHAPES[shape_id]
    kind, B, S = s["kind"], s["global_batch"], s["seq_len"]
    if shape_id == "long_500k" and not cfg.sub_quadratic:
        raise SkipShape(
            f"{cfg.name}: pure full-attention arch; long_500k skipped per "
            "assignment (no sub-quadratic variant)"
        )
    dt = jnp.dtype(cfg.param_dtype)
    Lh = cfg.num_layers

    cache: dict[str, Any] = {}
    if cfg.arch_type != "ssm":
        kvshape = (Lh, B, S, cfg.num_kv_heads, cfg.d_head)
        cache["k"] = _sds(kvshape, dt)
        cache["v"] = _sds(kvshape, dt)
    if cfg.arch_type == "ssm":
        dh = cfg.d_model // cfg.num_heads
        cache["state"] = _sds((Lh, B, cfg.num_heads, dh, dh), jnp.float32)
        cache["x_prev"] = _sds((Lh, B, cfg.d_model), dt)
        cache["ffn_prev"] = _sds((Lh, B, cfg.d_model), dt)
    if cfg.arch_type == "hybrid":
        di = cfg.ssm_d_inner or cfg.d_model
        cache["sstate"] = _sds((Lh, B, di, cfg.ssm_state), jnp.float32)

    if kind == "prefill":
        t_text = S
        batch: dict[str, Any] = {}
        if cfg.arch_type == "vlm":
            t_text = S - cfg.vision_tokens
            batch["patches"] = _sds((B, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16)
        if cfg.arch_type == "audio":
            # serving: the (stubbed) encoder runs once at admission; the
            # prefill consumes its memory directly.
            batch["memory"] = _sds((B, cfg.encoder_frames, cfg.d_model), dt)
        batch["tokens"] = _sds((B, t_text), jnp.int32)
        return {"batch": batch, "cache": cache}

    batch = {"token": _sds((B, 1), jnp.int32), "index": _sds((), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["memory"] = _sds((B, cfg.encoder_frames, cfg.d_model), dt)
    return {"batch": batch, "cache": cache}


def param_specs(cfg: ArchConfig, bundle) -> Any:
    """ShapeDtypeStructs of the model parameters (via eval_shape; no alloc)."""
    return jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
