"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay
[arXiv:2404.05892]. 32 heads of 64 (time-mix state per head is 64x64)."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,   # unused (attention-free); kept for config uniformity
    d_ff=7168,
    vocab_size=65536,
    d_head=64,
    rwkv_chunk=64,
    source="RWKV-6 Finch [arXiv:2404.05892]",
)

PLAN = MeshPlan(train_factors=(8, 4, 1, 8), microbatch=2)
