"""Mixtral 8x22B: sparse MoE decoder, 8 experts top-2 [arXiv:2401.04088].

Per the assignment card the attention is sliding-window (Mistral-family
SWA, 4096); GQA kv=8.
"""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    d_head=128,
    rope_base=1e6,
    sliding_window=4096,
    num_experts=8,
    top_k=2,
    source="Mixtral of Experts [arXiv:2401.04088]",
)

PLAN = MeshPlan(train_factors=(2, 2, 4, 16), microbatch=1)
