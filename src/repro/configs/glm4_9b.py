"""GLM-4-9B dense decoder [hf:THUDM/glm-4-9b]: RoPE + aggressive GQA (kv=2)."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="glm4-9b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    d_head=128,
    rope_base=1e6,
    qkv_bias=True,
    source="hf:THUDM/glm-4-9b",
)

PLAN = MeshPlan(train_factors=(4, 2, 4, 8), microbatch=2)
