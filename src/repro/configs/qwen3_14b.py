"""Qwen3-14B dense decoder [hf:Qwen/Qwen3 family]: per-head qk-RMSNorm + GQA."""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    d_head=128,
    rope_base=1e6,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B card family (assignment)",
)

PLAN = MeshPlan(train_factors=(4, 2, 4, 8), microbatch=2)
