"""Whisper-medium encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv2 frontend is a stub per the assignment:
``input_specs`` feeds the 1500 post-conv frame embeddings; we implement the
24-layer bidirectional encoder over those frames and the 24-layer causal
decoder with cross-attention. MHA (kv == heads = 16).
"""
from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

CONFIG = ArchConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    d_head=64,
    encoder_layers=24,
    encoder_frames=1500,
    source="Whisper [arXiv:2212.04356], medium.en card",
)

PLAN = MeshPlan(train_factors=(8, 4, 1, 8), microbatch=2)
