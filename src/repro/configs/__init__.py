"""Assigned-architecture registry.

Every architecture from the assignment pool is a module exporting
``CONFIG: ArchConfig`` (exact published hyper-parameters, source cited) and
``PLAN: MeshPlan`` (how it factors the production mesh). Select with
``get_arch("<id>")`` or ``--arch <id>`` on the launchers.
"""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig
from repro.sharding.plan import MeshPlan

ARCH_IDS = (
    "internvl2-26b",
    "mixtral-8x22b",
    "whisper-medium",
    "glm4-9b",
    "qwen2.5-32b",
    "hymba-1.5b",
    "granite-moe-1b-a400m",
    "rwkv6-1.6b",
    "qwen3-14b",
    "gemma3-27b",
)

# Input shapes from the assignment (see configs/shapes.py for specs).
SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_plan(arch_id: str) -> MeshPlan:
    return _module(arch_id).PLAN


def all_archs() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}
